"""Mixed-precision Pareto sweep: uniform ql vs sensitivity-allocated bits.

Trains a tiny LM briefly, scores per-(matrix, layer) quantization
sensitivity on a calibration batch (``repro.core.sensitivity``), then
compares, at matched byte budgets, the end-to-end output error of

  * uniform quantization at every supported precision (2/3/4/5/6/8), and
  * the greedy budgeted allocation ("minimize total error s.t. bytes").

For each configuration it also reports the SAIL cost model's projected
C-SRAM decode cycles (each matrix priced at its own ``ql`` — the lutmm
instruction takes precision per call, so mixed allocations are free at
the ISA level).  Results print as a table and optionally land in a JSON
artifact; ``--check`` asserts the Pareto claim the allocator exists for:
at the uniform-4-bit byte budget, allocated mixed precision achieves
strictly lower output error on the calibration batch.

Run:  PYTHONPATH=src python benchmarks/mixed_precision_bench.py \
          --train-steps 60 --budgets q3,q4,q5 --json mixed_precision.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import Counter

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import cost_model as cm
from repro.core import sensitivity as sens
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.optim.adamw import AdamW


def train_briefly(params, cfg, steps: int):
    if steps <= 0:
        return params
    opt = AdamW(learning_rate=3e-3)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True)(p)
        upd, o, _ = opt.update(g, o, p)
        return opt.apply(p, upd), o, loss

    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, _ = step(params, opt_state, batch)
    return params


def allocation_units(params, policy):
    """(k, n, bits, copies) per quantizable unit under ``policy`` — the
    cost model's view of a (possibly mixed) allocation."""
    units = []
    for pstr, w, stacked in sens.quantizable_units(params, policy):
        k, n = int(w.shape[-2]), int(w.shape[-1])
        spec = policy.bits_for(pstr)
        if stacked:
            per_slice = 1
            for d in w.shape[1:-2]:
                per_slice *= int(d)
            layers = int(w.shape[0])
            if isinstance(spec, (tuple, list)):
                for b in spec:
                    units.append((k, n, int(b), per_slice))
            else:
                units.append((k, n, int(spec), per_slice * layers))
        else:
            units.append((k, n, int(spec), 1))
    return units


def evaluate(params, policy, fwd, ref):
    """(true output error, quantized bytes, projected cycles)."""
    qtree, _, nbytes = quantize_params(params, policy)
    err = float(jnp.mean((fwd(qtree) - ref) ** 2))
    cycles = cm.mixed_decode_cycles(allocation_units(params, policy))
    return err, int(nbytes), float(cycles)


def budget_bytes(params, policy):
    """Quantized-weight bytes under the allocator's own accounting (packed
    words + scales, no per-tensor codebook) — the apples-to-apples number
    for budget comparisons; quantize_params' total also counts codebooks
    and every unquantized leaf."""
    units = allocation_units(params, policy)
    return sum(sens.unit_bytes(k, n, b, policy.group_size, c) for k, n, b, c in units)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--layers", type=int, default=4, help="override n_layers")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--calib-batch", type=int, default=4)
    ap.add_argument("--calib-seq", type=int, default=32)
    ap.add_argument("--budgets", default="q3,q4,q5", help="comma list of q<b>")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--check", action="store_true", help="assert Pareto win at q4")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    params = train_briefly(params, cfg, args.train_steps)
    tokens = sens.calibration_tokens(cfg.vocab, args.calib_batch, args.calib_seq)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)
    base = QuantPolicy(bits=4, group_size=args.group_size, min_size=1024)

    results = {
        "config": {
            "arch": cfg.name,
            "n_layers": cfg.n_layers,
            "group_size": args.group_size,
            "train_steps": args.train_steps,
            "calib": [args.calib_batch, args.calib_seq],
        },
        "uniform": [],
        "allocated": [],
    }
    hdr = f"{'config':<26} {'bytes':>9} {'output err':>11} {'proj Mcycles':>13} bit histogram"
    print(hdr)
    print("-" * len(hdr))

    uniform_err = {}
    uniform_bytes = {}
    for b in sens.SUPPORTED_BITS:
        pol = dataclasses.replace(base, bits=b)
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        uniform_err[b], uniform_bytes[b] = err, nbytes
        results["uniform"].append({"bits": b, "bytes": nbytes, "err": err, "cycles": cycles})
        print(f"{'uniform Q' + str(b):<26} {nbytes:>9} {err:>11.6f} {cycles / 1e6:>13.3f}")

    t0 = time.time()
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    score_s = time.time() - t0
    pareto = None
    for part in filter(None, args.budgets.split(",")):
        budget_bits = int(part.lstrip("q"))
        pol, rep = sens.calibrate_policy(
            params, cfg, base, match_uniform=budget_bits, scores=scores
        )
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        hist = dict(Counter(rep.bits_by_unit.values()))
        results["allocated"].append(
            {
                "budget": part,
                "bytes": nbytes,
                "err": err,
                "cycles": cycles,
                "bits_histogram": hist,
                "predicted_err": rep.predicted_error,
            }
        )
        print(
            f"{'allocated @' + part + ' bytes':<26} {nbytes:>9} {err:>11.6f} "
            f"{cycles / 1e6:>13.3f} {hist}"
        )
        if budget_bits == 4:
            uni4_budget = budget_bytes(params, dataclasses.replace(base, bits=4))
            alloc_budget = budget_bytes(params, pol)
            pareto = {
                "uniform_err": uniform_err[4],
                "allocated_err": err,
                "uniform_bytes": uniform_bytes[4],
                "allocated_bytes": nbytes,
                "uniform_budget_bytes": uni4_budget,
                "allocated_budget_bytes": alloc_budget,
                "dominates": bool(err < uniform_err[4] and alloc_budget <= uni4_budget),
            }
    results["pareto_q4"] = pareto
    results["score_seconds"] = score_s

    if pareto is not None:
        verdict = "DOMINATES" if pareto["dominates"] else "DOES NOT DOMINATE"
        print(
            f"\nallocated {verdict} uniform Q4: "
            f"err {pareto['allocated_err']:.6f} vs {pareto['uniform_err']:.6f} "
            f"at {pareto['allocated_bytes']} vs {pareto['uniform_bytes']} bytes "
            f"(sensitivity scoring took {score_s:.1f}s)"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")
    if args.check:
        assert pareto is not None, "--check needs q4 in --budgets"
        if not pareto["dominates"]:
            raise AssertionError(
                f"allocated mixed precision failed to Pareto-dominate uniform Q4: {pareto}"
            )
        print("CHECK OK: allocated mixed precision Pareto-dominates uniform Q4")


if __name__ == "__main__":
    main()
