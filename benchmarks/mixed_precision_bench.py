"""Mixed-precision Pareto sweep: uniform ql vs sensitivity-allocated bits.

Trains a tiny LM briefly, scores per-(matrix, layer) quantization
sensitivity on a calibration batch (``repro.core.sensitivity``), then
compares, at matched byte budgets, the end-to-end output error of

  * uniform quantization at every supported precision (2/3/4/5/6/8), and
  * the greedy budgeted allocation ("minimize total error s.t. bytes").

For each configuration it also reports the SAIL cost model's projected
C-SRAM decode cycles (each matrix priced at its own ``ql`` — the lutmm
instruction takes precision per call, so mixed allocations are free at
the ISA level).  Results print as a table and optionally land in a JSON
artifact; ``--check`` asserts the Pareto claim the allocator exists for:
at the uniform-4-bit byte budget, allocated mixed precision achieves
strictly lower output error on the calibration batch.

Run:  PYTHONPATH=src python benchmarks/mixed_precision_bench.py \
          --train-steps 60 --budgets q3,q4,q5 --json mixed_precision.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import Counter

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import cost_model as cm
from repro.core import pattern
from repro.core import sensitivity as sens
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.optim.adamw import AdamW


def train_briefly(params, cfg, steps: int):
    if steps <= 0:
        return params
    opt = AdamW(learning_rate=3e-3)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True)(p)
        upd, o, _ = opt.update(g, o, p)
        return opt.apply(p, upd), o, loss

    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, _ = step(params, opt_state, batch)
    return params


def allocation_units(params, policy, with_abits=False):
    """Cost-model units per quantizable leaf under ``policy``:
    (k, n, bits, copies), or (k, n, bits, abits, copies) when
    ``with_abits`` (the joint allocation's view — a None abits is priced
    at the 8-bit default by ``mixed_decode_cycles``)."""

    def emit(k, n, wb, ab, copies):
        if with_abits:
            units.append((k, n, int(wb), None if ab is None else int(ab), copies))
        else:
            units.append((k, n, int(wb), copies))

    def at(spec, i):
        if spec is None or not isinstance(spec, (tuple, list)):
            return spec
        return spec[i]

    units = []
    for pstr, w, stacked in sens.quantizable_units(params, policy):
        k, n = int(w.shape[-2]), int(w.shape[-1])
        spec = policy.bits_for(pstr)
        aspec = policy.abits_for(pstr)
        if stacked:
            per_slice = 1
            for d in w.shape[1:-2]:
                per_slice *= int(d)
            layers = int(w.shape[0])
            layered = isinstance(spec, (tuple, list)) or isinstance(aspec, (tuple, list))
            if layered:
                for i in range(layers):
                    emit(k, n, at(spec, i), at(aspec, i), per_slice)
            else:
                emit(k, n, spec, aspec, per_slice * layers)
        else:
            emit(k, n, spec, aspec, 1)
    return units


def evaluate(params, policy, fwd, ref):
    """(true output error, quantized bytes, projected cycles)."""
    qtree, _, nbytes = quantize_params(params, policy)
    err = float(jnp.mean((fwd(qtree) - ref) ** 2))
    cycles = cm.mixed_decode_cycles(allocation_units(params, policy))
    return err, int(nbytes), float(cycles)


def budget_bytes(params, policy):
    """Quantized-weight bytes under the allocator's own accounting (packed
    words + scales, no per-tensor codebook) — the apples-to-apples number
    for budget comparisons; quantize_params' total also counts codebooks
    and every unquantized leaf."""
    units = allocation_units(params, policy)
    return sum(sens.unit_bytes(k, n, b, policy.group_size, c) for k, n, b, c in units)


def run_activations(args, cfg, params, tokens, fwd, ref, base):
    """Joint (wbits, abits) vs weight-only allocation at EQUAL projected
    decode cycles.

    The weight-only reference allocates wbits within the uniform-4 byte
    budget and serves 8-bit activations everywhere (the pre-joint
    status quo).  The joint allocator gets that configuration's projected
    ``mixed_decode_cycles`` as its cycle budget — it can only win by
    re-spending cycles, e.g. dropping insensitive layers to 6-bit
    activations to afford wider weights where the probes say it matters.
    With ``--prt measured`` both sides are priced with the simulated
    per-precision PRT hit rates instead of the paper's flat 13.8%.
    """
    print(f"\n=== joint (wbits, abits) allocation vs weight-only (prt={args.prt}) ===")
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    act_scores = sens.activation_sensitivity(
        params, cfg, tokens, base, abits_candidates=sens.SUPPORTED_ABITS
    )

    wpol, wrep = sens.calibrate_policy(params, cfg, base, match_uniform=4, scores=scores)
    wpol = dataclasses.replace(wpol, act_bits=8)
    w_units = allocation_units(params, wpol, with_abits=True)
    w_cycles = cm.mixed_decode_cycles(w_units, nbw="auto", prt=args.prt)

    jpol, jrep = sens.calibrate_policy(
        params,
        cfg,
        base,
        scores=scores,
        act_scores=act_scores,
        abits_candidates=sens.SUPPORTED_ABITS,
        cycle_budget=w_cycles,
        prt=args.prt,
    )
    j_units = allocation_units(params, jpol, with_abits=True)
    j_cycles = cm.mixed_decode_cycles(j_units, nbw="auto", prt=args.prt)

    def true_err(policy):
        qtree, _, nbytes = quantize_params(params, policy)
        return float(jnp.mean((fwd(qtree) - ref) ** 2)), int(nbytes)

    w_err, w_bytes = true_err(wpol)
    j_err, j_bytes = true_err(jpol)
    whist = dict(Counter(wrep.bits_by_unit.values()))
    jhist = dict(Counter(jrep.bits_by_unit.values()))
    print(f"{'config':<22} {'bytes':>9} {'output err':>11} {'proj Mcycles':>13}")
    print(f"{'weight-only @q4 a8':<22} {w_bytes:>9} {w_err:>11.6f} {w_cycles / 1e6:>13.4f}")
    print(f"{'joint @equal cycles':<22} {j_bytes:>9} {j_err:>11.6f} {j_cycles / 1e6:>13.4f}")
    print(f"weight-only bits: {whist}")
    print(f"joint (wbits, abits): {jhist}")

    flat = 1.0 - pattern.PAPER_CYCLE_REDUCTION
    discounts = sorted(
        {
            round(cm.resolve_prt_discount(args.prt, nbw, wb, ab), 6)
            for (wb, ab) in jrep.bits_by_unit.values()
            for nbw in (1, 2, 3, 4)
        }
    )
    print(f"lookup discounts in use: {discounts} (flat paper constant: {flat:.4f})")

    result = {
        "prt": args.prt,
        "weight_only": {
            "err": w_err,
            "bytes": w_bytes,
            "cycles": w_cycles,
            "bits_histogram": {str(k): v for k, v in whist.items()},
        },
        "joint": {
            "err": j_err,
            "bytes": j_bytes,
            "cycles": j_cycles,
            "bits_histogram": {str(k): v for k, v in jhist.items()},
            "predicted_err": jrep.predicted_error,
            "cycle_budget": jrep.cycle_budget,
        },
        "discounts": discounts,
        "dominates": bool(j_err < w_err and j_cycles <= w_cycles * (1 + 1e-9)),
    }
    if args.check:
        assert j_cycles <= w_cycles * (1 + 1e-9), (
            f"joint allocation exceeds the weight-only cycle budget: "
            f"{j_cycles} > {w_cycles}"
        )
        assert j_err < w_err, (
            f"joint (wbits, abits) allocation failed to beat weight-only "
            f"at equal projected cycles: {j_err} vs {w_err}"
        )
        if args.prt == "measured":
            assert any(abs(d - flat) > 1e-4 for d in discounts), (
                f"measured PRT discounts {discounts} degenerate to the "
                f"flat paper constant {flat}"
            )
        print(
            "CHECK OK: joint allocation Pareto-dominates weight-only at "
            f"equal projected cycles ({j_err:.6f} < {w_err:.6f} err, "
            f"{j_cycles / 1e6:.4f} <= {w_cycles / 1e6:.4f} Mcycles)"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--layers", type=int, default=4, help="override n_layers")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--calib-batch", type=int, default=4)
    ap.add_argument("--calib-seq", type=int, default=32)
    ap.add_argument("--budgets", default="q3,q4,q5", help="comma list of q<b>")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--check", action="store_true", help="assert Pareto win at q4")
    ap.add_argument(
        "--activations",
        action="store_true",
        help="joint (wbits, abits) allocation vs weight-only at equal "
        "projected cycles (with --check: assert the joint Pareto win)",
    )
    ap.add_argument(
        "--prt",
        choices=("paper", "measured"),
        default="measured",
        help="pattern-discount model for projected cycles in --activations mode",
    )
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    params = train_briefly(params, cfg, args.train_steps)
    tokens = sens.calibration_tokens(cfg.vocab, args.calib_batch, args.calib_seq)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)
    base = QuantPolicy(bits=4, group_size=args.group_size, min_size=1024)

    if args.activations:
        result = run_activations(args, cfg, params, tokens, fwd, ref, base)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(result, f, indent=2)
            print(f"wrote {args.json}")
        return

    results = {
        "config": {
            "arch": cfg.name,
            "n_layers": cfg.n_layers,
            "group_size": args.group_size,
            "train_steps": args.train_steps,
            "calib": [args.calib_batch, args.calib_seq],
        },
        "uniform": [],
        "allocated": [],
    }
    hdr = f"{'config':<26} {'bytes':>9} {'output err':>11} {'proj Mcycles':>13} bit histogram"
    print(hdr)
    print("-" * len(hdr))

    uniform_err = {}
    uniform_bytes = {}
    for b in sens.SUPPORTED_BITS:
        pol = dataclasses.replace(base, bits=b)
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        uniform_err[b], uniform_bytes[b] = err, nbytes
        results["uniform"].append({"bits": b, "bytes": nbytes, "err": err, "cycles": cycles})
        print(f"{'uniform Q' + str(b):<26} {nbytes:>9} {err:>11.6f} {cycles / 1e6:>13.3f}")

    t0 = time.time()
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    score_s = time.time() - t0
    pareto = None
    for part in filter(None, args.budgets.split(",")):
        budget_bits = int(part.lstrip("q"))
        pol, rep = sens.calibrate_policy(
            params, cfg, base, match_uniform=budget_bits, scores=scores
        )
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        hist = dict(Counter(rep.bits_by_unit.values()))
        results["allocated"].append(
            {
                "budget": part,
                "bytes": nbytes,
                "err": err,
                "cycles": cycles,
                "bits_histogram": hist,
                "predicted_err": rep.predicted_error,
            }
        )
        print(
            f"{'allocated @' + part + ' bytes':<26} {nbytes:>9} {err:>11.6f} "
            f"{cycles / 1e6:>13.3f} {hist}"
        )
        if budget_bits == 4:
            uni4_budget = budget_bytes(params, dataclasses.replace(base, bits=4))
            alloc_budget = budget_bytes(params, pol)
            pareto = {
                "uniform_err": uniform_err[4],
                "allocated_err": err,
                "uniform_bytes": uniform_bytes[4],
                "allocated_bytes": nbytes,
                "uniform_budget_bytes": uni4_budget,
                "allocated_budget_bytes": alloc_budget,
                "dominates": bool(err < uniform_err[4] and alloc_budget <= uni4_budget),
            }
    results["pareto_q4"] = pareto
    results["score_seconds"] = score_s

    if pareto is not None:
        verdict = "DOMINATES" if pareto["dominates"] else "DOES NOT DOMINATE"
        print(
            f"\nallocated {verdict} uniform Q4: "
            f"err {pareto['allocated_err']:.6f} vs {pareto['uniform_err']:.6f} "
            f"at {pareto['allocated_bytes']} vs {pareto['uniform_bytes']} bytes "
            f"(sensitivity scoring took {score_s:.1f}s)"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")
    if args.check:
        assert pareto is not None, "--check needs q4 in --budgets"
        if not pareto["dominates"]:
            raise AssertionError(
                f"allocated mixed precision failed to Pareto-dominate uniform Q4: {pareto}"
            )
        print("CHECK OK: allocated mixed precision Pareto-dominates uniform Q4")


if __name__ == "__main__":
    main()
