"""Mixed-precision Pareto sweep: uniform ql vs sensitivity-allocated bits.

Trains a tiny LM briefly, scores per-(matrix, layer) quantization
sensitivity on a calibration batch (``repro.core.sensitivity``), then
compares, at matched byte budgets, the end-to-end output error of

  * uniform quantization at every supported precision (2/3/4/5/6/8), and
  * the greedy budgeted allocation ("minimize total error s.t. bytes").

For each configuration it also reports the SAIL cost model's projected
C-SRAM decode cycles (each matrix priced at its own ``ql`` — the lutmm
instruction takes precision per call, so mixed allocations are free at
the ISA level).  Results print as a table and optionally land in a JSON
artifact; ``--check`` asserts the Pareto claim the allocator exists for:
at the uniform-4-bit byte budget, allocated mixed precision achieves
strictly lower output error on the calibration batch.

Run:  PYTHONPATH=src python benchmarks/mixed_precision_bench.py \
          --train-steps 60 --budgets q3,q4,q5 --json mixed_precision.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import Counter

import jax
import jax.numpy as jnp

import repro.configs as C
from repro import planning
from repro.core import cost_model as cm
from repro.core import pattern
from repro.core import sensitivity as sens
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.optim.adamw import AdamW


def train_briefly(params, cfg, steps: int):
    if steps <= 0:
        return params
    opt = AdamW(learning_rate=3e-3)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True)(p)
        upd, o, _ = opt.update(g, o, p)
        return opt.apply(p, upd), o, loss

    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, _ = step(params, opt_state, batch)
    return params


def allocation_units(params, policy, with_abits=False):
    """Cost-model units per quantizable leaf under ``policy``:
    (k, n, bits, copies), or (k, n, bits, abits, copies) when
    ``with_abits`` (the joint allocation's view — a None abits is priced
    at the 8-bit default by ``mixed_decode_cycles``).  Thin adapter over
    ``repro.planning.policy_units`` (the single unit-building source)."""
    units = planning.policy_units(params, policy)
    if with_abits:
        return [(k, n, wb, ab, copies) for k, n, wb, ab, copies, _ in units]
    return [(k, n, wb, copies) for k, n, wb, ab, copies, _ in units]


def evaluate(params, policy, fwd, ref):
    """(true output error, quantized bytes, projected cycles)."""
    qtree, _, nbytes = quantize_params(params, policy)
    err = float(jnp.mean((fwd(qtree) - ref) ** 2))
    cycles = cm.mixed_decode_cycles(allocation_units(params, policy))
    return err, int(nbytes), float(cycles)


def budget_bytes(params, policy):
    """Quantized-weight bytes under the allocator's own accounting (packed
    words + scales, no per-tensor codebook) — the apples-to-apples number
    for budget comparisons; quantize_params' total also counts codebooks
    and every unquantized leaf."""
    units = allocation_units(params, policy)
    return sum(sens.unit_bytes(k, n, b, policy.group_size, c) for k, n, b, c in units)


def run_activations(args, cfg, params, tokens, fwd, ref, base):
    """Joint (wbits, abits) vs weight-only allocation at EQUAL projected
    decode cycles.

    The weight-only reference allocates wbits within the uniform-4 byte
    budget and serves 8-bit activations everywhere (the pre-joint
    status quo).  The joint allocator gets that configuration's projected
    ``mixed_decode_cycles`` as its cycle budget — it can only win by
    re-spending cycles, e.g. dropping insensitive layers to 6-bit
    activations to afford wider weights where the probes say it matters.
    With ``--prt measured`` both sides are priced with the simulated
    per-precision PRT hit rates instead of the paper's flat 13.8%.
    """
    print(f"\n=== joint (wbits, abits) allocation vs weight-only (prt={args.prt}) ===")
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    act_scores = sens.activation_sensitivity(
        params, cfg, tokens, base, abits_candidates=sens.SUPPORTED_ABITS
    )

    wpol, wrep = sens.calibrate_policy(params, cfg, base, match_uniform=4, scores=scores)
    wpol = dataclasses.replace(wpol, act_bits=8)
    w_units = allocation_units(params, wpol, with_abits=True)
    w_cycles = cm.mixed_decode_cycles(w_units, nbw="auto", prt=args.prt)

    jpol, jrep = sens.calibrate_policy(
        params,
        cfg,
        base,
        scores=scores,
        act_scores=act_scores,
        abits_candidates=sens.SUPPORTED_ABITS,
        cycle_budget=w_cycles,
        prt=args.prt,
    )
    j_units = allocation_units(params, jpol, with_abits=True)
    j_cycles = cm.mixed_decode_cycles(j_units, nbw="auto", prt=args.prt)

    def true_err(policy):
        qtree, _, nbytes = quantize_params(params, policy)
        return float(jnp.mean((fwd(qtree) - ref) ** 2)), int(nbytes)

    w_err, w_bytes = true_err(wpol)
    j_err, j_bytes = true_err(jpol)
    whist = dict(Counter(wrep.bits_by_unit.values()))
    jhist = dict(Counter(jrep.bits_by_unit.values()))
    print(f"{'config':<22} {'bytes':>9} {'output err':>11} {'proj Mcycles':>13}")
    print(f"{'weight-only @q4 a8':<22} {w_bytes:>9} {w_err:>11.6f} {w_cycles / 1e6:>13.4f}")
    print(f"{'joint @equal cycles':<22} {j_bytes:>9} {j_err:>11.6f} {j_cycles / 1e6:>13.4f}")
    print(f"weight-only bits: {whist}")
    print(f"joint (wbits, abits): {jhist}")

    flat = 1.0 - pattern.PAPER_CYCLE_REDUCTION
    discounts = sorted(
        {
            round(cm.resolve_prt_discount(args.prt, nbw, wb, ab), 6)
            for (wb, ab) in jrep.bits_by_unit.values()
            for nbw in (1, 2, 3, 4)
        }
    )
    print(f"lookup discounts in use: {discounts} (flat paper constant: {flat:.4f})")

    result = {
        "prt": args.prt,
        "weight_only": {
            "err": w_err,
            "bytes": w_bytes,
            "cycles": w_cycles,
            "bits_histogram": {str(k): v for k, v in whist.items()},
        },
        "joint": {
            "err": j_err,
            "bytes": j_bytes,
            "cycles": j_cycles,
            "bits_histogram": {str(k): v for k, v in jhist.items()},
            "predicted_err": jrep.predicted_error,
            "cycle_budget": jrep.cycle_budget,
        },
        "discounts": discounts,
        "dominates": bool(j_err < w_err and j_cycles <= w_cycles * (1 + 1e-9)),
    }
    if args.check:
        assert j_cycles <= w_cycles * (1 + 1e-9), (
            f"joint allocation exceeds the weight-only cycle budget: "
            f"{j_cycles} > {w_cycles}"
        )
        assert j_err < w_err, (
            f"joint (wbits, abits) allocation failed to beat weight-only "
            f"at equal projected cycles: {j_err} vs {w_err}"
        )
        if args.prt == "measured":
            assert any(abs(d - flat) > 1e-4 for d in discounts), (
                f"measured PRT discounts {discounts} degenerate to the "
                f"flat paper constant {flat}"
            )
        print(
            "CHECK OK: joint allocation Pareto-dominates weight-only at "
            f"equal projected cycles ({j_err:.6f} < {w_err:.6f} err, "
            f"{j_cycles / 1e6:.4f} <= {w_cycles / 1e6:.4f} Mcycles)"
        )
    return result


def run_slo(args, cfg, params, tokens, fwd, ref, base):
    """SLO-driven planning vs the fixed-cycle-budget baseline, DRAM term on.

    The *baseline* is the pre-PlanSpec behavior: a joint (wbits, abits)
    solve whose only constraint is the projected compute cycles of
    uniform (4, a8) — byte-blind.  Under the DRAM-aware cost model
    (``--dram-bw`` scales the machine's bandwidth so the tiny proxy model
    exercises the byte bound the way a 7B model would on real hardware)
    its extra weight bytes surface as a *lower* achieved tokens/s: the
    byte-heavy plan can no longer hide behind the compute bound.

    The *SLO plan* targets exactly the throughput the baseline actually
    achieves (equal modeled throughput), which the Planner decomposes
    into a cycle budget AND a byte budget.  At that operating point the
    solver has the cycle slack the baseline wasted, so it reaches
    strictly lower true output error — ``--check`` asserts both halves:
    the plan meets its target under the model, at lower error than the
    fixed-budget baseline.

    ``--calibration PATH`` swaps the paper's machine constants for fitted
    ones (``kernel_bench --calibrate``): the solve then budgets against
    measured hardware, and the saved plan records the provenance.  With
    fitted (host-scale) compute constants the tiny proxy would be
    compute-bound, so unless ``--dram-bw`` is given explicitly the
    bandwidth is auto-scaled to keep the byte-blind baseline ~8x
    DRAM-bound — the regime the SLO decomposition exercises.
    """
    calib = None
    machine_base = cm.SailMachine()
    if args.calibration:
        from repro.planning.calibrate_cost import CalibrationResult

        calib = CalibrationResult.load(args.calibration)
        machine_base = calib.machine()
    dram_bw = args.dram_bw
    if dram_bw is None:
        dram_bw = machine_base.dram_bw if calib is not None else 2e9
    machine = dataclasses.replace(machine_base, dram_bw=dram_bw)
    cost = planning.DecodeCostModel(machine=machine, prt=args.prt, batch=args.slo_batch)
    tag = f", calibrated[{calib.backend}]" if calib is not None else ""
    print(
        f"\n=== SLO-driven plan vs fixed cycle budget "
        f"(prt={args.prt}, dram_bw={dram_bw:.2e} B/s{tag}) ==="
    )
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    act_scores = sens.activation_sensitivity(
        params, cfg, tokens, base, abits_candidates=sens.SUPPORTED_ABITS
    )

    bpol, brep = sens.calibrate_policy(
        params,
        cfg,
        base,
        match_uniform=4,
        match_uniform_abits=8,
        abits_candidates=sens.SUPPORTED_ABITS,
        scores=scores,
        act_scores=act_scores,
        prt=args.prt,
        machine=machine,
        cost_batch=args.slo_batch,
    )
    bcost = cost.evaluate(params, bpol)
    if calib is not None and args.dram_bw is None:
        # auto-scale the DRAM side (the baseline solve above is byte-blind,
        # so only the evaluation changes): bw such that the baseline's
        # weight stream takes 8x its compute time
        t_c = bcost.cycles / machine.freq_hz
        dram_bw = bcost.total_bytes / (8.0 * t_c * machine.dram_efficiency)
        machine = dataclasses.replace(machine, dram_bw=dram_bw)
        cost = planning.DecodeCostModel(machine=machine, prt=args.prt, batch=args.slo_batch)
        bcost = cost.evaluate(params, bpol)
        print(f"auto-scaled dram_bw -> {dram_bw:.2e} B/s (baseline 8x DRAM-bound)")

    target = args.slo if args.slo else bcost.tokens_per_second
    slo = planning.Slo(target, batch=args.slo_batch)
    plan = planning.PlanSpec(
        mode="auto",
        weight_bits=4,
        act_bits=8,
        prt=args.prt,
        quant_kv=True,
        calibration=calib.provenance() if calib is not None else None,
    )
    planner = planning.Planner(
        params,
        cfg,
        plan,
        base=base,
        cost=cost,
        tokens=tokens,
        scores=scores,
        act_scores=act_scores,
    )
    res = planner.solve(slo=slo)
    scost = res.cost

    def true_err(policy):
        qtree, _, _ = quantize_params(params, policy)
        return float(jnp.mean((fwd(qtree) - ref) ** 2))

    b_err, s_err = true_err(bpol), true_err(res.policy)
    hdr = f"{'config':<26} {'qbytes':>8} {'output err':>11} {'tok/s (DRAM-aware)':>19}"
    print(hdr)
    print(
        f"{'fixed cycle budget':<26} {bcost.quant_bytes:>8} {b_err:>11.6f} "
        f"{bcost.tokens_per_second:>19.1f}"
        + ("  [DRAM-bound]" if bcost.dram_bound else "")
    )
    print(
        f"{'SLO plan @' + format(target, '.1f'):<26} {scost.quant_bytes:>8} "
        f"{s_err:>11.6f} {scost.tokens_per_second:>19.1f}"
        + ("  [DRAM-bound]" if scost.dram_bound else "")
    )
    print(
        f"budgets: {res.budgets.cycle_budget:.0f} cycles, "
        f"{res.budgets.byte_budget} quantized bytes "
        f"({res.budgets.fixed_bytes} fixed f32 bytes charged)"
    )
    hist = dict(Counter(res.report.bits_by_unit.values()))
    print(f"plan hash: {res.spec.spec_hash}  bits: {hist}")
    if args.save_plan:
        res.spec.save(args.save_plan)
        print(f"wrote solved plan to {args.save_plan}")

    result = {
        "prt": args.prt,
        "dram_bw": dram_bw,
        "calibrated": calib is not None,
        "target_tps": target,
        "baseline": {
            "err": b_err,
            "qbytes": bcost.quant_bytes,
            "tps": bcost.tokens_per_second,
            "dram_bound": bcost.dram_bound,
            "cycles": bcost.cycles,
        },
        "slo_plan": {
            "err": s_err,
            "qbytes": scost.quant_bytes,
            "tps": scost.tokens_per_second,
            "dram_bound": scost.dram_bound,
            "cycles": scost.cycles,
            "plan_hash": res.spec.spec_hash,
            "meets_slo": res.meets_slo,
        },
    }
    if args.check:
        assert scost.tokens_per_second >= target * (1 - 1e-9), (
            f"SLO-derived plan misses its own target under the model: "
            f"{scost.tokens_per_second} < {target}"
        )
        assert s_err < b_err, (
            f"SLO plan failed to beat the fixed-budget baseline at equal "
            f"modeled throughput: {s_err} vs {b_err}"
        )
        print(
            "CHECK OK: SLO plan meets its target tokens/s under the "
            f"DRAM-aware model ({scost.tokens_per_second:.1f} >= {target:.1f}) "
            f"at lower output error than the fixed-budget baseline "
            f"({s_err:.6f} < {b_err:.6f})"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--layers", type=int, default=4, help="override n_layers")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--calib-batch", type=int, default=4)
    ap.add_argument("--calib-seq", type=int, default=32)
    ap.add_argument("--budgets", default="q3,q4,q5", help="comma list of q<b>")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--check", action="store_true", help="assert Pareto win at q4")
    ap.add_argument(
        "--activations",
        action="store_true",
        help="joint (wbits, abits) allocation vs weight-only at equal "
        "projected cycles (with --check: assert the joint Pareto win)",
    )
    ap.add_argument(
        "--prt",
        choices=("paper", "measured"),
        default="measured",
        help="pattern-discount model for projected cycles in --activations/--slo mode",
    )
    ap.add_argument(
        "--slo",
        nargs="?",
        type=float,
        const=0.0,
        default=None,
        help="SLO-driven planning vs the fixed-cycle-budget baseline under the "
        "DRAM-aware cost model; optional value = target tokens/s (default: "
        "whatever the fixed-budget baseline actually achieves, i.e. equal "
        "modeled throughput).  With --check: assert the plan meets the target "
        "at lower output error than the baseline",
    )
    ap.add_argument(
        "--slo-batch",
        type=int,
        default=8,
        help="batch the SLO is quoted at (decode slots)",
    )
    ap.add_argument(
        "--dram-bw",
        type=float,
        default=None,
        help="machine DRAM bandwidth for --slo mode (default 2e9, scaled down "
        "so the tiny proxy model is byte-bound the way a 7B model is on real "
        "hardware; with --calibration the default auto-scales to keep the "
        "baseline DRAM-bound under the fitted constants)",
    )
    ap.add_argument(
        "--calibration",
        default=None,
        metavar="PATH",
        help="fitted-constants JSON from 'kernel_bench --calibrate PATH': "
        "--slo mode then budgets against the measured machine and records "
        "the provenance in the solved plan",
    )
    ap.add_argument("--save-plan", default=None, help="write the solved SLO plan JSON here")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    params = train_briefly(params, cfg, args.train_steps)
    tokens = sens.calibration_tokens(cfg.vocab, args.calib_batch, args.calib_seq)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)
    base = QuantPolicy(bits=4, group_size=args.group_size, min_size=1024)

    if args.slo is not None:
        result = run_slo(args, cfg, params, tokens, fwd, ref, base)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(result, f, indent=2)
            print(f"wrote {args.json}")
        return

    if args.activations:
        result = run_activations(args, cfg, params, tokens, fwd, ref, base)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(result, f, indent=2)
            print(f"wrote {args.json}")
        return

    results = {
        "config": {
            "arch": cfg.name,
            "n_layers": cfg.n_layers,
            "group_size": args.group_size,
            "train_steps": args.train_steps,
            "calib": [args.calib_batch, args.calib_seq],
        },
        "uniform": [],
        "allocated": [],
    }
    hdr = f"{'config':<26} {'bytes':>9} {'output err':>11} {'proj Mcycles':>13} bit histogram"
    print(hdr)
    print("-" * len(hdr))

    uniform_err = {}
    uniform_bytes = {}
    for b in sens.SUPPORTED_BITS:
        pol = dataclasses.replace(base, bits=b)
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        uniform_err[b], uniform_bytes[b] = err, nbytes
        results["uniform"].append({"bits": b, "bytes": nbytes, "err": err, "cycles": cycles})
        print(f"{'uniform Q' + str(b):<26} {nbytes:>9} {err:>11.6f} {cycles / 1e6:>13.3f}")

    t0 = time.time()
    scores = sens.output_sensitivity(params, cfg, tokens, base)
    score_s = time.time() - t0
    pareto = None
    for part in filter(None, args.budgets.split(",")):
        budget_bits = int(part.lstrip("q"))
        pol, rep = sens.calibrate_policy(
            params, cfg, base, match_uniform=budget_bits, scores=scores
        )
        err, nbytes, cycles = evaluate(params, pol, fwd, ref)
        hist = dict(Counter(rep.bits_by_unit.values()))
        results["allocated"].append(
            {
                "budget": part,
                "bytes": nbytes,
                "err": err,
                "cycles": cycles,
                "bits_histogram": hist,
                "predicted_err": rep.predicted_error,
            }
        )
        print(
            f"{'allocated @' + part + ' bytes':<26} {nbytes:>9} {err:>11.6f} "
            f"{cycles / 1e6:>13.3f} {hist}"
        )
        if budget_bits == 4:
            uni4_budget = budget_bytes(params, dataclasses.replace(base, bits=4))
            alloc_budget = budget_bytes(params, pol)
            pareto = {
                "uniform_err": uniform_err[4],
                "allocated_err": err,
                "uniform_bytes": uniform_bytes[4],
                "allocated_bytes": nbytes,
                "uniform_budget_bytes": uni4_budget,
                "allocated_budget_bytes": alloc_budget,
                "dominates": bool(err < uniform_err[4] and alloc_budget <= uni4_budget),
            }
    results["pareto_q4"] = pareto
    results["score_seconds"] = score_s

    if pareto is not None:
        verdict = "DOMINATES" if pareto["dominates"] else "DOES NOT DOMINATE"
        print(
            f"\nallocated {verdict} uniform Q4: "
            f"err {pareto['allocated_err']:.6f} vs {pareto['uniform_err']:.6f} "
            f"at {pareto['allocated_bytes']} vs {pareto['uniform_bytes']} bytes "
            f"(sensitivity scoring took {score_s:.1f}s)"
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")
    if args.check:
        assert pareto is not None, "--check needs q4 in --budgets"
        if not pareto["dominates"]:
            raise AssertionError(
                f"allocated mixed precision failed to Pareto-dominate uniform Q4: {pareto}"
            )
        print("CHECK OK: allocated mixed precision Pareto-dominates uniform Q4")


if __name__ == "__main__":
    main()
