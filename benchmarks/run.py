"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV blocks for the kernel microbench
plus the machine-model reproductions of every SAIL table/figure.

Run:  PYTHONPATH=src python -m benchmarks.run  [--skip-kernels]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    from benchmarks import paper_tables as pt
    pt.fig1_lut_vs_bitserial()
    pt.table2_throughput()
    pt.fig6_dse()
    pt.fig9_speedup()
    pt.fig10_table3_batch()
    pt.fig12_breakdown()
    pt.fig13_tpd()
    pt.typeconv_cost()

    if not args.skip_kernels:
        from benchmarks import kernel_bench
        kernel_bench.main()

    print("\nbenchmarks: done")


if __name__ == "__main__":
    main()
