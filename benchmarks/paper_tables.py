"""Reproductions of every SAIL table/figure from the calibrated machine
model + the algorithmic implementations.  Each function prints a CSV-ish
block and returns rows for programmatic checks.
"""
from __future__ import annotations


from repro.core import cost_model as cm
from repro.core.typeconv import sram_cycles


def fig1_lut_vs_bitserial():
    """Fig. 1: LUT vs bit-serial efficiency gain across batch sizes."""
    print("\n# Fig.1 — LUT/bit-serial efficiency gain (lutmm_1k workload)")
    print("batch," + ",".join(f"Q{q}" for q in (2, 3, 4)))
    rows = []
    for b in (1, 2, 4, 8, 16, 32):
        gains = [cm.fig1_efficiency_gain(q, b) for q in (2, 3, 4)]
        rows.append((b, gains))
        print(f"{b}," + ",".join(f"{g:.2f}" for g in gains))
    return rows


def table2_throughput():
    """Table II: tokens/s across quant levels and thread counts."""
    print("\n# Table II — decode throughput model vs paper "
          "(tokens/s, batch 8)")
    print("model,ql,threads,arm_model,arm_paper,amx_model,amx_paper,"
          "sail_model,sail_paper")
    rows = []
    idx = {1: 0, 2: 1, 4: 2, 8: 3, 16: 4}
    for (mn, ql), cols in sorted(cm.PAPER_TABLE_II.items()):
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        for t in (1, 4, 16):
            row = (mn, ql, t,
                   cm.arm_tokens_per_second(model, ql, t, 8),
                   cols["arm"][idx[t]],
                   cm.amx_tokens_per_second(model, ql, t, 8),
                   cols["amx"][idx[t]],
                   cm.sail_tokens_per_second(model, ql, t, 8),
                   cols["sail"][idx[t]])
            rows.append(row)
            print(",".join(f"{x:.2f}" if isinstance(x, float) else str(x)
                           for x in row))
    ratios = [r[7] / r[8] for r in rows]
    print(f"# geomean model/paper (SAIL): {cm.geomean(ratios):.3f}")
    return rows


def fig6_dse():
    """Fig. 6: cycle counts across batch x NBW x precision."""
    print("\n# Fig.6 — lutmm_1k DSE (Mcycles; * = published anchor)")
    print("batch,nbw," + ",".join(f"Q{q}" for q in (2, 3, 4, 6, 8)))
    rows = []
    for b in (1, 2, 4, 8, 16, 24, 32):
        for nbw in (1, 2, 3, 4):
            cyc = [cm.fig6_workload_cycles(b, nbw, q) / 1e6
                   for q in (2, 3, 4, 6, 8)]
            mark = {(24, 4): "*", (24, 2): "*"}.get((b, nbw), "")
            rows.append((b, nbw, cyc))
            print(f"{b},{nbw}{mark}," + ",".join(f"{c:.2f}" for c in cyc))
    print("# anchors: B24/NBW4/Q2=3.00M, B24/NBW4/Q4=4.87M, "
          "B24/NBW2/Q2=11.45M (paper Sec. III-C)")
    return rows


def fig9_speedup():
    """Fig. 9: SAIL speedup over ARM across quantization levels."""
    print("\n# Fig.9 — SAIL/ARM speedup by quant level (16T, batch 8)")
    print("model,ql,speedup_model,speedup_paper")
    rows = []
    for (mn, ql), cols in sorted(cm.PAPER_TABLE_II.items()):
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        ours = (cm.sail_tokens_per_second(model, ql, 16, 8) /
                cm.arm_tokens_per_second(model, ql, 16, 8))
        paper = cols["sail"][4] / cols["arm"][4]
        rows.append((mn, ql, ours, paper))
        print(f"{mn},{ql},{ours:.2f},{paper:.2f}")
    print(f"# paper headline: up to 10.41x (13B-Q2); model max: "
          f"{max(r[2] for r in rows):.2f}x")
    return rows


def fig10_table3_batch():
    """Fig. 10 / Table III: batched decode vs GPUs (paper-measured GPU)."""
    print("\n# Table III — SAIL vs GPU decode (tokens/s; GPU = "
          "paper-measured llama.cpp)")
    print("model,ql,sail_model,sail_paper,v100_4k,a100_4k")
    rows = []
    for (mn, ql), plat in sorted(cm.PAPER_TABLE_III.items()):
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        ours = cm.sail_tokens_per_second(model, ql, 16, 8)
        rows.append((mn, ql, ours, plat["sail"][4096],
                     plat["v100_1x"][4096], plat["a100"][4096]))
        print(f"{mn},{ql},{ours:.2f},{plat['sail'][4096]},"
              f"{plat['v100_1x'][4096]},{plat['a100'][4096]}")
    return rows


def fig12_breakdown():
    """Fig. 12: Q4 GEMV latency breakdown."""
    print("\n# Fig.12 — Q4 GEMV kernel breakdown (ms; speedup vs baseline)")
    bd = cm.gemv_breakdown()
    base = bd["baseline"]
    for k, v in bd.items():
        print(f"{k},{v*1e3:.3f},{base/v:.2f}x")
    print("# paper final speedup: 3.81x")
    return bd


def fig13_tpd():
    """Fig. 13 / Table IV: tokens per dollar."""
    print("\n# Fig.13 — tokens/dollar (batch 8; GPU rows from Table III)")
    print("system,model,ql,tokens_s,monthly_usd,tpd")
    rows = []
    for (mn, ql) in [("7b", 2), ("7b", 4), ("7b", 8), ("13b", 2),
                     ("13b", 4), ("13b", 8)]:
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        entries = [
            ("sail_16c", cm.sail_tokens_per_second(model, ql, 16, 8)),
            ("cpu_16c", cm.arm_tokens_per_second(model, ql, 16, 8)),
            ("cpu_5c", cm.arm_tokens_per_second(model, ql, 5, 8)),
        ]
        if (mn, ql) in cm.PAPER_TABLE_III:
            entries.append(("v100_1x",
                            cm.PAPER_TABLE_III[(mn, ql)]["v100_1x"][4096]))
        for sys_name, tps in entries:
            tpd = cm.tokens_per_dollar(tps, sys_name)
            rows.append((sys_name, mn, ql, tps, tpd))
            print(f"{sys_name},{mn},{ql},{tps:.2f},"
                  f"{cm.MONTHLY_PRICE[sys_name]:.0f},{tpd:,.0f}")
    sail = [r for r in rows if r[0] == "sail_16c"]
    arm = {(r[1], r[2]): r[4] for r in rows if r[0] == "cpu_16c"}
    gains = [r[4] / arm[(r[1], r[2])] for r in sail]
    print(f"# SAIL/ARM TPD gain: up to {max(gains):.1f}x "
          f"(paper headline: 19.9x incl. 5-core comparisons)")
    return rows


def typeconv_cost():
    """Sec. III-E: in-memory conversion cycle formula across widths."""
    print("\n# Algorithm 1 — conversion cycles by int width")
    print("n_bits,logic_ops,sram_cycles")
    from repro.core.typeconv import logic_ops
    for n in (8, 12, 16, 20, 24, 25):
        print(f"{n},{logic_ops(n):.0f},{sram_cycles(n):.0f}")
