"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Reads the JSONL records produced by ``repro.launch.dryrun`` and derives,
per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_bw

plus the dominant bottleneck, MODEL_FLOPS = 6*N*D (6*N_active*D for MoE),
and the MODEL_FLOPS / HLO_FLOPs usefulness ratio (remat / redundancy /
dispatch waste shows up here).

Usage: PYTHONPATH=src python -m benchmarks.roofline runs/dryrun.jsonl

``--calibration PATH`` switches to the *measured* roofline: reads a
fitted-constants artifact (``kernel_bench --calibrate PATH``) and prints
the modeled-vs-measured LUT-GEMV grid plus the fitted machine — the
measurement the cost model is held to.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one new token per sequence
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 3.0}   # fwd + bwd


def model_flops(arch: str, shape: str) -> Optional[float]:
    """6*N*D (dense) / 6*N_active*D (MoE); 2*N*D for inference shapes."""
    import repro.configs as C
    try:
        cfg = C.get_config(arch)
    except ModuleNotFoundError:
        return None
    n = cfg.active_param_count()
    toks = SHAPE_TOKENS[shape]
    per_tok = 6.0 * n if shape in TRAIN_MULT else 2.0 * n
    return per_tok * toks


def analytic_hbm_bytes(arch: str, shape: str, mesh: str,
                       quantize: bool = True, ql: int = 4) -> Optional[float]:
    """Per-chip HBM bytes per step under TPU-grade fusion.

    The parsed HLO byte count is an upper bound (the CPU backend
    materializes elementwise chains a TPU would fuse), so the memory
    roofline term uses this analytic model instead:

      train   : params bf16 read (fwd+bwd) + grad f32 + Adam m/v r/w
                + layer-boundary activations (save + reload) x remat reread
      prefill : quantized params read + activation boundary traffic + KV out
      decode  : quantized params + codebook scales + int8 KV cache read
                + cache write + activation vectors  (the SAIL balance)
    """
    import repro.configs as C
    from repro.launch import specs as sp
    try:
        cfg = C.get_config(arch)
    except ModuleNotFoundError:
        return None
    n_chips = {"single": 256, "multi": 512}[mesh]
    dp = {"single": 16, "multi": 32}[mesh]
    s = sp.SHAPES[shape]
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    bpw = (ql / 8 + 4.0 / cfg.d_model) if quantize else 4.0  # + scales

    if s["kind"] == "train":
        b_loc = max(1, s["batch"] // dp)
        p_shard = p_total / n_chips  # fsdp x tp shards, gathered per layer
        weight_traffic = p_total / (n_chips / 1.0) * 2 * 3  # bf16, fwd+2 bwd
        opt_traffic = p_shard * (4 + 2 * 8 + 8)  # grad + m,v rw + param rw
        act = (cfg.n_layers * b_loc * s["seq"] * cfg.d_model * 2) * 4
        return weight_traffic + opt_traffic + act
    if s["kind"] == "prefill":
        b_loc = max(1, s["batch"] // dp)
        toks = b_loc * s["seq"]
        weight_traffic = p_active * bpw / (n_chips / dp)  # TP shard read
        act = cfg.n_layers * toks * cfg.d_model * 2 * 6
        kv_out = (cfg.n_layers * toks * cfg.kv_dim * 2 * 1
                  if cfg.family not in ("ssm",) else 0)
        return weight_traffic + act + kv_out
    # decode: one token for the whole (sharded) batch
    b_loc = max(1, s["batch"] // dp)
    weight_traffic = p_active * bpw / 16  # TP shard, read once per step
    clen = sp.decode_cache_len(cfg, shape)
    kv_bytes_pos = cfg.n_layers * cfg.kv_dim * (1 + 4 / cfg.head_dim)
    kv_read = b_loc * min(clen, s["seq"]) * kv_bytes_pos * 2 / 16
    act = b_loc * cfg.n_layers * cfg.d_model * 2 * 8
    return weight_traffic + kv_read + act


def analyze(records: List[dict], chips: Dict[str, int] = None):
    chips = chips or {"single": 256, "multi": 512}
    rows = []
    for r in records:
        if r.get("status") != "ok":
            rows.append(dict(r, dominant=r.get("status")))
            continue
        n_chips = chips[r["mesh"]]
        # prefer the trip-count-corrected parse (see hlo_cost.py); the raw
        # cost_analysis numbers undercount scanned models
        flops = r.get("flops_parsed", -1)
        if flops is None or flops <= 0:
            flops = r["flops_per_device"]
        mem_bytes = r.get("bytes_parsed", -1)
        if mem_bytes is None or mem_bytes <= 0:
            mem_bytes = r["bytes_per_device"]
        coll = r.get("coll_parsed", -1)
        if coll is None or coll < 0:
            coll = r["collective_total"]
        hbm_model = analytic_hbm_bytes(r["arch"], r["shape"], r["mesh"],
                                       r.get("quantize", True),
                                       r.get("ql", 4))
        t_comp = flops / PEAK_FLOPS
        t_mem = (hbm_model if hbm_model else mem_bytes) / HBM_BW
        t_coll = coll / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = flops * n_chips
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant,
            "hbm_bytes_model": hbm_model,
            "bytes_parsed_upper": mem_bytes,
            "flops_per_device": flops,
            "coll_bytes": coll,
            "spec_bytes_accessed": r.get("bytes_per_device"),
            "spec_flops": r.get("flops_per_device"),
            "model_flops": mf,
            "useful_ratio": (mf / hlo_total) if mf and hlo_total > 0
            else None,
            "roofline_fraction": (
                max(t_comp, 0.0) / max(t_comp, t_mem, t_coll, 1e-30)
                if dominant != "compute" else 1.0),
            "bound_time_s": max(terms.values()),
        })
    return rows


def print_table(rows: List[dict]) -> None:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} "
           f"{'compute(s)':>11s} {'memory(s)':>11s} {'coll(s)':>10s} "
           f"{'dominant':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "t_compute_s" not in r:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
                  f"{'—':>11s} {'—':>11s} {'—':>10s} "
                  f"{r.get('dominant', '?'):>10s}")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['t_compute_s']:11.4f} {r['t_memory_s']:11.4f} "
              f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} {ur:>7s}")


def print_calibration(path: str) -> None:
    """Measured roofline from a calibration artifact."""
    from repro.planning.calibrate_cost import CalibrationResult
    res = CalibrationResult.load(path)
    b, k, n = res.shape
    print(f"# measured LUT-GEMV roofline (backend={res.backend}, "
          f"B={b} K={k} N={n})")
    hdr = (f"{'wbits':>5s} {'abits':>5s} {'nbw':>4s} "
           f"{'measured(us)':>13s} {'modeled(us)':>12s} {'rel_err':>8s}")
    print(hdr)
    print("-" * len(hdr))
    freq = 3.0e9
    for p in res.points:
        print(f"{p['wbits']:5d} {p['abits']:5d} {p['nbw']:4d} "
              f"{p['measured_cycles'] / freq * 1e6:13.1f} "
              f"{p['modeled_cycles'] / freq * 1e6:12.1f} "
              f"{p['rel_err']:8.3f}")
    print(f"\nfitted machine overrides:")
    for kk, v in sorted(res.machine_overrides.items()):
        print(f"  {kk:22s} = {v:.6g}")
    print(f"stream bandwidth: {res.dram_bw_measured / 1e9:.2f} GB/s")
    print(f"max_rel_err={res.max_rel_err:.3f} "
          f"mean_rel_err={res.mean_rel_err:.3f}")


def main() -> None:
    if "--calibration" in sys.argv:
        print_calibration(sys.argv[sys.argv.index("--calibration") + 1])
        return
    path = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.jsonl"
    records = [json.loads(l) for l in open(path)]
    # keep the newest record per cell
    seen = {}
    for r in records:
        seen[(r["arch"], r["shape"], r["mesh"],
              r.get("quantize", True))] = r
    rows = analyze(list(seen.values()))
    print_table(rows)
    out = path.replace(".jsonl", "_roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
