"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts every ``lax.scan``-structured model (layer stacks, microbatch
accumulation, flash-attention chunking) by the trip count — and the same
holds for collective bytes.  This module re-derives per-device costs from
``compiled.as_text()`` with loops multiplied out:

    cost(computation) = sum(op costs) + sum(trip(w) * cost(body(w)))

FLOPs: dot ops (2 * prod(result) * prod(contracted dims)) + 1 flop/elem
for arithmetic elementwise ops.  Bytes: operand+result sizes of top-level
(post-fusion) instructions — fusion calls count their boundary tensors,
which is exactly the HBM traffic model.  Collectives: result bytes per
kind, trip-multiplied.

Trip counts: scan-counted loops compare the induction var against an s32
constant in the condition computation; we take the largest such constant.
Validated against hand-counted examples in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE = re.compile(r"(pred|bf16|[sufc]\d+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s*"
                    r"([\w\-]+)\((.*)\)(.*)$")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "power", "negate", "abs", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sign",
    "compare", "select", "and", "or", "xor", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "convert", "clamp",
    "cosine", "sine", "atan2", "erf", "remainder",
}
SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "iota", "partition-id", "replica-id"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


class Instr:
    __slots__ = ("name", "rtype", "op", "args", "attrs")

    def __init__(self, name, rtype, op, args, attrs):
        self.name, self.rtype, self.op = name, rtype, op
        self.args, self.attrs = args, attrs


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4), m.group(5)))
    return comps


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_elems = _shape_elems(ins.rtype)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs + ins.args)
    # operands look like "f32[64,64]{1,0} %lhs, f32[64,64]{1,0} %rhs"
    # (the % sigil is optional in some dump modes): shapes contain commas,
    # so match a "type name" pair instead of splitting on ","
    nm = re.search(r"%([\w.\-]+)", ins.args) or \
        re.search(r"(?:pred|bf16|[sufc]\d+)\[[\d,]*\](?:\{[^}]*\})?\s+"
                  r"([\w.\-]+)", ins.args)
    lhs_type = symtab.get(nm.group(1), "") if nm else ""
    sm = _SHAPE.search(lhs_type)
    if not (m and sm):
        return 2.0 * out_elems  # fallback
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for di in m.group(1).split(","):
        if di and int(di) < len(dims):
            contract *= dims[int(di)]
    return 2.0 * out_elems * max(contract, 1)


def _trip_count(cond_name: str, comps: Dict[str, List[Instr]]) -> int:
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", f"constant({ins.args})")
            if m:
                best = max(best, int(m.group(1)))
    return best


def _while_trip(ins: Instr, comps: Dict[str, List[Instr]]) -> int:
    """Trip count of a while instruction: XLA's resolved
    ``known_trip_count`` when recorded, else the condition-constant
    heuristic."""
    kt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                   ins.args + ins.attrs)
    if kt:
        return int(kt.group(1))
    cond = re.search(r"condition=%?([\w.\-]+)", ins.args + ins.attrs)
    return _trip_count(cond.group(1), comps) if cond else 1


def analyze(hlo: str) -> Dict[str, float]:
    comps = parse_computations(hlo)
    cache: Dict[str, Dict[str, float]] = {}

    def cost_of(name: str) -> Dict[str, float]:
        if name in cache:
            return cache[name]
        out = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
        for k in COLLECTIVES:
            out[f"coll_{k}"] = 0.0
        cache[name] = out  # guard cycles
        symtab = {i.name: i.rtype for i in comps.get(name, [])}
        for ins in comps.get(name, []):
            op = ins.op
            base = re.sub(r"-(start|done)$", "", op)
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.args + ins.attrs)
                trip = _while_trip(ins, comps)
                if body and body.group(1) in comps:
                    sub = cost_of(body.group(1))
                    for kk, vv in sub.items():
                        out[kk] += trip * vv
                continue
            if op in ("fusion", "call", "conditional", "map", "custom-call",
                      "sort", "reduce", "reduce-window", "scatter"):
                # descend into called computations
                for m in re.finditer(r"(?:calls=|to_apply=|branch_computations=\{)"
                                     r"%?([\w.\-]+)", ins.args + ins.attrs):
                    if m.group(1) in comps:
                        sub = cost_of(m.group(1))
                        for kk, vv in sub.items():
                            out[kk] += vv
            if base in COLLECTIVES:
                if not op.endswith("-done"):
                    b = _shape_bytes(ins.rtype)
                    out["coll_bytes"] += b
                    out[f"coll_{base}"] += b
            if op in ("dot", "dot-general"):
                out["flops"] += _dot_flops(ins, symtab)
            elif op == "convolution":
                out["flops"] += 2.0 * _shape_elems(ins.rtype) * 64  # approx
            elif op in ELEMENTWISE:
                out["flops"] += _shape_elems(ins.rtype)
            # memory traffic: boundary tensors of top-level ops
            if op not in SKIP_BYTES:
                b = _shape_bytes(ins.rtype)
                for a in ins.args.split(","):
                    a = a.strip().lstrip("%").split(" ")[-1].lstrip("%")
                    if a in symtab:
                        b += _shape_bytes(symtab[a])
                out["bytes"] += b
        cache[name] = out
        return out

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back to last computation
        entry = list(comps)[-1] if comps else ""
    return cost_of(entry)


def top_collectives(hlo: str, n: int = 15):
    """Largest collectives by trip-multiplied bytes: the perf-iteration
    profile for collective-bound cells.  Returns
    [(kind, result_type, trips, total_bytes, metadata_op_name)]."""
    comps = parse_computations(hlo)
    # computation -> multiplier (product of enclosing loop trips)
    mult: Dict[str, int] = {}

    def walk(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for ins in comps.get(name, []):
            if ins.op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.args + ins.attrs)
                if body:
                    walk(body.group(1), m * _while_trip(ins, comps))
            else:
                for mm_ in re.finditer(
                        r"(?:calls=|to_apply=|body=|condition=)"
                        r"%?([\w.\-]+)", ins.args + ins.attrs):
                    if mm_.group(1) in comps:
                        walk(mm_.group(1), m)

    m0 = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    entry = m0.group(1) if m0 else (list(comps)[-1] if comps else "")
    walk(entry, 1)

    rows = []
    for cname, instrs in comps.items():
        mm_ = mult.get(cname, 0)
        if not mm_:
            continue
        for ins in instrs:
            base = re.sub(r"-(start|done)$", "", ins.op)
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                b = _shape_bytes(ins.rtype)
                meta = re.search(r'op_name="([^"]+)"', ins.attrs)
                rows.append((base, ins.rtype.split("{")[0], mm_, mm_ * b,
                             meta.group(1)[-80:] if meta else ""))
    rows.sort(key=lambda r: -r[3])
    return rows[:n]
