"""Serving benchmark: continuous batching vs run-to-completion A/B.

Replays the same staggered-arrival workload through both scheduling
modes of ``repro.serving.engine.Engine`` and reports tokens/s, model
iterations (prefill + decode), mean/p99 request latency, and mean
time-to-first-token.  Arrivals are simulated at iteration granularity:
request i is submitted once the engine has run ``arrival[i]`` iterations
(wall-clock-free, so the comparison is deterministic and runs on CPU).

Run:  PYTHONPATH=src python benchmarks/serve_bench.py --requests 12 \
          --max-new 24 --arrival-gap 3
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.configs as C
from repro.models import lm
from repro.serving.engine import Engine, EngineConfig


def build_workload(cfg, n_requests: int, max_new: int, arrival_gap: int,
                   seed: int = 0):
    """(prompt, max_new, arrival_iteration) triples, FIFO by arrival."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(4, 14))
        prompt = rng.integers(0, cfg.vocab, size=plen).tolist()
        new = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append((prompt, new, i * arrival_gap))
    return reqs


def run_mode(params, cfg, ecfg: EngineConfig, workload):
    eng = Engine(params, cfg, ecfg)
    pending = list(workload)
    t0 = time.time()
    # drive the engine one iteration at a time, injecting arrivals
    while pending or not eng.sched.idle():
        while pending and pending[0][2] <= eng.iterations:
            prompt, new, _ = pending.pop(0)
            eng.submit(prompt, max_new_tokens=new)
        if not eng.step() and pending:
            # engine drained before the next arrival: jump to it
            prompt, new, _ = pending.pop(0)
            eng.submit(prompt, max_new_tokens=new)
    wall = time.time() - t0
    st = eng.stats()
    st["wall_s"] = wall
    st["tok_per_s"] = st["generated_tokens"] / max(wall, 1e-9)
    return st


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arrival-gap", type=int, default=3,
                    help="iterations between request arrivals")
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--plan", default=None,
                    help="precision plan (grammar string or plan.json "
                         "path) served in both modes")
    ap.add_argument("--json", default=None,
                    help="write per-mode stats (incl. plan provenance: "
                         "plan_hash/replan_count/prt_hit_rate) here")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    workload = build_workload(cfg, args.requests, args.max_new,
                              args.arrival_gap)
    total_prompt = sum(len(w[0]) for w in workload)
    print(f"{cfg.name}: {args.requests} staggered requests "
          f"(gap {args.arrival_gap} iters, {total_prompt} prompt tokens), "
          f"pool of {args.batch} slots, Q{args.ql} weights, int8 KV")

    plan = None
    if args.plan is not None:
        # resolve once: an auto plan re-solved per mode would run the
        # whole sensitivity calibration twice for the identical answer
        from repro import planning
        from repro.models.sail_linear import QuantPolicy
        plan = planning.plan_from_arg(args.plan)
        if not plan.solved:
            plan = planning.resolve_plan(
                plan, params, cfg,
                base=QuantPolicy(bits=args.ql, group_size=32,
                                 min_size=1024)).spec
    results = {}
    for mode in ("batch", "continuous"):
        ecfg = EngineConfig(batch_size=args.batch,
                            cache_len=args.cache_len, quantize=True,
                            ql=args.ql, group_size=32, quant_kv=True,
                            mode=mode, plan=plan,
                            prefill_budget=args.prefill_budget)
        results[mode] = run_mode(params, cfg, ecfg, workload)

    hdr = (f"{'mode':<12} {'iters':>6} {'tok/s':>8} {'mean lat':>9} "
           f"{'p99 lat':>9} {'TTFT':>7}")
    print(hdr)
    print("-" * len(hdr))
    for mode, st in results.items():
        print(f"{mode:<12} {st['iterations']:>6} {st['tok_per_s']:>8.2f} "
              f"{st['mean_latency_s']:>8.2f}s {st['p99_latency_s']:>8.2f}s "
              f"{st['mean_ttft_s']:>6.2f}s")
    b, c = results["batch"], results["continuous"]
    assert (c["generated_tokens"] == b["generated_tokens"]
            and c["requests"] == b["requests"]), \
        "modes served different workloads"
    print(f"continuous vs run-to-completion: "
          f"{b['iterations']}/{c['iterations']} = "
          f"{b['iterations']/c['iterations']:.2f}x fewer model iterations, "
          f"{c['tok_per_s']/max(b['tok_per_s'],1e-9):.2f}x tokens/s")
    print(f"plan: {c['plan_hash']} ({c['plan_mode']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
