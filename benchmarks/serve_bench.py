"""Serving benchmark: A/B scheduling comparison + trace-driven replay.

Two entry points over the same trace machinery
(``repro.serving.workload``):

  * default — replay one workload through both scheduling modes of
    ``repro.serving.engine.Engine`` (continuous batching vs
    run-to-completion) and report tokens/s, model iterations, mean/p99
    request latency, and mean time-to-first-token;
  * ``--replay`` — replay the identical trace under several precision
    plans (``--plan`` is repeatable, ``--slo-solve`` appends an
    SLO-solved plan) and emit a modeled-vs-measured tokens/s error
    report, optionally with the autonomous SLO controller attached
    (``--controller`` / ``--slo-frac``) — the CI ``trace-replay-gate``
    runs this mode with ``--max-rel-err`` and the controller-action
    assertions (``--expect-sheds`` / ``--expect-no-replan``).

  * ``--paged-gate`` — equal-KV-memory A/B of the fixed-slot pool vs
    the paged block pool on a prefix-heavy trace (``--prefix-len``):
    the paged engine must sustain strictly higher peak concurrency with
    per-request token-identical completions — the CI gate for the
    block-pool refactor.

Arrivals are simulated at iteration granularity: request i is submitted
once the engine has run ``arrival_iteration`` iterations (wall-clock
free, so a trace replays deterministically on any host).  Traces are
seeded and JSON-serializable: ``--save-trace`` writes one, ``--trace``
replays a saved file bit-identically.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py --requests 12 \
          --max-new 24 --arrival-gap 3 --arrival poisson --seed 7
      PYTHONPATH=src python benchmarks/serve_bench.py --replay \
          --arrival bursty --plan uniform:4 --slo-solve 1.2 \
          --controller --slo-frac 1.5 --json report.json
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Any, Dict, List


def _ensure_tp_devices(argv=None) -> None:
    """``--tp M`` on a CPU host needs M visible XLA devices, and the
    forcing flag only works BEFORE jax initializes — scan argv and set it
    here (mirrors ``repro.launch.serve``)."""
    argv = sys.argv[1:] if argv is None else argv
    tp = 1
    for i, a in enumerate(argv):
        if a == "--tp" and i + 1 < len(argv):
            tp = int(argv[i + 1])
        elif a.startswith("--tp="):
            tp = int(a.split("=", 1)[1])
    flags = os.environ.get("XLA_FLAGS", "")
    if tp > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (flags + f" --xla_force_host_platform_device_count={tp}").strip()


_ensure_tp_devices()

import jax  # noqa: E402  (after the device-count env fixup)

import repro.configs as C
from repro.models import lm
from repro.serving.engine import Engine, EngineConfig
from repro.serving.workload import ArrivalSpec, LengthDist, Trace, TraceSpec, generate


def build_workload(
    cfg,
    n_requests: int,
    max_new: int,
    arrival_gap: float,
    seed: int = 0,
    arrival: str = "fixed",
    burst: int = 4,
    prefix_len: int = 0,
    prompt_len: int = None,
) -> Trace:
    """The benchmark's workload as a :class:`Trace` — fully reproducible
    from ``(seed, spec)``, with ``arrival`` naming one of the generator's
    processes (fixed/poisson/bursty/diurnal) at mean gap ``arrival_gap``.
    ``prefix_len > 0`` prepends one shared system prompt to every request
    (the paged pool's prefix-sharing regime); ``prompt_len`` pins the
    per-request suffix to a constant length."""
    if prompt_len is not None:
        prompt = LengthDist(kind="constant", low=prompt_len, high=prompt_len)
    else:
        prompt = LengthDist(kind="uniform", low=4, high=13)
    spec = TraceSpec(
        seed=seed,
        n_requests=n_requests,
        vocab=cfg.vocab,
        prompt=prompt,
        output=LengthDist(kind="uniform", low=max(1, max_new // 2), high=max_new),
        arrival=ArrivalSpec(process=arrival, gap=arrival_gap, burst=burst),
        prefix_len=prefix_len,
    )
    return generate(spec)


def run_trace(params, cfg, ecfg: EngineConfig, trace: Trace) -> Dict[str, Any]:
    """Drive one engine through the trace (arrivals in engine
    iterations) and return its stats + wall-clock throughput."""
    eng = Engine(params, cfg, ecfg)
    pending = sorted(trace.requests, key=lambda r: r.arrival_iteration)
    i = 0
    t0 = time.perf_counter()
    while i < len(pending) or not eng.sched.idle():
        while i < len(pending) and pending[i].arrival_iteration <= eng.iterations:
            eng.submit(list(pending[i].prompt), max_new_tokens=pending[i].max_new_tokens)
            i += 1
        if not eng.step() and i < len(pending):
            # engine drained before the next arrival: jump to it
            eng.submit(list(pending[i].prompt), max_new_tokens=pending[i].max_new_tokens)
            i += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    st["wall_s"] = wall
    st["tok_per_s"] = st["generated_tokens"] / max(wall, 1e-9)
    st["completion_tokens"] = {str(u): c.tokens for u, c in sorted(eng.completions.items())}
    return st


# --- replay mode ----------------------------------------------------------


def _modeled_tps(params, cfg, policy, spec, batch: int, tp: int = 1, wire: int = 32) -> float:
    """Modeled decode tokens/s of a resolved plan at ``batch`` occupancy
    (the engine's ``planned_tps`` pricing, computed without building an
    engine — no quantization pass needed)."""
    from repro import planning

    units = planning.policy_units(params, policy)
    fixed = planning.unquantized_bytes(params, policy)
    kw: Dict[str, Any] = {"batch": batch, "prt": spec.prt, "nbw": spec.nbw}
    if spec.calibration is not None:
        kw["machine"] = planning.machine_from_json(spec.calibration)
        disp = planning.dispatch_from_json(spec.calibration)
        if disp is not None:
            kw["dispatch_cycles"] = disp
    if tp > 1:
        kw.update(tp=tp, wire_bits=wire, allreduce_elems=planning.tp_allreduce_elems(cfg))
    cost = planning.DecodeCostModel(**kw)
    secs = cost.iteration_seconds(
        cost.cycles(units), cost.qbytes(units, policy.group_size) + fixed
    )
    return batch / max(secs, 1e-30)


def _resolve_plans(args, params, cfg) -> List[Dict[str, Any]]:
    """CLI plan args -> [{label, spec, policy, modeled_tps}], resolving
    each once (auto plans run the Planner here, not per engine build)."""
    from repro import planning
    from repro.models.sail_linear import QuantPolicy

    base = QuantPolicy(bits=args.ql, group_size=32, min_size=1024)
    out: List[Dict[str, Any]] = []
    for arg in args.plan or ["uniform:%d" % args.ql]:
        plan = planning.plan_from_arg(arg)
        result = planning.resolve_plan(plan, params, cfg, base=base)
        out.append(
            {
                "label": arg,
                "spec": result.spec,
                "policy": result.policy,
                "modeled_tps": _modeled_tps(params, cfg, result.policy, result.spec, args.batch),
            }
        )
    if args.slo_solve is not None:
        # SLO-solved plan: target quoted against the baseline plan's own
        # modeled capacity, so the solve is self-referencing (no
        # hardcoded tokens/s that would rot with the cost model)
        target = args.slo_solve * out[0]["modeled_tps"]
        slo = planning.Slo(target, batch=args.batch)
        plan = planning.PlanSpec(
            mode="auto", weight_bits=args.ql, act_bits=8, prt="measured", quant_kv=True
        )
        result = planning.resolve_plan(plan, params, cfg, base=base, slo=slo)
        out.append(
            {
                "label": f"slo-solve:{args.slo_solve:g}x",
                "spec": result.spec,
                "policy": result.policy,
                "modeled_tps": _modeled_tps(params, cfg, result.policy, result.spec, args.batch),
            }
        )
    return out


def _replay(args, params, cfg, trace: Trace) -> Dict[str, Any]:
    """Replay the trace under every plan; fit one measured/modeled scale
    across plans (geometric mean — the host is not the modeled SAIL
    machine) and report each plan's residual relative error."""
    plans = _resolve_plans(args, params, cfg)
    entries: List[Dict[str, Any]] = []
    for p in plans:
        slo = args.slo_frac * p["modeled_tps"] if args.slo_frac is not None else None
        ecfg = EngineConfig(
            batch_size=args.batch,
            cache_len=args.cache_len,
            quantize=True,
            ql=args.ql,
            group_size=32,
            quant_kv=True,
            mode="continuous",
            plan=p["spec"],
            slo=slo,
            controller=args.controller or None,
            tap_capacity=args.tap if args.controller else 0,
            prefill_budget=args.prefill_budget,
            kv_block_size=args.block_size if args.paged else None,
            kv_pool_blocks=args.pool_blocks,
            kv_budget_bytes=args.kv_budget,
        )
        st = run_trace(params, cfg, ecfg, trace)
        tokens = st.pop("completion_tokens")
        if args.verify_determinism:
            st2 = run_trace(params, cfg, ecfg, trace)
            if st2.pop("completion_tokens") != tokens:
                raise SystemExit(f"FAIL: plan {p['label']} replay was not token-identical")
        entries.append(
            {
                "plan": p["label"],
                "plan_hash": st["plan_hash"],
                "plan_mode": st["plan_mode"],
                "slo_tps": slo,
                # occupancy-matched: each iteration priced at its true
                # occupancy, so controller caps don't read as model error
                "modeled_tps": st["modeled_run_tps"] or st["planned_tps"],
                "planned_tps": st["planned_tps"],
                "measured_tps": st["measured_tps"],
                "wall_s": st["wall_s"],
                "generated_tokens": st["generated_tokens"],
                "requests": st["requests"],
                "decode_iterations": st["decode_iterations"],
                "replan_count": st["replan_count"],
                "controller": st["controller"],
                # paged-pool observability (None on the slot pool): peak
                # blocks in use, shared-block hit ratio, preemptions
                "peak_active": st["peak_active"],
                "block_pool": st["block_pool"],
            }
        )
    ratios = [e["measured_tps"] / e["modeled_tps"] for e in entries]
    scale = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    for e, r in zip(entries, ratios):
        e["measured_over_modeled"] = r
        e["rel_err"] = abs(r / scale - 1.0)
    return {
        "trace": {
            "hash": trace.trace_hash,
            "requests": len(trace.requests),
            "prompt_tokens": trace.total_prompt_tokens,
            "new_tokens": trace.total_new_tokens,
            "spec": trace.spec.to_json(),
        },
        "scale": scale,
        "max_rel_err": max(e["rel_err"] for e in entries),
        "bound": args.max_rel_err,
        "slo_frac": args.slo_frac,
        "plans": entries,
    }


def _gate(args, report: Dict[str, Any]) -> None:
    """CI assertions: modeled-vs-measured error bound + controller
    behavior (sheds under SLO pressure, no replans on steady traffic)."""
    failures: List[str] = []
    if args.max_rel_err is not None and report["max_rel_err"] > args.max_rel_err:
        failures.append(
            f"modeled-vs-measured rel err {report['max_rel_err']:.3f} "
            f"exceeds bound {args.max_rel_err:.3f}"
        )
    if args.expect_sheds:
        acted = any(
            e["controller"] is not None
            and (e["controller"]["shed"] > 0 or e["controller"]["shrink"] > 0)
            for e in report["plans"]
        )
        if not acted:
            failures.append("expected >= 1 shed/shrink under SLO pressure, controller never acted")
    if args.expect_no_replan:
        for e in report["plans"]:
            c = e["controller"]
            if c is not None and (c["replan"] > 0 or c["resolve"] > 0):
                failures.append(
                    f"plan {e['plan']}: {c['replan']} replans / {c['resolve']} "
                    "resolves on a trace that expected none"
                )
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))


# --- paged gate -----------------------------------------------------------


def _paged_gate(args, params, cfg, trace: Trace) -> Dict[str, Any]:
    """Equal-memory A/B: fixed-slot pool vs paged block pool on a
    prefix-heavy trace.

    Both engines get the SAME KV byte budget — ``--gate-slots`` full
    ``cache_len`` slots, which the paged side receives as the equivalent
    block count (``cache_len`` must divide evenly into blocks so the
    budgets match exactly).  The gate asserts the paged engine (a) held
    strictly more requests in flight at its peak and (b) produced
    token-identical completions per request — prefix sharing buys
    concurrency, never output drift."""
    from repro import planning

    bs = args.block_size
    clen = args.cache_len
    if clen % bs:
        raise SystemExit(f"--cache-len {clen} must be a multiple of --block-size {bs}")
    mbs = clen // bs
    tok_bytes = planning.kv_token_bytes(lm.n_scan_blocks(cfg), cfg.n_kv, cfg.head_dim, 8)
    budget = args.gate_slots * clen * tok_bytes

    common = dict(
        cache_len=clen,
        quantize=True,
        ql=args.ql,
        group_size=32,
        quant_kv=True,
        mode="continuous",
        prefill_budget=args.prefill_budget,
    )
    slot = run_trace(
        params, cfg, EngineConfig(batch_size=args.gate_slots, **common), trace
    )
    paged = run_trace(
        params,
        cfg,
        EngineConfig(
            batch_size=args.batch,
            kv_block_size=bs,
            kv_pool_blocks=args.gate_slots * mbs,
            **common,
        ),
        trace,
    )
    slot_tokens = slot.pop("completion_tokens")
    paged_tokens = paged.pop("completion_tokens")
    identical = slot_tokens == paged_tokens
    report = {
        "trace": {
            "hash": trace.trace_hash,
            "requests": len(trace.requests),
            "prefix_len": trace.spec.prefix_len,
            "spec": trace.spec.to_json(),
        },
        "kv_budget_bytes": budget,
        "slot": {
            "batch_size": args.gate_slots,
            "peak_active": slot["peak_active"],
            "iterations": slot["iterations"],
            "mean_ttft_s": slot["mean_ttft_s"],
        },
        "paged": {
            "batch_size": args.batch,
            "pool_blocks": args.gate_slots * mbs,
            "block_size": bs,
            "peak_active": paged["peak_active"],
            "iterations": paged["iterations"],
            "mean_ttft_s": paged["mean_ttft_s"],
            "block_pool": paged["block_pool"],
        },
        "token_identical": identical,
    }
    print(
        f"equal KV budget {budget} B ({args.gate_slots} x {clen}-token slots"
        f" == {args.gate_slots * mbs} x {bs}-token blocks):"
    )
    print(
        f"  slot  pool: peak {slot['peak_active']} concurrent, "
        f"{slot['iterations']} iterations"
    )
    bp = paged["block_pool"]
    print(
        f"  paged pool: peak {paged['peak_active']} concurrent, "
        f"{paged['iterations']} iterations, shared ratio "
        f"{bp['shared_ratio']:.2f}, {bp['preemptions']} preemptions"
    )
    print(f"  completions token-identical: {identical}")
    failures = []
    if paged["peak_active"] <= slot["peak_active"]:
        failures.append(
            f"paged peak concurrency {paged['peak_active']} did not beat "
            f"the slot pool's {slot['peak_active']} at equal memory"
        )
    if not identical:
        failures.append("paged completions diverged from the slot pool's")
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return report


# --- speculative gate -----------------------------------------------------


def _speculative_gate(args, params, cfg, trace: Trace) -> Dict[str, Any]:
    """Greedy self-speculative A/B: one weight tree, two plans.

    Replays the identical trace through the baseline plan and through the
    same plan with a ``draft=`` clause, greedy both times.  The gate
    asserts (a) token-identical completions per request — greedy
    speculative decoding is exact, the draft/verify machinery may never
    change output — and (b) measured decode tokens/s at least
    ``--spec-speedup`` x the baseline's.  Each side runs twice and the
    second run is timed: the first pays the jit compiles (the baseline
    and speculative paths compile different kernels) and doubles as a
    determinism check.

    The default draft is the SAME precision as the baseline plan
    (``q8a8:k8`` under ``uniform:8a8``): on this op-count-bound reference
    backend a lower-bit draft step costs exactly what a full step costs,
    so the measured win isolates what IS measurable on the host — one
    fused k-token draft dispatch plus one batched verify dispatch
    replacing k+1 single-token iterations, with per-position acceptance
    exactly 1.  The bit-gap economics (fewer draft bytes vs acceptance
    loss) are SAIL-hardware quantities; the DecodeCostModel prices them
    and the planner's ``draft=auto`` solve arbitrates — pass a low-bit
    ``--spec-draft`` (e.g. ``q4a8:k3``) to exercise the lossy-draft
    accept/rollback path, which must still be token-identical.

    Saturate the engine for a stable measurement: arrivals are indexed
    by engine *iterations* and one speculative round is one iteration,
    so a staggered trace starves the speculative side's batch (run with
    ``--arrival-gap 0``)."""
    base_label = (args.plan or ["uniform:8a8"])[0]
    spec_label = f"{base_label},draft={args.spec_draft}"
    common = dict(
        batch_size=args.batch,
        cache_len=args.cache_len,
        quantize=True,
        group_size=32,
        min_size=1024,
        quant_kv=False,
        mode="continuous",
        prefill_budget=args.prefill_budget,
    )

    def timed(label):
        warm = run_trace(params, cfg, EngineConfig(plan=label, **common), trace)
        st = run_trace(params, cfg, EngineConfig(plan=label, **common), trace)
        if warm["completion_tokens"] != st["completion_tokens"]:
            raise SystemExit(f"FAIL: plan {label} replay was not token-identical")
        return st

    base = timed(base_label)
    spec = timed(spec_label)
    base_tokens = base.pop("completion_tokens")
    spec_tokens = spec.pop("completion_tokens")
    identical = base_tokens == spec_tokens
    speedup = spec["measured_tps"] / max(base["measured_tps"], 1e-9)
    sstat = spec["speculative"]
    report = {
        "trace": {
            "hash": trace.trace_hash,
            "requests": len(trace.requests),
            "spec": trace.spec.to_json(),
        },
        "baseline": {
            "plan": base_label,
            "measured_tps": base["measured_tps"],
            "decode_iterations": base["decode_iterations"],
            "generated_tokens": base["generated_tokens"],
        },
        "speculative": {
            "plan": spec_label,
            "measured_tps": spec["measured_tps"],
            "decode_iterations": spec["decode_iterations"],
            "generated_tokens": spec["generated_tokens"],
            "rounds": sstat["rounds"],
            "acceptance_rate": sstat["acceptance_rate"],
            "expected_tokens_per_round": sstat["expected_tokens_per_round"],
        },
        "token_identical": identical,
        "speedup": speedup,
        "bound": args.spec_speedup,
    }
    print(
        f"speculative gate ({spec_label} vs {base_label}): "
        f"{spec['measured_tps']:.1f} vs {base['measured_tps']:.1f} decode tok/s "
        f"= {speedup:.2f}x (bound {args.spec_speedup:g}x)"
    )
    print(
        f"  {sstat['rounds']} rounds, acceptance {sstat['acceptance_rate']:.3f}, "
        f"{spec['decode_iterations']}/{base['decode_iterations']} decode iterations, "
        f"token-identical: {identical}"
    )
    failures = []
    if not identical:
        failures.append("greedy speculative completions diverged from the baseline's")
    if speedup < args.spec_speedup:
        failures.append(
            f"measured speculative speedup {speedup:.2f}x below bound {args.spec_speedup:g}x"
        )
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return report


# --- tensor-parallel gate -------------------------------------------------


def _tp_gate(args, params, cfg, trace: Trace) -> Dict[str, Any]:
    """Greedy tp=M vs tp=1 A/B on the identical trace.

    The same plan serves the same trace single-device and sharded over
    ``--tp`` model-parallel shards; the gate asserts token-identical
    completions per request — sharding the quantized tree may buy
    throughput, never output drift (wire=32; the int8 wire is lossy by
    design and has its own bounded-error property test).  The report
    carries the engine's tp stats plus a per-shard modeled timing split
    (compute / DRAM / wire) — the CI artifact.
    """
    from repro import planning
    from repro.models.sail_linear import QuantPolicy

    label = (args.plan or ["uniform:%d" % args.ql])[0]
    common = dict(
        batch_size=args.batch,
        cache_len=args.cache_len,
        quantize=True,
        ql=args.ql,
        group_size=32,
        quant_kv=True,
        mode="continuous",
        plan=label,
        prefill_budget=args.prefill_budget,
        kv_block_size=args.block_size if args.paged else None,
        kv_pool_blocks=args.pool_blocks,
    )
    base = run_trace(params, cfg, EngineConfig(tp=1, **common), trace)
    shard = run_trace(params, cfg, EngineConfig(tp=args.tp, wire=args.wire, **common), trace)
    base_tokens = base.pop("completion_tokens")
    shard_tokens = shard.pop("completion_tokens")
    identical = base_tokens == shard_tokens

    # per-shard modeled split: each shard runs 1/tp of the lookups and
    # streams 1/tp of the quantized bytes; the wire term is the ring
    # all-reduce every shard pays in full
    base_q = QuantPolicy(bits=args.ql, group_size=32, min_size=1024)
    spec_obj = planning.as_plan(label)
    if not spec_obj.solved:
        spec_obj = planning.resolve_plan(spec_obj, params, cfg, base=base_q).spec
    policy = spec_obj.to_policy(base_q)
    units = planning.policy_units(params, policy)
    fixed = planning.unquantized_bytes(params, policy)
    cost = planning.DecodeCostModel(
        batch=args.batch,
        prt=spec_obj.prt,
        nbw=spec_obj.nbw,
        tp=args.tp,
        wire_bits=args.wire,
        allreduce_elems=planning.tp_allreduce_elems(cfg),
    )
    cycles = cost.cycles(units)
    total = cost.qbytes(units, policy.group_size) + fixed
    per_shard = [
        {
            "shard": i,
            "modeled_compute_s": cost.t_compute(cycles),
            "modeled_dram_s": cost.t_dram(total),
            "modeled_wire_s": cost.t_wire(args.batch),
        }
        for i in range(args.tp)
    ]
    report = {
        "trace": {
            "hash": trace.trace_hash,
            "requests": len(trace.requests),
            "spec": trace.spec.to_json(),
        },
        "plan": label,
        "pool": "paged" if args.paged else "ring",
        "tp1": {
            "measured_tps": base["measured_tps"],
            "decode_iterations": base["decode_iterations"],
            "generated_tokens": base["generated_tokens"],
        },
        "tp": {
            "shards": args.tp,
            "wire_bits": args.wire,
            "measured_tps": shard["measured_tps"],
            "decode_iterations": shard["decode_iterations"],
            "generated_tokens": shard["generated_tokens"],
            "stats": shard["tp"],
            "per_shard": per_shard,
        },
        "token_identical": identical,
    }
    print(
        f"tp gate ({label}, {report['pool']} pool): tp={args.tp} wire={args.wire} "
        f"vs tp=1 on trace {trace.trace_hash}"
    )
    print(
        f"  tp=1: {base['measured_tps']:.1f} tok/s over {base['decode_iterations']} iterations; "
        f"tp={args.tp}: {shard['measured_tps']:.1f} tok/s over {shard['decode_iterations']}"
    )
    st = shard["tp"]
    print(
        f"  all-reduce {st['allreduce_bytes_per_iter']} B/iter, modeled wire share "
        f"{st['modeled_wire_share']:.3f}" if st["modeled_wire_share"] is not None
        else f"  all-reduce {st['allreduce_bytes_per_iter']} B/iter"
    )
    print(f"  completions token-identical: {identical}")
    if not identical:
        raise SystemExit(f"FAIL: tp={args.tp} completions diverged from tp=1 on the same trace")
    return report


# --- CLI ------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0, help="trace generator seed")
    ap.add_argument(
        "--arrival",
        default="fixed",
        choices=["fixed", "poisson", "bursty", "diurnal"],
        help="arrival process (mean gap --arrival-gap iterations)",
    )
    ap.add_argument(
        "--arrival-gap",
        type=float,
        default=3,
        help="mean iterations between request arrivals",
    )
    ap.add_argument("--burst", type=int, default=4, help="bursty: arrivals per burst")
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="replay this saved trace.json instead of generating one",
    )
    ap.add_argument("--save-trace", default=None, metavar="PATH", help="write the trace JSON here")
    ap.add_argument(
        "--plan",
        action="append",
        default=None,
        help="precision plan (grammar string or plan.json path); "
        "repeatable in --replay mode, single-valued otherwise",
    )
    ap.add_argument("--json", default=None, help="write the stats/report JSON here")
    # replay mode
    ap.add_argument(
        "--replay",
        action="store_true",
        help="replay the trace under each --plan and report modeled-vs-measured error",
    )
    ap.add_argument(
        "--slo-solve",
        type=float,
        default=None,
        metavar="FRAC",
        help="replay: append an SLO-solved plan targeting FRAC x the "
        "baseline plan's modeled tokens/s",
    )
    ap.add_argument(
        "--controller",
        action="store_true",
        help="replay: attach the autonomous SLO controller to each engine",
    )
    ap.add_argument(
        "--slo-frac",
        type=float,
        default=None,
        metavar="FRAC",
        help="replay: serve each plan under an SLO of FRAC x its own "
        "modeled tokens/s (FRAC > 1 forces shed/shrink pressure)",
    )
    ap.add_argument("--tap", type=int, default=64, help="replay: ActivationTap rows (controller)")
    ap.add_argument(
        "--max-rel-err",
        type=float,
        default=None,
        help="gate: fail when any plan's scale-fitted modeled-vs-measured "
        "relative error exceeds this",
    )
    ap.add_argument(
        "--expect-sheds",
        action="store_true",
        help="gate: fail unless the controller shed/shrank at least once",
    )
    ap.add_argument(
        "--expect-no-replan",
        action="store_true",
        help="gate: fail if the controller replanned/resolved",
    )
    ap.add_argument(
        "--verify-determinism",
        action="store_true",
        help="replay each plan twice and require token-identical output",
    )
    # paged KV pool
    ap.add_argument(
        "--prefix-len",
        type=int,
        default=0,
        help="shared system-prompt tokens prepended to every request",
    )
    ap.add_argument(
        "--prompt-len",
        type=int,
        default=None,
        help="pin every request's (post-prefix) prompt to this length",
    )
    ap.add_argument(
        "--paged",
        action="store_true",
        help="replay: serve from the paged block pool instead of slots",
    )
    ap.add_argument("--block-size", type=int, default=16, help="paged: tokens per KV block")
    ap.add_argument("--pool-blocks", type=int, default=None, help="paged: pool size in blocks")
    ap.add_argument(
        "--kv-budget",
        type=int,
        default=None,
        metavar="BYTES",
        help="paged: size the pool from a KV byte budget",
    )
    ap.add_argument(
        "--paged-gate",
        action="store_true",
        help="equal-memory slot-vs-paged A/B gate: the paged pool must "
        "sustain strictly higher peak concurrency with token-identical "
        "output (prefix-heavy traces; see --prefix-len/--gate-slots)",
    )
    ap.add_argument(
        "--gate-slots",
        type=int,
        default=3,
        help="paged gate: KV budget quoted as this many full cache_len slots",
    )
    # tensor-parallel serving
    ap.add_argument(
        "--tp",
        type=int,
        default=1,
        help="with --replay: tp=M vs tp=1 A/B gate on the same trace — "
        "token-identity required (repro.serving.distributed); forces M "
        "host devices on CPU automatically",
    )
    ap.add_argument(
        "--wire",
        type=int,
        default=32,
        choices=(8, 32),
        help="tp gate: all-reduce precision (32 exact, 8 compressed)",
    )
    # self-speculative decoding
    ap.add_argument(
        "--speculative",
        action="store_true",
        help="A/B gate: the baseline plan vs the same plan with a draft= "
        "clause must be token-identical (greedy) and at least "
        "--spec-speedup x faster in measured decode tokens/s",
    )
    ap.add_argument(
        "--spec-draft",
        default="q8a8:k8",
        help="speculative gate: the draft= clause (q<b>[a<ab>]:k<k>); "
        "the same-precision default isolates round amortization, a "
        "low-bit value exercises lossy-draft accept/rollback",
    )
    ap.add_argument(
        "--spec-speedup",
        type=float,
        default=1.2,
        help="speculative gate: minimum measured decode tokens/s ratio",
    )
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.trace is not None:
        trace = Trace.load(args.trace)
    else:
        trace = build_workload(
            cfg,
            args.requests,
            args.max_new,
            args.arrival_gap,
            seed=args.seed,
            arrival=args.arrival,
            burst=args.burst,
            prefix_len=args.prefix_len,
            prompt_len=args.prompt_len,
        )
    if args.save_trace:
        trace.save(args.save_trace)
        print(f"wrote {args.save_trace}")
    print(
        f"{cfg.name}: {len(trace.requests)} requests "
        f"({trace.spec.arrival.process} arrivals, trace {trace.trace_hash}, "
        f"{trace.total_prompt_tokens} prompt tokens, "
        f"<= {trace.total_new_tokens} new), pool of {args.batch} slots"
    )

    if args.paged_gate:
        report = _paged_gate(args, params, cfg, trace)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        return

    if args.speculative:
        report = _speculative_gate(args, params, cfg, trace)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        return

    if args.replay and args.tp > 1:
        report = _tp_gate(args, params, cfg, trace)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        return

    if args.replay:
        report = _replay(args, params, cfg, trace)
        hdr = (
            f"{'plan':<18} {'modeled':>12} {'measured':>10} {'ratio':>10} "
            f"{'rel err':>8} {'shed':>5} {'replan':>7}"
        )
        print(hdr)
        print("-" * len(hdr))
        for e in report["plans"]:
            c = e["controller"] or {}
            print(
                f"{e['plan']:<18} {e['modeled_tps']:>12.0f} "
                f"{e['measured_tps']:>10.1f} {e['measured_over_modeled']:>10.2e} "
                f"{e['rel_err']:>8.3f} {c.get('shed', 0):>5} "
                f"{c.get('replan', 0) + c.get('resolve', 0):>7}"
            )
        print(
            f"measured/modeled scale {report['scale']:.3e} (geomean), "
            f"max residual rel err {report['max_rel_err']:.3f}"
            + (f" (bound {args.max_rel_err})" if args.max_rel_err is not None else "")
        )
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        _gate(args, report)
        return

    # --- default: continuous vs run-to-completion A/B ---------------------
    plan = None
    if args.plan:
        if len(args.plan) > 1:
            raise SystemExit("multiple --plan values need --replay")
        # resolve once: an auto plan re-solved per mode would run the
        # whole sensitivity calibration twice for the identical answer
        from repro import planning
        from repro.models.sail_linear import QuantPolicy

        plan = planning.plan_from_arg(args.plan[0])
        if not plan.solved:
            plan = planning.resolve_plan(
                plan, params, cfg, base=QuantPolicy(bits=args.ql, group_size=32, min_size=1024)
            ).spec
    results = {}
    for mode in ("batch", "continuous"):
        ecfg = EngineConfig(
            batch_size=args.batch,
            cache_len=args.cache_len,
            quantize=True,
            ql=args.ql,
            group_size=32,
            quant_kv=True,
            mode=mode,
            plan=plan,
            prefill_budget=args.prefill_budget,
        )
        results[mode] = run_trace(params, cfg, ecfg, trace)
        results[mode].pop("completion_tokens")

    hdr = f"{'mode':<12} {'iters':>6} {'tok/s':>8} {'mean lat':>9} {'p99 lat':>9} {'TTFT':>7}"
    print(hdr)
    print("-" * len(hdr))
    for mode, st in results.items():
        print(
            f"{mode:<12} {st['iterations']:>6} {st['tok_per_s']:>8.2f} "
            f"{st['mean_latency_s']:>8.2f}s {st['p99_latency_s']:>8.2f}s "
            f"{st['mean_ttft_s']:>6.2f}s"
        )
    b, c = results["batch"], results["continuous"]
    assert c["generated_tokens"] == b["generated_tokens"] and c["requests"] == b["requests"], (
        "modes served different workloads"
    )
    print(
        f"continuous vs run-to-completion: "
        f"{b['iterations']}/{c['iterations']} = "
        f"{b['iterations'] / c['iterations']:.2f}x fewer model iterations, "
        f"{c['tok_per_s'] / max(b['tok_per_s'], 1e-9):.2f}x tokens/s"
    )
    print(f"plan: {c['plan_hash']} ({c['plan_mode']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
