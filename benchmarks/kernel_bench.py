"""Kernel microbenchmarks (CPU wall time of the jitted XLA paths; the
Pallas kernels are TPU-targeted and timed structurally via the roofline).

Prints name,us_per_call,derived CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import lut_gemv, quant, typeconv
from repro.kernels.lut_gemv import ref as lut_ref


def timeit(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    print("\n# kernel microbench (XLA-on-CPU wall time)")
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    # quantized matmul (jnp oracle path that serve_step lowers)
    for bits in (2, 4, 8):
        w = jax.random.normal(key, (1024, 1024))
        qt = quant.quantize(w, bits, 128)
        x = jax.random.normal(key, (8, 1024))
        f = jax.jit(lambda x, qt=qt: lut_ref.lut_matmul_ref(x, qt))
        us = timeit(f, x)
        gmacs = 8 * 1024 * 1024 / (us * 1e-6) / 1e9
        print(f"lut_matmul_q{bits}_8x1024x1024,{us:.1f},{gmacs:.2f} GMAC/s")

    # faithful bit-serial LUT-GEMV
    xq = jax.random.randint(key, (8, 1024), -127, 128, dtype=jnp.int32)
    wq = jax.random.randint(key, (1024, 512), -8, 8, dtype=jnp.int32)
    for nbw in (2, 4):
        f = jax.jit(lambda x, w, nbw=nbw: lut_gemv.lut_gemv(x, w, nbw=nbw))
        us = timeit(f, xq, wq)
        print(f"bitserial_lut_gemv_nbw{nbw},{us:.1f},exact-int path")

    # Algorithm 1 conversion
    a = jax.random.randint(key, (65536,), -(1 << 24) + 1, 1 << 24,
                           dtype=jnp.int32)
    f = jax.jit(lambda a: typeconv.int_to_f32(a, 25))
    us = timeit(f, a)
    print(f"typeconv_int25_to_f32_64k,{us:.1f},"
          f"{65536 / (us * 1e-6) / 1e6:.1f} Melem/s")

    # activation quantization
    x = jax.random.normal(key, (8, 4096))
    f = jax.jit(lambda x: quant.quantize_activations(x, 8)[0])
    us = timeit(f, x)
    print(f"act_quant_8x4096,{us:.1f},-")


if __name__ == "__main__":
    main()
