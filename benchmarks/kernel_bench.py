"""Kernel microbenchmarks (CPU wall time of the jitted XLA paths; the
Pallas kernels are TPU-targeted and timed structurally via the roofline).

Prints name,us_per_call,derived CSV.

``--calibrate`` switches to cost-model calibration: time the faithful
LUT-GEMV across the (wbits, abits, NBW) grid, fit DecodeCostModel's
machine constants to the measurements (``planning/calibrate_cost.py``),
and optionally gate the modeled-vs-measured error (``--check``) / save
the fitted-constants JSON artifact (``--calibrate PATH``).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import lut_gemv, quant, typeconv
from repro.kernels.lut_gemv import ref as lut_ref


def timeit(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))  # warmup: one call, block everything
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_microbench() -> None:
    print("\n# kernel microbench (XLA-on-CPU wall time)")
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    # quantized matmul (jnp oracle path that serve_step lowers)
    for bits in (2, 4, 8):
        w = jax.random.normal(key, (1024, 1024))
        qt = quant.quantize(w, bits, 128)
        x = jax.random.normal(key, (8, 1024))
        f = jax.jit(lambda x, qt=qt: lut_ref.lut_matmul_ref(x, qt))
        us = timeit(f, x)
        gmacs = 8 * 1024 * 1024 / (us * 1e-6) / 1e9
        print(f"lut_matmul_q{bits}_8x1024x1024,{us:.1f},{gmacs:.2f} GMAC/s")

    # int-activation serve path (real low-bit datapath)
    w = jax.random.normal(key, (1024, 1024))
    x = jax.random.normal(key, (8, 1024))
    for abits in (4, 8):
        qt = quant.quantize(w, 4, 128)
        import dataclasses
        qt = dataclasses.replace(qt, abits=abits)
        xq, xs = quant.quantize_activations(x, abits)
        f = jax.jit(lambda xq, xs, qt=qt: lut_ref.lut_matmul_ref_int(
            xq, xs, qt))
        us = timeit(f, xq, xs)
        print(f"lut_matmul_q4_a{abits}_8x1024x1024,{us:.1f},int-act path")

    # faithful bit-serial LUT-GEMV
    xq = jax.random.randint(key, (8, 1024), -127, 128, dtype=jnp.int32)
    wq = jax.random.randint(key, (1024, 512), -8, 8, dtype=jnp.int32)
    for nbw in (2, 4):
        f = jax.jit(lambda x, w, nbw=nbw: lut_gemv.lut_gemv(x, w, nbw=nbw))
        us = timeit(f, xq, wq)
        print(f"bitserial_lut_gemv_nbw{nbw},{us:.1f},exact-int path")

    # Algorithm 1 conversion
    a = jax.random.randint(key, (65536,), -(1 << 24) + 1, 1 << 24,
                           dtype=jnp.int32)
    f = jax.jit(lambda a: typeconv.int_to_f32(a, 25))
    us = timeit(f, a)
    print(f"typeconv_int25_to_f32_64k,{us:.1f},"
          f"{65536 / (us * 1e-6) / 1e6:.1f} Melem/s")

    # activation quantization
    x = jax.random.normal(key, (8, 4096))
    f = jax.jit(lambda x: quant.quantize_activations(x, 8)[0])
    us = timeit(f, x)
    print(f"act_quant_8x4096,{us:.1f},-")


def run_calibrate(args) -> int:
    from repro.planning.calibrate_cost import run_calibration
    res = run_calibration(batch=args.batch, k=args.k, n=args.n,
                          iters=args.iters)
    print("\n# cost-model calibration "
          f"(backend={res.backend}, B={args.batch} K={args.k} N={args.n})")
    print("wbits,abits,nbw,measured_us,modeled_us,rel_err")
    freq = 3.0e9
    for p in res.points:
        print(f"{p['wbits']},{p['abits']},{p['nbw']},"
              f"{p['measured_cycles'] / freq * 1e6:.1f},"
              f"{p['modeled_cycles'] / freq * 1e6:.1f},{p['rel_err']:.3f}")
    print("# fitted machine overrides:")
    for kk, v in sorted(res.machine_overrides.items()):
        print(f"#   {kk} = {v:.6g}")
    print(f"# max_rel_err={res.max_rel_err:.3f} "
          f"mean_rel_err={res.mean_rel_err:.3f} "
          f"stream_bw={res.dram_bw_measured / 1e9:.2f} GB/s")
    if args.calibrate:
        res.save(args.calibrate)
        print(f"# saved fitted constants -> {args.calibrate}")
    if args.check:
        ok = (res.max_rel_err <= args.max_rel_err
              and res.mean_rel_err <= args.mean_rel_err)
        print(f"# check: max {res.max_rel_err:.3f} <= {args.max_rel_err} "
              f"and mean {res.mean_rel_err:.3f} <= {args.mean_rel_err}: "
              f"{'PASS' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="run cost-model calibration; save fitted-constants"
                         " JSON to PATH when given")
    ap.add_argument("--check", action="store_true",
                    help="with --calibrate: exit nonzero if the "
                         "modeled-vs-measured error exceeds the bounds")
    # defaults sized to the per-(nbw, abits) dispatch fit: measured CI
    # hosts land around max ~0.45 / mean ~0.15 (pre-fit worst was ~0.69)
    ap.add_argument("--max-rel-err", type=float, default=0.75,
                    help="--check bound on the worst grid point")
    ap.add_argument("--mean-rel-err", type=float, default=0.25,
                    help="--check bound on the grid mean")
    ap.add_argument("--iters", type=int, default=10,
                    help="timing repetitions per grid point")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--k", type=int, default=512)
    ap.add_argument("--n", type=int, default=256)
    args = ap.parse_args()
    if args.calibrate is not None:
        sys.exit(run_calibrate(args))
    run_microbench()


if __name__ == "__main__":
    main()
