"""Batched serving with the SAIL quantized path (tensor-level scheduling).

Quantizes a model to ql bits, serves prompts through the
continuous-batching engine (weights streamed once per iteration, reused
by all active users — the paper's Sec. III-A — with finished slots
back-filled at iteration granularity), and reports measured CPU
throughput plus the calibrated SAIL machine model's projection for the
same workload on the paper's hardware.

Run:  PYTHONPATH=src python examples/serve_batched.py --ql 4 --batch 8
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as C
from repro.core import cost_model as cm
from repro.models import lm
from repro.serving.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinymistral_248m")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--plan", default=None,
                    help="precision plan, e.g. rules:mlp=3,attn=5 or "
                         "auto:q4a8,prt=measured, or a plan.json path "
                         "(see repro.planning)")
    ap.add_argument("--full", action="store_true",
                    help="use the full config instead of smoke (slow)")
    ap.add_argument("--mode", choices=("continuous", "batch"),
                    default="continuous")
    args = ap.parse_args()

    cfg = C.get_config(args.arch) if args.full else C.get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)

    from repro.planning import plan_from_arg
    plan = plan_from_arg(args.plan) if args.plan is not None else None
    engine = Engine(params, cfg, EngineConfig(
        batch_size=args.batch, cache_len=256, quantize=True, ql=args.ql,
        group_size=32, quant_kv=True, mode=args.mode, plan=plan))
    wdesc = (f"mixed ({args.plan})"
             if engine.stats()["mixed_precision"] else f"Q{args.ql}")
    print(f"serving {cfg.name}: weights {wdesc}, "
          f"compression {engine.compression:.2f}x, int8 KV cache")

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).tolist()
        engine.submit(prompt, max_new_tokens=args.max_new)

    t0 = time.time()
    completions = engine.run()
    dt = time.time() - t0
    st = engine.stats()
    print(f"served {st['requests']} requests / "
          f"{st['generated_tokens']} tokens in {dt:.1f}s "
          f"({st['generated_tokens']/dt:.2f} tok/s measured on this CPU, "
          f"{st['iterations']} model iterations, "
          f"mean TTFT {st['mean_ttft_s']:.2f}s)")
    for c in completions[:3]:
        print(f"  req {c.uid}: {len(c.tokens)} tokens, "
              f"latency {c.latency_s:.2f}s, first tokens {c.tokens[:8]}")

    # SAIL machine-model projection for the same (model-size, ql, batch)
    model = cm.ModelSpec("arch", sum(
        x.size for x in jax.tree_util.tree_leaves(params)),
        cfg.d_model, cfg.n_layers, cfg.d_ff or cfg.d_model * 4)
    proj = cm.sail_tokens_per_second(model, args.ql, threads=16,
                                     batch=args.batch)
    arm = cm.arm_tokens_per_second(model, args.ql, threads=16,
                                   batch=args.batch)
    print(f"SAIL machine-model projection @16T/batch{args.batch}: "
          f"{proj:.1f} tok/s (ARM CPU baseline {arm:.1f} -> "
          f"{proj/arm:.1f}x)")


if __name__ == "__main__":
    main()
