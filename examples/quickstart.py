"""Quickstart: SAIL's three mechanisms in five minutes (CPU-only).

  1. bit-exact batched LUT-GEMV (the paper's Fig. 2 algorithm);
  2. the TPU LUT-dequant matmul kernel vs its jnp oracle;
  3. Algorithm-1 in-memory int->f32 conversion, bit-equal to the hardware
     conversion;
  4. the calibrated SAIL machine model reproducing headline paper numbers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import lut_gemv, pattern, quant, typeconv
from repro.kernels.lut_gemv import ops as lut_ops, ref as lut_ref


def main():
    key = jax.random.PRNGKey(0)

    print("=" * 70)
    print("1. Batched LUT-GEMV (paper Fig. 2) — exact integer semantics")
    xq = jax.random.randint(key, (8, 256), -127, 128, dtype=jnp.int32)
    wq = jax.random.randint(jax.random.PRNGKey(1), (256, 128), -8, 8,
                            dtype=jnp.int32)
    for nbw in (1, 2, 3, 4):
        out = lut_gemv.lut_gemv(xq, wq, nbw=nbw, abits=8)
        ref = lut_gemv.reference_int_gemv(xq, wq)
        counts = lut_gemv.lut_gemv_op_counts(8, 256, 128, nbw)
        print(f"  NBW={nbw}: exact={bool((out == ref).all())}  "
              f"LUT entries={counts['lut_entries']:3d}  "
              f"lookups={counts['lookups']}")

    print("=" * 70)
    print("2. TPU kernel (Pallas, interpret) vs jnp oracle")
    w = jax.random.normal(key, (512, 256))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512))
    for bits in (2, 4, 8):
        qt = quant.quantize(w, bits, group_size=128)
        y_k = lut_ops.lut_matmul(x, qt, backend="pallas")
        y_r = lut_ref.lut_matmul_ref(x, qt)
        err = float(jnp.abs(y_k - y_r).max())
        rel = float(jnp.abs(y_r - x @ w).max() / jnp.abs(x @ w).max())
        print(f"  Q{bits}: kernel-vs-oracle max err {err:.1e}; "
              f"quantization rel err {rel:.3f}; "
              f"weight bytes {qt.nbytes():,} vs {w.size * 4:,}")

    print("=" * 70)
    print("3. Algorithm 1: in-memory int->f32 (logic ops only)")
    a = np.random.randint(-(1 << 24) + 1, 1 << 24, size=10000).astype(np.int32)
    r = typeconv.int_to_f32(jnp.asarray(a), n=25)
    print(f"  bit-exact vs astype(float32): "
          f"{bool((np.asarray(r) == a.astype(np.float32)).all())}  "
          f"(cycles per 512-lane array batch: {typeconv.sram_cycles(25):.0f})")

    print("=" * 70)
    print("4. Pattern-aware LUT (PRT): measured repeat rate on activations")
    acts = jax.random.normal(jax.random.PRNGKey(3), (8, 256))
    aq, _ = quant.quantize_activations(acts, 8)
    st = pattern.measure_repeat_rate(np.asarray(aq), nbw=3)
    print(f"  PRT hit rate {st.hit_rate:.1%} (paper reports ~17% repeats "
          f"-> {pattern.PAPER_CYCLE_REDUCTION:.1%} cycle reduction)")

    print("=" * 70)
    print("5. SAIL machine model vs paper (Table II, 16 threads, batch 8)")
    print(f"  {'model':12s} {'ql':3s} {'SAIL model':>11s} {'paper':>8s} "
          f"{'ARM model':>10s} {'paper':>8s}")
    for (mn, ql) in [("7b", 2), ("7b", 4), ("7b", 8), ("13b", 2)]:
        m = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        srow = cm.PAPER_TABLE_II[(mn, ql)]
        print(f"  llama2-{mn:5s} Q{ql}  "
              f"{cm.sail_tokens_per_second(m, ql, 16, 8):11.2f} "
              f"{srow['sail'][4]:8.2f} "
              f"{cm.arm_tokens_per_second(m, ql, 16, 8):10.2f} "
              f"{srow['arm'][4]:8.2f}")
    bd = cm.gemv_breakdown()
    base = bd["baseline"]
    print(f"  Fig.12 staircase (speedup over CPU baseline): "
          f"NC {base/bd['neural_cache']:.2f}x, LUT {base/bd['lut']:.2f}x, "
          f"LUT+TC {base/bd['lut_tc']:.2f}x (paper: 3.81x)")


if __name__ == "__main__":
    main()
