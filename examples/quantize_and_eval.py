"""Quantization sensitivity sweep (the algorithm-level face of Fig. 9).

Trains a small LM briefly, then quantizes it at every supported precision
(Q2..Q8) and reports eval-loss degradation, weight compression, and the
SAIL cost model's projected speedup at that precision — the quality/speed
trade-off the ``ql`` instruction field exposes.

Run:  PYTHONPATH=src python examples/quantize_and_eval.py
"""
import argparse

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import cost_model as cm
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.models.sail_linear import QuantPolicy, quantize_params, nf_codebook
from repro.optim.adamw import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--nf", action="store_true",
                    help="use the non-uniform (normal-float) codebook")
    ap.add_argument("--no-alloc", action="store_true",
                    help="skip the sensitivity-allocated mixed rows")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    opt = AdamW(learning_rate=3e-3)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8))

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg), has_aux=True)(params)
        upd, opt_state, _ = opt.update(g, opt_state, params)
        return opt.apply(params, upd), opt_state, loss

    for i in range(args.train_steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, loss = step(params, opt_state, batch)
    print(f"trained {args.train_steps} steps, loss {float(loss):.3f}")

    eval_batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    base_loss = float(lm.loss_fn(params, eval_batch, cfg)[0])
    print(f"\n{'ql':>3s} {'eval loss':>10s} {'delta':>8s} {'compress':>9s} "
          f"{'SAIL 7B-proj tok/s':>18s}")
    print(f"{'f32':>3s} {base_loss:10.4f} {'-':>8s} {'1.0x':>9s} {'-':>18s}")
    for ql in (8, 6, 5, 4, 3, 2):
        cb = nf_codebook(ql) if args.nf else None
        qp, b0, b1 = quantize_params(
            params, QuantPolicy(bits=ql, group_size=32, min_size=1024,
                                codebook=cb))
        qloss = float(lm.loss_fn(qp, eval_batch, cfg)[0])
        proj = cm.sail_tokens_per_second(cm.LLAMA2_7B, ql, 16, 8)
        print(f"Q{ql:>2d} {qloss:10.4f} {qloss-base_loss:+8.4f} "
              f"{b0/b1:8.1f}x {proj:18.1f}")

    if not args.no_alloc:
        # sensitivity-allocated mixed precision at the uniform-4 byte
        # budget: same weight bytes, lower degradation (SAIL's
        # "optimal bit precision varies across layers")
        from repro.core import sensitivity as sens
        base = QuantPolicy(bits=4, group_size=32, min_size=1024,
                           codebook=nf_codebook if args.nf else None)
        pol, rep = sens.calibrate_policy(
            params, cfg, base, match_uniform=4,
            tokens=eval_batch["tokens"][:, :-1])
        qp, b0, b1 = quantize_params(params, pol)
        qloss = float(lm.loss_fn(qp, eval_batch, cfg)[0])
        print(f"mix {qloss:10.4f} {qloss-base_loss:+8.4f} {b0/b1:8.1f}x "
              f"{'(allocated at the Q4 byte budget)':>18s}")


if __name__ == "__main__":
    main()
