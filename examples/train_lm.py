"""End-to-end training driver: data pipeline -> sharded train step ->
fault-tolerant loop with checkpointing and resume.

Presets:
  --preset tiny   (default) ~1M params, 60 steps — finishes in minutes on
                  this CPU box and demonstrates loss going down;
  --preset 100m   the assignment's "~100M model for a few hundred steps"
                  configuration (what you'd run on a real slice);
  --arch <id>     any registry architecture at smoke scale.

Fault tolerance demo: run, Ctrl-C it mid-way, run again with the same
--ckpt dir — it resumes from the last checkpoint (data state included).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.models.common import ModelConfig
from repro.training.loop import TrainLoop, TrainLoopConfig


def preset_cfg(name: str, arch: str) -> ModelConfig:
    if name == "tiny":
        return dataclasses.replace(C.get_smoke(arch), attn_chunk=64)
    if name == "100m":
        # ~100M-param llama-style model (the real driver config)
        return ModelConfig(name="lm-100m", vocab=32000, d_model=640,
                           n_layers=10, n_heads=10, n_kv=5, d_ff=1728,
                           act="swiglu", max_seq=2048)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = preset_cfg(args.preset, args.arch)
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    print(f"training {cfg.name} on {n_dev} device(s)")

    sp_shapes = {"tokens": jax.ShapeDtypeStruct(
        (args.batch, args.seq + 1), jnp.int32)}
    built = build_train_step(cfg, mesh, bf16_compute=False)
    # rebuild the jit against the example batch shape
    step_fn = built.fn

    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    opt = built.meta["optimizer"]
    opt_state = opt.init(params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n/1e6:.1f}M")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    loop = TrainLoop(
        step_fn=lambda p, o, b: step_fn(p, o, b),
        params=params, opt_state=opt_state, data=data,
        lcfg=TrainLoopConfig(total_steps=args.steps, log_every=5,
                             checkpoint_every=20,
                             checkpoint_dir=args.ckpt))
    loop.install_signal_handlers()
    if loop.maybe_restore():
        print(f"resumed from step {loop.step}")
    result = loop.run()
    first = result["log"][0]["loss"] if result["log"] else float("nan")
    last = result["log"][-1]["loss"] if result["log"] else float("nan")
    print(f"done: step {result['final_step']}  loss {first:.3f} -> "
          f"{last:.3f}")


if __name__ == "__main__":
    main()
