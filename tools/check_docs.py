"""Docs checker: keep the prose as verified as the code.

Three checks over ``docs/*.md`` + ``README.md`` (run by the CI
``docs-check`` job and ``tests/test_docs.py``):

1. every fenced ``python`` code block must ``compile()``;
2. every dotted ``repro.*`` symbol named anywhere in the text must
   resolve — the longest importable module prefix is imported and the
   remaining attributes are walked with ``getattr`` — so the docs can
   only name API that actually exists;
3. every intra-repo markdown link target must exist on disk.

Exit code 0 when clean; nonzero with one line per violation.
"""

from __future__ import annotations

import importlib
import os
import re
import sys
from typing import Iterator, List, Tuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_FENCE = re.compile(r"^```(\w*)\s*$")
# dotted repro.* references; trailing () / punctuation stripped below
_SYMBOL = re.compile(r"\brepro(?:\.\w+)+")
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def doc_files() -> List[str]:
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out.extend(
            os.path.join(docs, f) for f in sorted(os.listdir(docs)) if f.endswith(".md")
        )
    return out


def iter_code_blocks(text: str) -> Iterator[Tuple[int, str, str]]:
    """Yield (start_line, language, source) for each fenced block."""
    lang, buf, start = None, [], 0
    for i, line in enumerate(text.splitlines(), start=1):
        m = _FENCE.match(line)
        if m and lang is None:
            lang, buf, start = m.group(1) or "", [], i
        elif line.strip() == "```" and lang is not None:
            yield start, lang, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def check_python_blocks(path: str, text: str) -> List[str]:
    errs = []
    for line, lang, src in iter_code_blocks(text):
        if lang != "python":
            continue
        try:
            compile(src, f"{path}:{line}", "exec")
        except SyntaxError as e:
            errs.append(f"{path}:{line}: python block does not compile: {e.msg}")
    return errs


def resolve_symbol(dotted: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbols(path: str, text: str) -> List[str]:
    errs = []
    seen = set()
    for i, line in enumerate(text.splitlines(), start=1):
        for m in _SYMBOL.finditer(line):
            dotted = m.group(0).rstrip(".")
            if dotted in seen:
                continue
            seen.add(dotted)
            if not resolve_symbol(dotted):
                errs.append(f"{path}:{i}: unresolvable symbol {dotted!r}")
    return errs


def check_links(path: str, text: str) -> List[str]:
    errs = []
    base = os.path.dirname(path)
    in_fence = False
    for i, line in enumerate(text.splitlines(), start=1):
        if _FENCE.match(line) or line.strip() == "```":
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            target = m.group(1)
            if "://" in target or target.startswith("mailto:"):
                continue
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                errs.append(f"{path}:{i}: dead link {target!r}")
    return errs


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    errors: List[str] = []
    for path in doc_files():
        if not os.path.exists(path):
            errors.append(f"{path}: missing")
            continue
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, ROOT)
        errors += check_python_blocks(rel, text)
        errors += check_symbols(rel, text)
        errors += check_links(path, text)
    for e in errors:
        print(e)
    if not errors:
        print(f"docs-check: {len(doc_files())} files clean")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
