"""Optimizer, data pipeline, checkpointing, training loop, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import (AsyncCheckpointer, keep_last, latest_step,
                              restore, save)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.optim.adamw import AdamW, cosine_schedule
from repro.serving.engine import Engine, EngineConfig
from repro.training.loop import TrainLoop, TrainLoopConfig


# --- optimizer --------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        upd, state, _ = opt.update(g, state, params)
        params = opt.apply(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule():
    fn = cosine_schedule(1.0, 10, 100)
    assert float(fn(jnp.array(0))) == 0.0
    assert float(fn(jnp.array(10))) == pytest.approx(1.0)
    assert float(fn(jnp.array(100))) == pytest.approx(0.1, rel=0.01)


def test_grad_clip():
    opt = AdamW(learning_rate=1.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(gnorm) == pytest.approx(np.sqrt(3) * 100, rel=1e-4)


# --- data -------------------------------------------------------------------

def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
    d1 = SyntheticLM(cfg)
    b1 = [d1.next_batch()["tokens"] for _ in range(3)]
    d2 = SyntheticLM(cfg)
    d2.load_state_dict({"step": 2})
    assert (d2.next_batch()["tokens"] == b1[2]).all()


def test_data_host_sharding():
    full = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8))
    h0 = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8,
                                n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8,
                                n_hosts=2, host_id=1))
    assert h0.next_batch()["tokens"].shape[0] == 4
    assert not (h0._batch_rng(0).integers(0, 1 << 30) ==
                h1._batch_rng(0).integers(0, 1 << 30))


# --- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    save(str(tmp_path), 7, tree, extras={"note": "x"})
    out, extras = restore(str(tmp_path), tree)
    assert extras["note"] == "x"
    assert (np.asarray(out["a"]) == np.asarray(tree["a"])).all()
    assert latest_step(str(tmp_path)) == 7


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, tree)
    keep_last(str(tmp_path), 2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones(5)})
    ck.wait()
    out, _ = restore(str(tmp_path), {"w": jnp.zeros(5)})
    assert (np.asarray(out["w"]) == 1).all()


def test_checkpoint_structure_mismatch(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"a": jnp.zeros(2), "b": jnp.zeros(1)})


# --- training loop (fault tolerance) ---------------------------------------

def _make_loop(tmp_path, steps=8):
    cfg = C.get_smoke("llama3_2_1b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg), has_aux=True)(params)
        upd, opt_state, gnorm = opt.update(g, opt_state, params)
        return opt.apply(params, upd), opt_state, {
            "loss": loss, "grad_norm": gnorm, "nll": m["nll"]}

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4))
    return TrainLoop(step, params, opt_state, data,
                     TrainLoopConfig(total_steps=steps, log_every=2,
                                     checkpoint_every=4,
                                     checkpoint_dir=str(tmp_path)))


def test_train_loss_decreases(tmp_path):
    loop = _make_loop(tmp_path, steps=30)
    result = loop.run()
    losses = [r["loss"] for r in result["log"]]
    assert losses[-1] < losses[0]


def test_train_checkpoint_resume(tmp_path):
    loop1 = _make_loop(tmp_path, steps=4)
    loop1.run()
    assert latest_step(str(tmp_path)) == 4
    loop2 = _make_loop(tmp_path, steps=8)
    assert loop2.maybe_restore()
    assert loop2.step == 4
    assert loop2.data.state.step == 4
    result = loop2.run()
    assert result["final_step"] == 8


# --- serving engine ---------------------------------------------------------

def test_engine_serves_batch():
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(batch_size=4, cache_len=64,
                                           quantize=True, ql=4,
                                           group_size=32, quant_kv=True))
    assert eng.compression > 2.0
    for i in range(5):
        eng.submit([1 + i, 2, 3], max_new_tokens=4)
    done = eng.run()
    assert len(done) == 5
    assert all(len(c.tokens) == 4 for c in done)
    st = eng.stats()
    assert st["generated_tokens"] == 20


def test_engine_unquantized():
    cfg = C.get_smoke("llama3_2_1b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(batch_size=2, cache_len=32,
                                           quantize=False, quant_kv=False))
    eng.submit([1, 2], max_new_tokens=3)
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 3
