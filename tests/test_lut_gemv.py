"""Faithful LUT-GEMV: bit-exact equality with integer matmul (the paper's
central algorithmic claim), across NBW, activation widths, and shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import lut_gemv


@pytest.mark.parametrize("nbw", [1, 2, 3, 4])
@pytest.mark.parametrize("abits", [4, 8])
def test_exact_vs_int_matmul(nbw, abits):
    lim = 1 << (abits - 1)
    xq = jax.random.randint(jax.random.PRNGKey(nbw), (5, 48), -lim + 1, lim,
                            dtype=jnp.int32)
    wq = jax.random.randint(jax.random.PRNGKey(abits), (48, 16), -8, 8,
                            dtype=jnp.int32)
    out = lut_gemv.lut_gemv(xq, wq, nbw=nbw, abits=abits)
    ref = lut_gemv.reference_int_gemv(xq, wq)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_lut_contents_match_fig2():
    """Paper Fig. 2: LUT[001] = W2, LUT[100] = W0, LUT[111] = sum."""
    w = jnp.array([[3], [5], [7]], jnp.int32)       # W0, W1, W2
    luts = lut_gemv.build_luts(w, nbw=3)            # [1, 8, 1]
    lut = np.asarray(luts)[0, :, 0]
    assert lut[0b001] == 7 and lut[0b100] == 3 and lut[0b010] == 5
    assert lut[0b111] == 15 and lut[0b000] == 0


def test_padding_path():
    xq = jax.random.randint(jax.random.PRNGKey(0), (3, 50), -100, 100,
                            dtype=jnp.int32)
    wq = jax.random.randint(jax.random.PRNGKey(1), (50, 8), -4, 4,
                            dtype=jnp.int32)
    out = lut_gemv.lut_gemv(xq, wq, nbw=4, abits=8)
    assert (np.asarray(out) ==
            np.asarray(lut_gemv.reference_int_gemv(xq, wq))).all()


def test_quantized_end_to_end_close():
    """The LUT pipeline must not add error beyond the irreducible weight
    quantization noise: compare against x @ dequant(wq) (what an exact
    integer GEMV + group dequant computes, up to 8-bit activation
    rounding), not against the unquantized matmul, whose 4-bit noise
    floor at K=128 is ~0.17 and not this function's responsibility."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 128))
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
    from repro.core.quant import quantize_int
    wq, ws = quantize_int(w, 4, 64)
    y = lut_gemv.lut_gemv_quantized(x, wq, ws, nbw=4, group_size=64)
    ref = x @ w
    wd = (wq.reshape(-1, 64, 32) * ws[:, None, :]).reshape(128, 32)
    qref = x @ wd                       # weight-quant-only oracle
    scale = float(jnp.abs(ref).max())
    lut_err = float(jnp.abs(y - qref).max()) / scale
    wq_err = float(jnp.abs(qref - ref).max()) / scale
    assert lut_err < 0.02               # 8-bit activations add <2%
    assert wq_err < 0.3                 # 4-bit group quant sanity bound
    assert float(jnp.abs(y - ref).max()) / scale < wq_err + 0.02


@settings(max_examples=30, deadline=None)
@given(nbw=st.integers(1, 4), b=st.integers(1, 6), k=st.integers(1, 8),
       n=st.integers(1, 6), seed=st.integers(0, 999))
def test_property_exactness(nbw, b, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    xq = jax.random.randint(k1, (b, 8 * k), -127, 128, dtype=jnp.int32)
    wq = jax.random.randint(k2, (8 * k, n), -16, 16, dtype=jnp.int32)
    out = lut_gemv.lut_gemv(xq, wq, nbw=nbw, abits=8)
    assert (np.asarray(out) ==
            np.asarray(lut_gemv.reference_int_gemv(xq, wq))).all()


@pytest.mark.parametrize("nbw", [1, 2, 3, 4])
@pytest.mark.parametrize("abits", [4, 6, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_kernel_precision_grid_exact(nbw, abits, signed):
    """Every point of the (nbw, abits, signed) kernel-precision grid the
    lutmm instruction can issue stays bit-exact vs the integer matmul —
    the property the joint (wbits, abits) allocator relies on when it
    varies activation precision per layer.  Random inputs per point come
    from the _hyp sweep below; this grid pins exhaustive coverage."""
    lim = 1 << (abits - 1)
    lo, hi = (-lim + 1, lim) if signed else (0, 1 << abits)
    xq = jax.random.randint(jax.random.PRNGKey(17 * nbw + abits),
                            (5, 36), lo, hi, dtype=jnp.int32)
    wq = jax.random.randint(jax.random.PRNGKey(abits), (36, 12), -8, 8,
                            dtype=jnp.int32)
    out = lut_gemv.lut_gemv(xq, wq, nbw=nbw, abits=abits, signed=signed)
    ref = lut_gemv.reference_int_gemv(xq, wq)
    assert (np.asarray(out) == np.asarray(ref)).all()


@settings(max_examples=24, deadline=None)
@given(nbw=st.sampled_from([1, 2, 3, 4]), abits=st.sampled_from([4, 6, 8]),
       signed=st.booleans(), b=st.integers(1, 6), k=st.integers(1, 6),
       n=st.integers(1, 5), seed=st.integers(0, 999))
def test_property_kernel_precision_grid(nbw, abits, signed, b, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    lim = 1 << (abits - 1)
    lo, hi = (-lim + 1, lim) if signed else (0, 1 << abits)
    xq = jax.random.randint(k1, (b, 8 * k), lo, hi, dtype=jnp.int32)
    wq = jax.random.randint(k2, (8 * k, n), -16, 16, dtype=jnp.int32)
    out = lut_gemv.lut_gemv(xq, wq, nbw=nbw, abits=abits, signed=signed)
    assert (np.asarray(out) ==
            np.asarray(lut_gemv.reference_int_gemv(xq, wq))).all()


def test_op_counts():
    c = lut_gemv.lut_gemv_op_counts(batch=8, k=1024, n=1024, nbw=4)
    assert c["lut_builds"] == 256
    assert c["lut_entries"] == 16
    assert c["lookups"] == 8 * 8 * 256
