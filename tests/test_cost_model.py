"""SAIL machine model vs the paper's published numbers (the reproduction's
quantitative validation — tolerances reflect the calibration residuals
recorded in EXPERIMENTS.md)."""
import math

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import pattern


def test_fig6_anchor_points():
    """Fig. 6 anchors within the documented calibration band (<=2.5x is a
    failure; fitted residuals are ~20-40%)."""
    for (b, nbw, wb), target in cm.PAPER_FIG6_ANCHORS.items():
        got = cm.fig6_workload_cycles(b, nbw, wb)
        assert 0.4 < got / target < 2.0, ((b, nbw, wb), got, target)


def test_fig6_qualitative_shape():
    """Cycle count decreases with batch amortization and the NBW=2 rebuild
    penalty exceeds NBW=4 at 2-bit (paper's stated trade-off)."""
    c_small = cm.fig6_workload_cycles(1, 4, 2)
    c_big = cm.fig6_workload_cycles(24, 4, 2)
    assert c_big < c_small * 24  # sublinear in batch (LUT reuse)
    assert (cm.fig6_workload_cycles(24, 2, 2) >
            cm.fig6_workload_cycles(24, 4, 2))


def test_table2_sail_fit():
    ratios = []
    for (mn, ql), cols in cm.PAPER_TABLE_II.items():
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        got = cm.sail_tokens_per_second(model, ql, 16, 8)
        ratios.append(got / cols["sail"][4])
    g = math.exp(np.mean(np.log(ratios)))
    assert 0.75 < g < 1.25, g
    assert np.mean(np.abs(np.array(ratios) - 1)) < 0.25


def test_table2_baseline_fit():
    errs = []
    for (mn, ql), cols in cm.PAPER_TABLE_II.items():
        model = cm.LLAMA2_7B if mn == "7b" else cm.LLAMA2_13B
        errs.append(abs(cm.arm_tokens_per_second(model, ql, 1, 8) /
                        cols["arm"][0] - 1))
        errs.append(abs(cm.amx_tokens_per_second(model, ql, 16, 8) /
                        cols["amx"][4] - 1))
    assert np.mean(errs) < 0.25, np.mean(errs)


def test_fig12_breakdown():
    bd = cm.gemv_breakdown()
    base = bd["baseline"]
    assert base / bd["lut_tc"] == pytest.approx(3.81, rel=0.12)
    # staircase ordering: baseline > NC > LUT > LUT+TC
    assert bd["baseline"] > bd["neural_cache"] > bd["lut"] > bd["lut_tc"]


def test_fig1_shape():
    """LUT gain grows with batch; bit-serial wins at batch 1 (LUT build
    unamortized) — the crossover the paper's Fig. 1 shows."""
    g1 = cm.fig1_efficiency_gain(2, 1)
    g32 = cm.fig1_efficiency_gain(2, 32)
    assert g32 > g1
    assert g32 > 1.5


def test_speedup_headlines():
    """Paper headline: up to ~10.4x over ARM (13B-Q2)."""
    best = max(
        cm.sail_tokens_per_second(cm.LLAMA2_13B, ql, 16, 8) /
        cm.arm_tokens_per_second(cm.LLAMA2_13B, ql, 16, 8)
        for ql in (2, 3, 4))
    assert best > 5.0


def test_tpd():
    tpd = cm.tokens_per_dollar(100.0, "cpu_16c")
    assert tpd == pytest.approx(100 * 30 * 24 * 3600 / 665.45)


def test_lut_overhead_contradiction_documented():
    """The paper says LUT build is 3% at (B8, NBW2, Q2) yet attributes
    11.45M cycles at NBW=2 to 'rebuild overhead' — mutually inconsistent.
    We follow the Fig. 6 anchors; this test pins the chosen behaviour."""
    frac = cm.lut_build_fraction(cm.SailMachine(), 8, 2, 2)
    assert frac > 0.2  # anchor-consistent, NOT the 3% prose figure


def test_pattern_discount():
    assert pattern.cycle_discount(0.17) == pytest.approx(1 - 0.138, rel=0.01)
    assert pattern.cycle_discount(0.0) == 1.0


def test_best_nbw_in_range():
    for ql in (2, 4, 8):
        nbw = cm.best_nbw(cm.LLAMA2_7B, ql, 16, 8)
        assert 1 <= nbw <= 4


def test_lut_build_fraction_uses_kernel_level_lookup_cost():
    """lut_build_fraction must price lookups at the SAME kernel level as
    the cycle total it describes (it used to ignore the flag): kernel
    lookups are cheaper, so the build fraction is strictly larger, and
    both must be exactly consistent with lookup_cycles."""
    m = cm.SailMachine()
    f_sys = cm.lut_build_fraction(m, 8, 4, 4)
    f_krn = cm.lut_build_fraction(m, 8, 4, 4, kernel_level=True)
    assert f_krn > f_sys
    b = cm.lut_build_cycles(m, 4, 4)
    for kl, frac in ((False, f_sys), (True, f_krn)):
        lookups = 8 * 8 * cm.lookup_cycles(m, 4, kernel_level=kl)
        assert frac == pytest.approx(b / (b + lookups))


def test_best_nbw_for_unit_matches_exhaustive_argmin():
    """The per-unit pick must be the true argmin of lut_gemv_cycles over
    NBW at that unit's exact operating point."""
    m = cm.SailMachine()
    flat = 1.0 - pattern.PAPER_CYCLE_REDUCTION
    for k, n, wb, ab, batch in ((1024, 1024, 4, 8, 8),
                                (256, 512, 2, 4, 1),
                                (4096, 4096, 8, 6, 64)):
        pick = cm.best_nbw_for_unit(k, n, wb, ab, batch=batch)
        cycles = {nbw: cm.lut_gemv_cycles(m, batch, k, n, nbw, wb, ab,
                                          16, flat)
                  for nbw in (1, 2, 3, 4)}
        assert cycles[pick] == min(cycles.values()), (k, n, wb, ab, batch)


def test_mixed_decode_cycles_unit_formats_consistent():
    m = cm.SailMachine()
    legacy3 = [(1024, 1024, 4)]
    legacy4 = [(1024, 1024, 4, 2)]
    with_ab = [(1024, 1024, 4, 8, 2)]
    assert cm.mixed_decode_cycles(legacy4, m) == pytest.approx(
        2 * cm.mixed_decode_cycles(legacy3, m))
    assert cm.mixed_decode_cycles(with_ab, m) == pytest.approx(
        cm.mixed_decode_cycles(legacy4, m))   # abits=8 == default pricing
    none_ab = [(1024, 1024, 4, None, 2)]
    assert cm.mixed_decode_cycles(none_ab, m) == pytest.approx(
        cm.mixed_decode_cycles(legacy4, m))


def test_mixed_decode_cycles_monotone_in_abits():
    m = cm.SailMachine()
    cycles = [cm.mixed_decode_cycles([(1024, 1024, 4, ab, 1)], m)
              for ab in (4, 6, 8)]
    assert cycles[0] < cycles[1] < cycles[2]


def test_mixed_decode_cycles_measured_prt_differs_from_paper():
    units = [(512, 512, 4, 8, 1)]
    paper = cm.mixed_decode_cycles(units, prt="paper")
    measured = cm.mixed_decode_cycles(units, prt="measured")
    off = cm.mixed_decode_cycles(units, prt=False)
    assert paper < off
    assert measured != paper
    assert measured < off       # synthetic batches still repeat patterns
    auto = cm.mixed_decode_cycles(units, nbw="auto", prt="measured")
    assert auto <= measured * (1 + 1e-9)
