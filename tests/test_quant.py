"""Quantization: packing exactness, roundtrip error bounds, properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import quant


@pytest.mark.parametrize("bits", quant.SUPPORTED_BITS)
def test_pack_unpack_exact(bits):
    rng = np.random.default_rng(bits)
    codes = rng.integers(0, 1 << bits, size=(96, 7)).astype(np.uint32)
    packed = quant.pack_bits(jnp.asarray(codes), bits)
    out = quant.unpack_bits(packed, bits, k=96)
    assert (np.asarray(out) == codes).all()


@pytest.mark.parametrize("bits", quant.KERNEL_BITS)
@pytest.mark.parametrize("group", [32, 64])
def test_pack_grouped_exact(bits, group):
    rng = np.random.default_rng(bits * 100 + group)
    k = group * 3
    codes = rng.integers(0, 1 << bits, size=(k, 5)).astype(np.uint32)
    packed = quant.pack_grouped(jnp.asarray(codes), bits, group)
    out = quant.unpack_grouped(packed, bits, group, k)
    assert (np.asarray(out) == codes).all()


@pytest.mark.parametrize("bits", quant.SUPPORTED_BITS)
def test_quantize_roundtrip_error(bits):
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 32))
    qt = quant.quantize(w, bits, group_size=64)
    wd = quant.dequantize(qt)
    # worst-case uniform quantization error: half a step per group
    step = 2.0 / max((1 << (bits - 1)) - 1, 1)
    groups = np.asarray(w).reshape(4, 64, 32)
    absmax = np.abs(groups).max(axis=1, keepdims=True)
    bound = (step / 2) * absmax + 1e-6
    err = np.abs(np.asarray(w) - np.asarray(wd)).reshape(4, 64, 32)
    assert (err <= bound + 1e-5).all()


def test_quantize_int_matches_scale():
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
    wq, scales = quant.quantize_int(w, 4, 64)
    wd = (np.asarray(wq).reshape(2, 64, 16) *
          np.asarray(scales)[:, None, :]).reshape(128, 16)
    # half-step bound: absmax/(2*qmax) with absmax ~ 3.5 for N(0,1)@128
    assert np.abs(wd - np.asarray(w)).max() < 0.35


def test_kv_quant_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4, 16))
    codes, scale = quant.quantize_kv(x)
    xd = quant.dequantize_kv(codes, scale)
    assert codes.dtype == jnp.int8
    assert float(jnp.abs(xd - x).max()) < float(jnp.abs(x).max()) / 64


@pytest.mark.parametrize("group", [32, 64, 128, 256])
def test_packed_bytes_strictly_monotone_in_bits(group):
    """Regression: the value-aligned layout collapsed 3->4 and 5->6 bits
    to identical group sizes at group_size=32; the bit-contiguous layout
    must pay for every bit at every supported group size."""
    from repro.core.cost_model import qtensor_bytes
    k, n = group * 4, 16
    words = [quant.words_per_group(b, group) for b in quant.SUPPORTED_BITS]
    assert words == sorted(set(words)), (group, words)
    sizes = [qtensor_bytes(k, n, b, group) for b in quant.SUPPORTED_BITS]
    assert all(a < b for a, b in zip(sizes, sizes[1:])), (group, sizes)
    # the packed arrays themselves ladder identically
    w = jax.random.normal(jax.random.PRNGKey(group), (k, n))
    packed = [quant.quantize(w, b, group).packed.size
              for b in quant.SUPPORTED_BITS]
    assert all(a < b for a, b in zip(packed, packed[1:])), (group, packed)


def test_words_per_group_is_bit_exact_capacity():
    for b in quant.KERNEL_BITS:
        for g in (32, 64, 128, 256):
            assert quant.words_per_group(b, g) == -(-(b * g) // 32)


def test_one_bit_sign_quantize():
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 8))
    qt = quant.quantize(w, 1, group_size=32)
    wd = np.asarray(quant.dequantize(qt))
    ww = np.asarray(w)
    # sign codebook [-1, 1]: reconstruction is sign(w) * group absmax
    absmax = np.abs(ww).reshape(2, 32, 8).max(axis=1, keepdims=True)
    want = (np.sign(ww).reshape(2, 32, 8) * absmax).reshape(64, 8)
    mask = np.abs(ww) > 1e-6  # ties at 0 may round either way
    np.testing.assert_allclose(wd[mask.reshape(64, 8)],
                               want[mask.reshape(64, 8)], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from(quant.SUPPORTED_BITS),
       k=st.integers(1, 4), n=st.integers(1, 17), seed=st.integers(0, 99))
def test_property_grouped_pack_roundtrip(bits, k, n, seed):
    rng = np.random.default_rng(seed)
    kk = 32 * k
    codes = rng.integers(0, 1 << bits, size=(kk, n)).astype(np.uint32)
    packed = quant.pack_grouped(jnp.asarray(codes), bits, 32)
    out = quant.unpack_grouped(packed, bits, 32, kk)
    assert (np.asarray(out) == codes).all()


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 99))
def test_property_dequant_monotone_in_bits(bits, seed):
    """More bits never increases reconstruction error materially."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 8))
    err = {}
    for b in (bits, 8):
        qt = quant.quantize(w, b, group_size=32)
        err[b] = float(jnp.abs(quant.dequantize(qt) - w).max())
    assert err[8] <= err[bits] + 1e-6
