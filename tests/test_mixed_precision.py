"""Mixed-precision bit allocation: policy resolution, segmented
quantization, per-leaf kernel equivalence at each leaf's precision,
checkpoint round-trips of mixed trees, the greedy budgeted allocator, and
mixed-policy serving."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import repro.configs as C
from repro.core import sensitivity as sens
from repro.core.quant import SUPPORTED_BITS, quantize
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import (BitAllocation, QuantPolicy,
                                      QTensor, StackedQTensor,
                                      dequantize_any, quantize_params)


def tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", vocab=64, d_model=32,
                n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu",
                attn_chunk=16, max_seq=128)
    base.update(kw)
    return ModelConfig(**base)


def tiny_params(cfg=None, seed=0):
    return lm.init_params(jax.random.PRNGKey(seed), cfg or tiny_cfg())


POLICY = dict(group_size=32, min_size=1024)


def iter_qtensors(tree, prefix=""):
    """(path, QTensor|StackedQTensor) leaves of a quantized tree."""
    if isinstance(tree, (QTensor, StackedQTensor)):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from iter_qtensors(v, prefix + f"['{k}']")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_qtensors(v, prefix + f"[{i}]")


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------

def test_bits_for_precedence():
    alloc = BitAllocation(per_path={"['x']": 6, "['y']": (2, 8)})
    pol = QuantPolicy(bits=4, rules=(("x", 3),), allocation=alloc)
    assert pol.bits_for("['x']") == 3          # rules beat allocation
    assert pol.bits_for("['y']") == (2, 8)     # allocation beats default
    assert pol.bits_for("['z']") == 4          # fallback


def test_policy_spec_roundtrip():
    alloc = BitAllocation(per_path={"['a']": 5, "['b']": (2, 3, 4)})
    pol = QuantPolicy(bits=6, group_size=64, min_size=2048,
                      rules=(("mlp", 3),), allocation=alloc)
    spec = pol.to_spec()
    back = QuantPolicy.from_spec(spec)
    assert back == pol
    # specs are msgpack/JSON-plain
    import json
    json.dumps(spec)


def test_parse_bit_policy_grammar():
    assert sens.parse_bit_policy("uniform:6") == {"mode": "uniform",
                                                  "bits": 6}
    r = sens.parse_bit_policy("rules:attn=5,mlp=3,default=4")
    assert r["mode"] == "rules" and r["bits"] == 4
    assert ("attn", 5) in r["rules"] and ("mlp", 3) in r["rules"]
    assert sens.parse_bit_policy("auto:q4") == {"mode": "auto",
                                                "match_uniform": 4}
    assert sens.parse_bit_policy("auto:4.5bpw") == {"mode": "auto",
                                                    "budget_bpw": 4.5}
    with pytest.raises(ValueError):
        sens.parse_bit_policy("nope:1")


def test_unsupported_bits_rejected():
    pol = QuantPolicy(bits=4, rules=(("wq", 7),), **POLICY)
    with pytest.raises(ValueError):
        quantize_params(tiny_params(), pol)


# ---------------------------------------------------------------------------
# mixed quantize_params
# ---------------------------------------------------------------------------

def test_mixed_leaf_bits_and_bytes():
    params = tiny_params()
    pol = QuantPolicy(bits=4, rules=(("mlp", 2), ("wo", 8)), **POLICY)
    qtree, b0, b1 = quantize_params(params, pol)
    bits = {path: qt.bits for path, qt in iter_qtensors(qtree)}
    assert bits["['blocks']['mlp']['w_down']"] == 2
    assert bits["['blocks']['attn']['wo']"] == 8
    assert bits["['blocks']['attn']['wq']"] == 4
    _, _, uniform4 = quantize_params(params, QuantPolicy(bits=4, **POLICY))
    _, _, uniform2 = quantize_params(params, QuantPolicy(bits=2, **POLICY))
    assert uniform2 < b1 < uniform4 + (1 << 8) * 4


def test_per_layer_allocation_segments_blocks():
    params = tiny_params()
    alloc = BitAllocation(per_path={"['blocks']['attn']['wq']": (8, 2)})
    qtree, _, _ = quantize_params(
        params, QuantPolicy(bits=4, allocation=alloc, **POLICY))
    assert isinstance(qtree["blocks"], list) and len(qtree["blocks"]) == 2
    assert qtree["blocks"][0]["attn"]["wq"].bits == 8
    assert qtree["blocks"][1]["attn"]["wq"].bits == 2
    assert qtree["blocks"][0]["mlp"]["w_up"].bits == 4
    # each segment slice dequantizes to the per-slice quantization of the
    # original weight at that slice's bits
    w = params["blocks"]["attn"]["wq"]
    for seg, layer, bits in ((0, 0, 8), (1, 1, 2)):
        got = dequantize_any(qtree["blocks"][seg]["attn"]["wq"])[0]
        want = sens.fake_quant(w[layer], bits, 32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_uniform_tuple_does_not_segment():
    params = tiny_params()
    alloc = BitAllocation(per_path={"['blocks']['attn']['wq']": (6, 6)})
    qtree, _, _ = quantize_params(
        params, QuantPolicy(bits=4, allocation=alloc, **POLICY))
    assert isinstance(qtree["blocks"], dict)
    assert qtree["blocks"]["attn"]["wq"].bits == 6


def test_segmented_model_matches_dequantized_oracle():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    alloc = BitAllocation(per_path={
        "['blocks']['attn']['wq']": (8, 4),
        "['blocks']['mlp']['w_down']": (4, 8),
    })
    qtree, _, _ = quantize_params(
        params, QuantPolicy(bits=4, allocation=alloc, **POLICY))
    assert isinstance(qtree["blocks"], list)
    # oracle: same tree with every QTensor dequantized back to f32 arrays,
    # segments re-stacked into one scan
    deq_segs = [jax.tree_util.tree_map(
        dequantize_any, seg,
        is_leaf=lambda x: isinstance(x, (QTensor, StackedQTensor)))
        for seg in qtree["blocks"]]
    oracle = {k: v for k, v in qtree.items() if k != "blocks"}
    oracle = jax.tree_util.tree_map(
        dequantize_any, oracle,
        is_leaf=lambda x: isinstance(x, (QTensor, StackedQTensor)))
    oracle["blocks"] = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, 0), *deq_segs)

    toks = jnp.asarray([[1, 2, 3, 4]])
    lq, cq = lm.prefill(qtree, toks, cfg, cache_len=16)
    lo, co = lm.prefill(oracle, toks, cfg, cache_len=16)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lo), rtol=1e-5,
                               atol=1e-5)
    tok = jnp.argmax(lq, axis=-1)[:, None]
    for _ in range(3):
        lq, cq = lm.decode_step(qtree, tok, cq, cfg)
        lo, co = lm.decode_step(oracle, tok, co, cfg)
        np.testing.assert_allclose(np.asarray(lq), np.asarray(lo),
                                   rtol=1e-5, atol=1e-5)
        tok = jnp.argmax(lq, axis=-1)[:, None]


# ---------------------------------------------------------------------------
# per-leaf kernel equivalence (property)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(b_mlp=st.sampled_from(SUPPORTED_BITS),
       b_attn=st.sampled_from(SUPPORTED_BITS), seed=st.integers(0, 99))
def test_property_mixed_leaves_match_uniform_reference(b_mlp, b_attn, seed):
    """Every leaf of a mixed tree must equal the uniform-quantized tensor
    at that leaf's precision, and ``lut_matmul`` on it must match the
    pure-jnp reference at that precision (kernel dispatch is per-tensor,
    so mixing cannot change any single matmul's numerics)."""
    from repro.kernels.lut_gemv.ops import lut_matmul
    from repro.kernels.lut_gemv.ref import lut_matmul_ref
    params = tiny_params(seed=seed)
    pol = QuantPolicy(bits=4, rules=(("mlp", b_mlp), ("attn", b_attn)),
                      **POLICY)
    qtree, _, _ = quantize_params(params, pol)
    raw = {p: w for p, w in
           ((jax.tree_util.keystr(path), w) for path, w in
            jax.tree_util.tree_flatten_with_path(params)[0])}
    rng = np.random.default_rng(seed)
    for path, qt in iter_qtensors(qtree):
        w = raw[path]
        expect_bits = b_mlp if "mlp" in path else (
            b_attn if "attn" in path else 4)
        assert qt.bits == expect_bits, path
        if isinstance(qt, StackedQTensor):
            qt = qt[0]
            w = w[0]
        ref_qt = quantize(w, expect_bits, 32)
        np.testing.assert_array_equal(np.asarray(qt.packed),
                                      np.asarray(ref_qt.packed))
        x = jnp.asarray(rng.standard_normal((3, qt.k)), jnp.float32)
        y_kernel = lut_matmul(x, qt, backend="pallas")
        y_ref = lut_matmul_ref(x, ref_qt)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint round-trip (property)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(b_a=st.sampled_from(SUPPORTED_BITS),
       b_b=st.sampled_from(SUPPORTED_BITS),
       b_l0=st.sampled_from([2, 4, 8]), b_l1=st.sampled_from([2, 4, 8]))
def test_property_checkpoint_roundtrip_mixed(b_a, b_b, b_l0, b_l1):
    """A mixed-bits tree (incl. per-layer segmentation) must round-trip
    through save/load bit-exactly, both against its own template and
    rebuilt from nothing but the raw params via the stored policy spec."""
    from repro.checkpoint import restore, restore_quantized, save_quantized
    params = tiny_params()
    alloc = BitAllocation(per_path={
        "['blocks']['attn']['wq']": (b_l0, b_l1),
        "['blocks']['mlp']['w_up']": b_a,
        "['lm_head']": b_b,
    })
    pol = QuantPolicy(bits=4, allocation=alloc, **POLICY)
    qtree, _, _ = quantize_params(params, pol)
    with tempfile.TemporaryDirectory() as d:
        save_quantized(d, 1, qtree, pol)
        back, _ = restore(d, qtree)
        flat_a = jax.tree_util.tree_leaves(qtree)
        flat_b = jax.tree_util.tree_leaves(back)
        assert len(flat_a) == len(flat_b)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # template-free restore: quantized structure (statics, segments)
        # reconstructed from the manifest's policy spec
        back2, _ = restore_quantized(d, params)
        bits_orig = {p: q.bits for p, q in iter_qtensors(qtree)}
        bits_back = {p: q.bits for p, q in iter_qtensors(back2)}
        assert bits_orig == bits_back
        for a, b in zip(flat_a, jax.tree_util.tree_leaves(back2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def make_units(n=6, k=64, seed=0):
    rng = np.random.default_rng(seed)
    units = []
    for i in range(n):
        scale = float(rng.uniform(0.1, 10.0))
        errors = {b: scale * 4.0 ** (-b) for b in SUPPORTED_BITS}
        units.append(sens.Unit(path=f"['w{i}']", layer=None, k=k, n=k,
                               copies=1, errors=errors))
    return units


def test_allocator_respects_budget_and_dominates_uniform():
    units = make_units()
    g = 32
    for b in (3, 4, 5):
        budget = sum(sens.unit_bytes(u.k, u.n, b, g, u.copies)
                     for u in units)
        rep = sens.allocate_bits(units, budget, g)
        assert rep.feasible and rep.bytes_total <= budget
        uniform_err = sum(u.errors[b] for u in units)
        assert rep.predicted_error <= uniform_err + 1e-12


def test_allocator_monotone_in_budget():
    units = make_units(seed=1)
    g = 32
    budgets = [sum(sens.unit_bytes(u.k, u.n, b, g, u.copies)
                   for u in units) for b in (2, 3, 4, 6, 8)]
    errs = [sens.allocate_bits(units, bb, g).predicted_error
            for bb in budgets]
    assert all(e2 <= e1 + 1e-12 for e1, e2 in zip(errs, errs[1:]))


def test_allocator_pins_rule_matched_units():
    units = make_units()
    g = 32
    budget = sum(sens.unit_bytes(u.k, u.n, 4, g, u.copies) for u in units)
    rep = sens.allocate_bits(units, budget, g,
                             pinned={("['w0']", None): 8})
    assert rep.bits_by_unit[("['w0']", None)] == 8


def test_allocator_infeasible_budget_reports():
    units = make_units(n=2)
    rep = sens.allocate_bits(units, budget_bytes=8, group_size=32)
    assert not rep.feasible
    assert all(b == min(SUPPORTED_BITS) for b in rep.bits_by_unit.values())


# ---------------------------------------------------------------------------
# sensitivity scoring
# ---------------------------------------------------------------------------

def test_output_sensitivity_decreases_with_bits():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    pol = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    scores = sens.output_sensitivity(params, cfg, toks, pol,
                                     bits_candidates=(2, 4, 8))
    assert scores, "no quantizable units found"
    for key, errs in scores.items():
        assert errs[8] <= errs[2] + 1e-9, key
    # per-layer granularity over the stacked blocks
    layers = {k[1] for k in scores if k[0].startswith("['blocks']")}
    assert layers == {0, 1}


def test_weight_sensitivity_proxy_decreases_with_bits():
    params = tiny_params()
    pol = QuantPolicy(bits=4, **POLICY)
    scores = sens.weight_sensitivity(params, pol, bits_candidates=(2, 4, 8))
    for key, errs in scores.items():
        assert errs[8] <= errs[4] <= errs[2] + 1e-9, key


def test_calibrate_policy_matches_budget():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    pol, rep = sens.calibrate_policy(params, cfg, base, match_uniform=4,
                                     tokens=toks,
                                     bits_candidates=(2, 3, 4, 6))
    assert rep.feasible
    assert rep.bytes_total <= rep.budget_bytes
    assert pol.allocation is not None
    # the allocated policy must actually quantize (and possibly segment)
    qtree, _, _ = quantize_params(params, pol)
    assert dict(iter_qtensors(qtree))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_engine_mixed_bit_policy_token_identical_to_f32():
    """A high-precision mixed allocation (per-layer 6/8 bits -> segmented
    serving path) must produce token-identical greedy output to the
    unquantized model on short smoke prompts."""
    from repro.serving.engine import Engine, EngineConfig
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]

    def run(ecfg):
        eng = Engine(params, cfg, ecfg)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        return {c.uid: c.tokens for c in eng.run()}, eng

    ref, _ = run(EngineConfig(batch_size=4, cache_len=64, quantize=False,
                              quant_kv=False))
    alloc = BitAllocation(
        per_path={"['blocks']['mlp']['w_down']": (6, 8)})
    pol = QuantPolicy(bits=8, group_size=32, min_size=1024,
                      allocation=alloc)
    mixed, eng = run(EngineConfig(batch_size=4, cache_len=64, quantize=True,
                                  ql=8, group_size=32, quant_kv=False,
                                  bit_policy=pol))
    assert isinstance(eng.params["blocks"], list), \
        "per-layer allocation must serve through the segmented path"
    assert eng.stats()["mixed_precision"]
    assert mixed == ref


def test_engine_auto_bit_policy_smoke():
    """auto:q4 runs the sensitivity calibration inside the engine and
    serves with a budget-respecting mixed allocation."""
    from repro.serving.engine import Engine, EngineConfig
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        quant_kv=True, bit_policy="auto:q4"))
    assert eng.quant_policy.allocation is not None
    eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 4
    # allocation bytes within the uniform-4 budget
    budget = sens.uniform_bytes(params, eng.quant_policy, 4)
    used = 0
    for pstr, w, stacked in sens.quantizable_units(params,
                                                   eng.quant_policy):
        spec = eng.quant_policy.bits_for(pstr)
        k, n = int(w.shape[-2]), int(w.shape[-1])
        copies = 1
        for d in w.shape[:-2]:
            copies *= int(d)
        if isinstance(spec, (tuple, list)):
            per = copies // len(spec)
            used += sum(sens.unit_bytes(k, n, int(b), 32, per)
                        for b in spec)
        else:
            used += sens.unit_bytes(k, n, int(spec), 32, copies)
    assert used <= budget


def test_engine_rules_bit_policy_string():
    from repro.serving.engine import Engine, EngineConfig
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        bit_policy="rules:mlp=2,default=6"))
    bits = {p: q.bits for p, q in iter_qtensors(eng.params)}
    assert bits["['blocks']['mlp']['w_up']"] == 2
    assert bits["['blocks']['attn']['wq']"] == 6
    eng.submit([3, 2, 1], max_new_tokens=3)
    assert len(eng.run()) == 1
