"""Continuous-batching engine: equivalence with run-to-completion serving,
iteration-granular backfill on staggered arrivals, slot reuse, streaming,
and the decode-phase stats the benchmarks report."""
import jax
import pytest

import repro.configs as C
from repro.models import lm
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine(tiny, mode, batch=4, **kw):
    cfg, params = tiny
    return Engine(params, cfg, EngineConfig(
        batch_size=batch, cache_len=64, quantize=True, ql=4,
        group_size=32, quant_kv=True, mode=mode, **kw))


PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [3, 1, 4, 1, 5], [2, 7, 1]]


def test_continuous_matches_run_to_completion(tiny):
    """Greedy outputs must be token-identical across scheduling modes:
    the slot pool + masked decode change WHEN work runs, never WHAT is
    computed."""
    outs = {}
    for mode in ("continuous", "batch"):
        eng = make_engine(tiny, mode)
        for p in PROMPTS:
            eng.submit(p, max_new_tokens=6)
        done = eng.run()
        outs[mode] = {c.uid: c.tokens for c in done}
        assert all(len(t) == 6 for t in outs[mode].values())
    assert outs["continuous"] == outs["batch"]


def test_staggered_arrival_backfills_mid_decode(tiny):
    """A request arriving mid-decode must join the running batch at the
    next iteration (not wait for the cohort), and the whole workload must
    take strictly fewer model iterations than run-to-completion."""
    max_new = 24
    cohort = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    late = [7, 7, 7]

    eng = make_engine(tiny, "continuous")
    uids = [eng.submit(p, max_new) for p in cohort]
    for _ in range(4):
        assert eng.step()
    late_uid = eng.submit(late, max_new)
    eng.run()
    ev = eng.events
    cohort_finish = max(ev[u]["finished_iteration"] for u in uids)
    assert ev[late_uid]["first_decode_iteration"] < cohort_finish, \
        "late request must start decoding before the first cohort finishes"

    # same arrival pattern, run-to-completion: late waits for the cohort
    eng2 = make_engine(tiny, "batch")
    for p in cohort:
        eng2.submit(p, max_new)
    eng2.step()                     # serves the whole cohort to the end
    eng2.submit(late, max_new)
    eng2.run()
    assert eng.iterations < eng2.iterations
    # both served the same tokens
    assert (eng.stats()["generated_tokens"]
            == eng2.stats()["generated_tokens"] == 4 * max_new)


def test_more_requests_than_slots_reuses_slots(tiny):
    """7 requests through a 2-slot pool: every slot is recycled and every
    request completes with the full token budget."""
    eng = make_engine(tiny, "continuous", batch=2)
    for i in range(7):
        eng.submit([i + 1, 2, 3], max_new_tokens=3)
    done = eng.run()
    assert len(done) == 7
    assert all(len(c.tokens) == 3 for c in done)
    assert eng.sched.free_slots == [0, 1]          # pool fully drained


def test_streaming_callback_order(tiny):
    """on_token streams each request's tokens in generation order."""
    eng = make_engine(tiny, "continuous")
    streamed = {}
    cb = lambda uid, tok: streamed.setdefault(uid, []).append(tok)
    uids = [eng.submit(p, 5, on_token=cb) for p in PROMPTS[:3]]
    done = {c.uid: c.tokens for c in eng.run()}
    assert set(streamed) == set(uids)
    for uid in uids:
        assert streamed[uid] == done[uid]


def test_eos_retires_slot_early(tiny):
    """A request hitting EOS frees its slot before max_new_tokens."""
    cfg, params = tiny
    eng = make_engine(tiny, "continuous", batch=2)
    # first learn what the model emits, then use that token as EOS
    probe = make_engine(tiny, "continuous", batch=2)
    probe.submit([1, 2, 3], 4)
    first = probe.run()[0].tokens[0]
    eng.ecfg.eos_token = first
    uid = eng.submit([1, 2, 3], max_new_tokens=64)
    done = eng.run()
    assert len(done) == 1
    assert done[0].tokens[-1] == first
    assert len(done[0].tokens) < 64


def test_stats_decode_phase_breakdown(tiny):
    """stats() must separate prefill from decode so benchmarks can report
    paper-relevant decode-phase throughput, plus per-request TTFT."""
    eng = make_engine(tiny, "continuous")
    for p in PROMPTS[:4]:
        eng.submit(p, max_new_tokens=5)
    done = eng.run()
    st = eng.stats()
    assert st["prefill_tokens"] == sum(len(p) for p in PROMPTS[:4])
    # the simultaneous burst pads to one bucket -> ONE batched prefill
    # pass (weights streamed once for all four admissions)
    assert st["prefill_iterations"] == 1
    assert st["decode_iterations"] > 0
    assert st["iterations"] == (st["prefill_iterations"]
                                + st["decode_iterations"])
    assert st["generated_tokens"] == 4 * 5
    assert st["mean_ttft_s"] > 0.0
    assert all(0.0 < c.ttft_s <= c.latency_s for c in done)


def test_prefill_budget_staggers_admission(tiny):
    """With a tight prefill budget, a burst of prompts is admitted across
    several iterations instead of all at once."""
    eng = make_engine(tiny, "continuous", prefill_budget=4)
    for p in PROMPTS[:4]:                      # prompt lens 3, 4, 2, 5
        eng.submit(p, max_new_tokens=3)
    eng.step()
    first_admitted = eng.prefill_iterations
    assert first_admitted < 4                  # budget split the burst
    done = eng.run()
    assert len(done) == 4                      # but everyone finishes


def test_recurrent_family_slot_serving():
    """ssm-family prefill is exact-length (bucket padding would fold pad
    tokens into the recurrent state): equal-length prompts must match
    run-to-completion exactly, ragged prompts must still complete."""
    cfg = C.get_smoke("xlstm_350m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    mk = lambda mode: Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=False, quant_kv=False,
        mode=mode))
    outs = {}
    for mode in ("continuous", "batch"):
        eng = mk(mode)
        for p in ([1, 2, 3], [4, 5, 6], [7, 8, 9]):
            eng.submit(p, max_new_tokens=3)
        outs[mode] = {c.uid: c.tokens for c in eng.run()}
    assert outs["continuous"] == outs["batch"]
    eng = mk("continuous")
    for p in ([1, 2], [3, 4, 5, 6], [7]):
        eng.submit(p, max_new_tokens=3)
    done = eng.run()
    assert len(done) == 3 and all(len(c.tokens) == 3 for c in done)


def test_zero_max_new_tokens_matches_batch_mode(tiny):
    """max_new_tokens=0 must yield an empty completion in both modes."""
    for mode in ("continuous", "batch"):
        eng = make_engine(tiny, mode, batch=2)
        uid = eng.submit([1, 2, 3], max_new_tokens=0)
        uid2 = eng.submit([4, 5], max_new_tokens=3)
        done = {c.uid: c.tokens for c in eng.run()}
        assert done[uid] == [], mode
        assert len(done[uid2]) == 3, mode
