"""Pattern Reuse Table simulation invariants and the measured
per-precision cycle discount that replaces the paper's flat 13.8%."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import pattern


def _patterns(b, abits, g, nbw, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << nbw, size=(b, abits, g)).astype(np.int64)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 8), g=st.integers(1, 12),
       nbw=st.sampled_from([1, 2, 3, 4]), abits=st.sampled_from([4, 6, 8]),
       seed=st.integers(0, 999))
def test_property_prt_capacity_invariants(b, g, nbw, abits, seed):
    """An unbounded PRT hits every repeat (hits == accesses - unique);
    any finite table hits at most that; once the table holds every
    unique key, capacity stops mattering."""
    pats = _patterns(b, abits, g, nbw, seed)
    unbounded = pattern.prt_simulate(pats, entries=b * abits * g + 1)
    assert unbounded.hits == unbounded.accesses - unbounded.unique_patterns
    for entries in (2, 8, 32):
        s = pattern.prt_simulate(pats, entries=entries)
        assert s.accesses == unbounded.accesses
        assert s.unique_patterns == unbounded.unique_patterns
        assert s.hits <= unbounded.hits
    full = pattern.prt_simulate(pats, entries=unbounded.unique_patterns)
    assert full.hits == unbounded.hits


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 6), g=st.integers(1, 10),
       nbw=st.sampled_from([2, 3, 4]), seed=st.integers(0, 999))
def test_property_prt_entries_monotone(b, g, nbw, seed):
    """Misses are monotone non-increasing in table size on these streams:
    the batch dimension is innermost, so each (bit-plane, group) column's
    working set is at most ``b`` keys and growing the FIFO can only keep
    keys resident longer."""
    pats = _patterns(b, 8, g, nbw, seed)
    hits = [pattern.prt_simulate(pats, entries=e).hits
            for e in (1, 2, 4, 8, 16, 32, 64)]
    assert all(h2 >= h1 for h1, h2 in zip(hits, hits[1:])), hits


@settings(max_examples=8, deadline=None)
@given(b=st.integers(2, 6), g=st.integers(1, 8),
       nbw=st.sampled_from([1, 2, 3, 4]), seed=st.integers(0, 999))
def test_property_duplicated_batch_hits_more(b, g, nbw, seed):
    """A batch containing every request twice must hit at least as often
    as the unique batch — cross-user pattern reuse is exactly what the
    PRT exists for (paper Sec. III-D)."""
    pats = _patterns(b, 8, g, nbw, seed)
    dup = np.concatenate([pats, pats], axis=0)
    rate = pattern.prt_simulate(pats).hit_rate
    rate_dup = pattern.prt_simulate(dup).hit_rate
    assert rate_dup >= rate - 1e-12


def test_prt_hit_rate_narrow_patterns_repeat_more():
    """2^nbw possible patterns: NBW=1 streams from a 2-entry alphabet and
    must hit far more often than NBW=4 — the per-precision effect the
    flat paper constant cannot express."""
    calib = pattern.synthetic_activations(512, batch=8)
    r1 = pattern.prt_hit_rate(1, 8, calib)
    r4 = pattern.prt_hit_rate(4, 8, calib)
    assert r1 > r4 + 0.1
    d1 = pattern.prt_discount(1, 8, 4, calib)
    d4 = pattern.prt_discount(4, 8, 4, calib)
    assert d1 < d4 <= 1.0


def test_prt_discount_scales_with_ql():
    """A hit skips a fixed amount of C-SRAM work, so cheaper (narrow-ql)
    lookups see a larger fractional discount."""
    calib = pattern.synthetic_activations(512, batch=8)
    d2 = pattern.prt_discount(4, 8, 2, calib)
    d8 = pattern.prt_discount(4, 8, 8, calib)
    assert d2 < d8 < 1.0


def test_prt_discount_anchored_at_paper_point():
    """At the paper's anchor (ql=4) a 17% hit rate must reproduce the
    published 13.8% cycle reduction exactly."""
    m = cm.SailMachine()
    saved = (pattern.PAPER_CYCLE_REDUCTION / pattern.PAPER_REPEAT_RATE) * \
        cm.lookup_cycles(m, pattern.PAPER_ANCHOR_QL)
    got = 1.0 - pattern.PAPER_REPEAT_RATE * saved / cm.lookup_cycles(m, 4)
    assert got == pytest.approx(1.0 - pattern.PAPER_CYCLE_REDUCTION)


def test_prt_hit_rate_cached_and_validated():
    calib = pattern.synthetic_activations(256, batch=4)
    a = pattern.prt_hit_rate(2, 6, calib)
    b = pattern.prt_hit_rate(2, 6, calib)
    assert a == b
    with pytest.raises(ValueError):
        pattern.prt_hit_rate(2, 6, np.zeros((2, 3, 4), np.float32))


def test_resolve_prt_discount_switch():
    assert cm.resolve_prt_discount(False, 4, 4, 8) == 1.0
    assert cm.resolve_prt_discount(None, 4, 4, 8) == 1.0
    flat = 1.0 - pattern.PAPER_CYCLE_REDUCTION
    assert cm.resolve_prt_discount(True, 4, 4, 8) == pytest.approx(flat)
    assert cm.resolve_prt_discount("paper", 4, 4, 8) == pytest.approx(flat)
    calib = pattern.synthetic_activations(256, batch=4)
    d = cm.resolve_prt_discount("measured", 2, 4, 8, calib)
    assert 0.0 <= d < 1.0 and abs(d - flat) > 1e-4
    with pytest.raises(ValueError):
        cm.resolve_prt_discount("bogus", 4, 4, 8)
