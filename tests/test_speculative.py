"""Self-speculative decoding: the draft= plan axis (grammar, JSON,
validation), round pricing, the accept/rollback invariants that keep
greedy output token-identical across ring and paged pools, the planner's
draft="auto" grid solve, the controller's round-aware SLO budget, and
the engine's gating/capacity guards."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.configs as C
from repro import planning
from repro.core import cost_model as cm
from repro.models import lm
from repro.models.sail_linear import QuantPolicy
from repro.planning import (DecodeCostModel, DraftSpec, PlanSpec, Planner,
                            Slo, expected_tokens_per_round, policy_units,
                            speculative_round_seconds)
from repro.serving.control import ControllerConfig, SloController
from repro.serving.engine import Engine, EngineConfig
from repro.serving.speculative import (SpeculativeDecoder, draft_policy,
                                       measure_acceptance)


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [3, 1, 4, 1, 5]]

# min_size=1024 so the smoke model's tensors actually quantize — at the
# planner default (65536) every smoke tensor stays f32 and the draft
# tree would be bit-identical to the conservative one, voiding the test
BASE = QuantPolicy(bits=8, group_size=32, min_size=1024, act_bits=8)


def make_engine(tiny, plan, batch=4, **kw):
    cfg, params = tiny
    return Engine(params, cfg, EngineConfig(
        batch_size=batch, cache_len=64, quantize=True, group_size=32,
        min_size=1024, quant_kv=False, mode="continuous", plan=plan, **kw))


def run_all(eng, max_new=8, prompts=PROMPTS):
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    return {c.uid: c.tokens for c in eng.run()}


# --- DraftSpec: grammar, JSON, validation -----------------------------------


def test_draft_grammar_round_trip():
    p = PlanSpec.parse("uniform:8a8,draft=q4a8:k3")
    assert p.draft == DraftSpec(weight_bits=4, act_bits=8, k=3)
    assert p.solved
    assert PlanSpec.parse(p.format()) == p
    assert PlanSpec.from_json(p.to_json()) == p
    # weight-only draft token
    q = PlanSpec.parse("uniform:8,draft=q2:k4")
    assert q.draft == DraftSpec(weight_bits=2, act_bits=None, k=4)
    assert PlanSpec.parse(q.format()) == q


def test_draft_auto_keeps_plan_unsolved():
    p = PlanSpec.parse("uniform:8a8,draft=auto")
    assert p.draft == "auto"
    assert not p.solved
    assert PlanSpec.parse(p.format()) == p
    assert PlanSpec.from_json(p.to_json()) == p


def test_draft_json_carries_acceptance_grammar_drops_it():
    """The measured acceptance is probe provenance: durable in the JSON
    artifact, absent from the compact grammar form."""
    d = DraftSpec(weight_bits=4, act_bits=8, k=3, acceptance=0.83)
    assert DraftSpec.from_json(d.to_json()) == d
    assert d.format() == "q4a8:k3"
    assert DraftSpec.parse(d.format()).acceptance is None


def test_draftless_plan_hash_unchanged():
    """Adding the draft axis must not move pre-draft plan hashes: the
    key is omitted when unset."""
    p = PlanSpec.parse("uniform:8a8")
    assert "draft" not in p.to_json()
    assert p.spec_hash == dataclasses.replace(p, draft=None).spec_hash


@pytest.mark.parametrize("bad", [
    dict(weight_bits=7),
    dict(weight_bits=4, k=0),
    dict(weight_bits=4, acceptance=1.5),
    dict(weight_bits=4, act_bits=3),
])
def test_draft_validation_rejects(bad):
    with pytest.raises(ValueError):
        DraftSpec(**bad)


def test_draft_grammar_rejects_malformed():
    with pytest.raises(ValueError):
        DraftSpec.parse("q4k3")
    with pytest.raises(ValueError):
        DraftSpec.parse("qa8:k3")      # must pin weight bits


# --- round pricing ----------------------------------------------------------


def test_expected_tokens_per_round_bounds():
    for k in (1, 3, 8):
        assert expected_tokens_per_round(0.0, k) == pytest.approx(1.0)
        assert expected_tokens_per_round(1.0, k) == pytest.approx(k + 1)
    # monotone in acceptance, bounded by (1, k+1]
    k = 4
    vals = [expected_tokens_per_round(a, k) for a in (0.1, 0.4, 0.7, 0.95)]
    assert vals == sorted(vals)
    assert all(1.0 < v <= k + 1 for v in vals)


def test_speculative_round_seconds_structure(tiny):
    """A round is k draft steps plus ONE verify priced at batch*(k+1)
    rows — so round seconds grow with k, and on a DRAM-bound point the
    verify's byte stream is NOT multiplied by k+1 (weights stream once)."""
    cfg, params = tiny
    policy = BASE
    units = policy_units(params, policy)
    d_units = policy_units(
        params, draft_policy(policy, DraftSpec(weight_bits=2, act_bits=8)))
    cost = DecodeCostModel(batch=4)
    secs = [speculative_round_seconds(cost, units, d_units,
                                      policy.group_size, 0, k)
            for k in (1, 2, 4)]
    assert secs == sorted(secs) and secs[0] > 0
    # DRAM-bound machine: one round's bytes ~ k drafts + one conservative
    # stream, strictly less than k+1 conservative streams
    slow = DecodeCostModel(machine=cm.SailMachine(dram_bw=2.0e9), batch=4)
    k = 4
    round_s = speculative_round_seconds(slow, units, d_units,
                                        policy.group_size, 0, k)
    per_tok = slow.iteration_seconds(slow.cycles(units),
                                     slow.qbytes(units, policy.group_size))
    assert round_s < (k + 1) * per_tok


# --- acceptance rule (pure, no engine) --------------------------------------


def test_greedy_accept_is_exact_argmax_prefix():
    dec = object.__new__(SpeculativeDecoder)      # accept() needs no state
    v = np.zeros((2, 4, 8), np.float32)           # B=2, k=3, V=8
    # lane 0: verifier argmaxes 5,6,7 then bonus 1 — draft matches all
    for j, t in enumerate((5, 6, 7, 1)):
        v[0, j, t] = 9.0
    # lane 1: verifier argmaxes 2,3,4 then 1 — draft diverges at step 1
    for j, t in enumerate((2, 3, 4, 1)):
        v[1, j, t] = 9.0
    draft = np.array([[5, 6, 7], [2, 9, 4]])
    n_acc, nxt = SpeculativeDecoder.accept(dec, draft, v, None)
    assert n_acc.tolist() == [3, 1]
    # lane 0 gets the bonus token, lane 1 the correction at the rejection
    assert nxt.tolist() == [1, 3]


def test_stochastic_accept_full_acceptance_when_q_equals_p():
    """With draft == target distribution the p/q ratio is 1: every draft
    accepted, bonus drawn from row k."""
    dec = object.__new__(SpeculativeDecoder)
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(1, 4, 8)).astype(np.float32)
    draft = np.array([[int(np.argmax(logits[0, j])) for j in range(3)]])
    n_acc, nxt = SpeculativeDecoder.accept(
        dec, draft, logits, logits.copy(), temperature=0.7, seed=0,
        uids=np.array([5]), indices=np.array([0]))
    assert n_acc.tolist() == [3]
    assert 0 <= int(nxt[0]) < 8


# --- measured acceptance ----------------------------------------------------


def test_measure_acceptance_same_bits_is_one(tiny):
    """Identical draft and conservative quantization agree everywhere:
    the acceptance probe must read exactly 1.0 (it is the same tree)."""
    cfg, params = tiny
    a = measure_acceptance(params, cfg, BASE, draft_bits=8, act_bits=8,
                           prompt=[1, 2, 3, 5], n_tokens=8)
    assert a == 1.0


def test_measure_acceptance_lossy_in_unit_interval(tiny):
    cfg, params = tiny
    a = measure_acceptance(params, cfg, BASE, draft_bits=2, act_bits=8,
                           prompt=[1, 2, 3, 5], n_tokens=8)
    assert 0.0 <= a <= 1.0


# --- engine rounds: token identity, rollback, stats -------------------------


def test_lossy_draft_token_identical_with_rollbacks(tiny):
    """The q4 draft disagrees with the q8 verifier (acceptance < 1), so
    rounds roll back — and greedy output must STILL be token-identical
    to per-token decode under the conservative plan alone."""
    base = run_all(make_engine(tiny, "uniform:8a8"))
    eng = make_engine(tiny, "uniform:8a8,draft=q4a8:k3")
    out = run_all(eng)
    assert out == base
    st = eng.stats()["speculative"]
    assert st["rounds"] > 0
    # k drafts per ACTIVE lane per round (lanes retire as budgets finish)
    assert 0 < st["drafted"] <= st["rounds"] * eng.ecfg.batch_size * 3
    assert st["drafted"] % 3 == 0
    assert 0.0 < st["acceptance_rate"] < 1.0     # rejections happened
    # rounds commit multiple tokens: fewer iterations than tokens
    assert eng.iterations < sum(len(t) for t in out.values())


def test_same_precision_draft_accepts_everything(tiny):
    """draft bits == plan bits -> the two trees are identical, verify
    argmax == draft argmax at every position: rule-level acceptance is
    exactly 1.0 even though max_new truncates some commits."""
    base = run_all(make_engine(tiny, "uniform:8a8"))
    eng = make_engine(tiny, "uniform:8a8,draft=q8a8:k3")
    assert run_all(eng) == base
    st = eng.stats()["speculative"]
    assert st["rounds"] > 0
    assert st["acceptance_rate"] == 1.0


def test_paged_pool_round_trip_and_invariants(tiny):
    """Speculative rounds over the paged pool: rollback truncates block
    tails, output stays token-identical, and the pool drains clean."""
    base = run_all(make_engine(tiny, "uniform:8a8"))
    eng = make_engine(tiny, "uniform:8a8,draft=q4a8:k3", kv_block_size=8)
    assert run_all(eng) == base
    eng.block_mgr.check_invariants()
    bp = eng.stats()["block_pool"]
    assert bp["used_blocks"] == 0                # every table freed
    assert eng.stats()["speculative"]["rounds"] > 0


def test_stochastic_rounds_complete_and_rollback(tiny):
    """temperature > 0 exercises the p/q coin-flip path: every request
    must still complete its budget with legal tokens."""
    cfg, _ = tiny
    eng = make_engine(tiny, "uniform:8a8,draft=q4a8:k3",
                      temperature=0.8, seed=11)
    out = run_all(eng, max_new=6)
    assert all(len(t) == 6 for t in out.values())
    assert all(0 <= tok < cfg.vocab for t in out.values() for tok in t)
    assert eng.stats()["speculative"]["rounds"] > 0


# --- sampling determinism (slot vs paged, temperature > 0) ------------------


def test_sampled_tokens_invariant_to_pool_layout(tiny):
    """The (seed, uid, position)-keyed sampler must emit identical
    sequences whether KV lives in the slot pool or the paged pool — the
    pool layout changes WHERE state lives, never the key stream."""
    slot = run_all(make_engine(tiny, "uniform:8a8",
                               temperature=0.7, seed=7))
    paged = run_all(make_engine(tiny, "uniform:8a8",
                                temperature=0.7, seed=7, kv_block_size=8))
    assert slot == paged
    # and the draw is genuinely stochastic: greedy differs somewhere
    greedy = run_all(make_engine(tiny, "uniform:8a8"))
    assert slot != greedy


# --- planner: draft="auto" --------------------------------------------------


def _seeded_planner(tiny, cost=None):
    cfg, params = tiny
    pl = Planner(params, cfg, "uniform:8a8,draft=auto", base=BASE, cost=cost)
    # pre-seed the measured-acceptance cache so the grid solve runs
    # without the (slow) teacher-forced probes
    for bits, acc in ((2, 0.35), (3, 0.6), (4, 0.9)):
        pl._draft_acceptance[(bits, 8)] = acc
    return pl


def test_draft_auto_compute_bound_resolves_to_none(tiny):
    """On the compute-bound default machine verify cycles scale with
    k+1 rows — speculation cannot win, and the honest solve strips the
    draft rather than pinning a losing one."""
    res = _seeded_planner(tiny).solve()
    assert res.spec.draft is None
    assert res.spec.solved


def test_draft_auto_dram_bound_picks_measured_draft(tiny):
    """On a DRAM-bound machine the draft's byte gap pays: the grid solve
    must pin a concrete DraftSpec carrying the measured acceptance."""
    cost = DecodeCostModel(machine=cm.SailMachine(dram_bw=2.0e9), batch=1)
    pl = _seeded_planner(tiny, cost=cost)
    res = pl.solve()
    d = res.spec.draft
    assert isinstance(d, DraftSpec)
    assert res.spec.solved
    assert d.acceptance == pl._draft_acceptance[(d.weight_bits, 8)]
    # deterministic: re-solving from the same cache picks the same draft
    assert _seeded_planner(tiny, cost=cost).solve().spec.draft == d
    # the solved spec round-trips with its provenance
    assert PlanSpec.from_json(res.spec.to_json()).draft == d


# --- controller: rounds, not tokens -----------------------------------------


def test_controller_budget_scales_with_expected_tokens():
    """One speculative round commits E[accepted+1] tokens per lane, so
    the SLO's per-iteration latency budget scales by tokens_per_iter —
    an occupancy infeasible per-token can be feasible per-round."""
    iter_seconds = lambda b: 0.002 * b
    slo = Slo(1000.0, batch=8)            # 8 ms per plain iteration
    per_token = SloController(ControllerConfig(), slo=slo,
                              iter_seconds=iter_seconds)
    assert per_token.meets_slo_at(4) and not per_token.meets_slo_at(5)
    rounds = SloController(ControllerConfig(), slo=slo,
                           iter_seconds=iter_seconds, tokens_per_iter=3.0)
    assert rounds.meets_slo_at(8)
    assert rounds.batch_cap(8) == 8
    # plan_changed with a new expected-tokens updates the budget in place
    rounds.plan_changed(iter_seconds=iter_seconds, tokens_per_iter=1.0)
    assert not rounds.meets_slo_at(8)


# --- gating and capacity guards ---------------------------------------------


def test_draft_requires_continuous_mode(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="continuous"):
        Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=64, quantize=True, min_size=1024,
            mode="batch", plan="uniform:8a8,draft=q4:k2"))


def test_draft_rejects_tap_and_recurrent_family(tiny):
    with pytest.raises(ValueError, match="ActivationTap"):
        make_engine(tiny, "uniform:8a8,draft=q4:k2", tap_capacity=16)
    scfg = C.get_smoke("xlstm_350m")
    sparams = lm.init_params(jax.random.PRNGKey(0), scfg)
    with pytest.raises(ValueError, match="attention"):
        Engine(sparams, scfg, EngineConfig(
            batch_size=2, cache_len=32, quantize=True, min_size=1024,
            mode="continuous", plan="uniform:8a8,draft=q4:k2"))


def test_submit_reserves_draft_lookahead(tiny):
    """The ring must never wrap across a rollback: a request whose
    prompt + budget + k + 1 exceeds the ring is rejected up front."""
    eng = make_engine(tiny, "uniform:8a8,draft=q4a8:k3", batch=2)
    with pytest.raises(ValueError, match="ring holds"):
        eng.submit([1] * 10, max_new_tokens=60)
    # the same request fits a draft-less engine (no lookahead reserve)
    plain = make_engine(tiny, "uniform:8a8", batch=2)
    assert plain.submit([1] * 10, max_new_tokens=54) > 0
