"""Tensor-level scheduling / ping-pong pipeline planner + PRT sim."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.scheduler import (IterationScheduler, PipelineModel,
                                  Request, plan_tensor_schedule)
from repro.core import pattern


def test_tensor_schedule_alternates_buffers():
    layers = [[("w1", 100), ("w2", 50)], [("w3", 120)], [("w4", 60)]]
    sched = plan_tensor_schedule(layers, buffer_bytes=400)
    assert sched.n_phases == 3
    buffers = [sched.residency(i)[0].buffer for i in range(3)]
    assert buffers == [0, 1, 0]


def test_tensor_schedule_splits_oversized_layer():
    layers = [[("big1", 150), ("big2", 150)]]   # 300 > half (200/2=... )
    sched = plan_tensor_schedule(layers, buffer_bytes=400)
    assert sched.n_phases == 2                   # split into two tiles


def test_pipeline_bubble_free_batch():
    pm = PipelineModel(stream_bw=100.0, compute_rate=1000.0)
    # write time = b/100; compute at B: B*b/1000 -> balanced at B=10
    assert pm.bubble_free_batch(1000) == 10


def test_pipeline_optimal_batch_knee():
    # paper: throughput plateaus around batch ~8 for its machine balance
    pm = PipelineModel(stream_bw=204.8e9, compute_rate=204.8e9 * 8)
    b = pm.optimal_batch(32 << 20)
    assert 6 <= b <= 10


def test_iteration_scheduler_backfill():
    s = IterationScheduler(target_batch=2)
    for i in range(4):
        s.submit(Request(uid=i, prompt_len=4, max_new_tokens=2))
    batch = s.admit()
    assert [r.uid for r in batch] == [0, 1]
    s.step_complete([])          # 1 token each
    s.step_complete([])          # hit max_new -> finish
    assert {r.uid for r in s.finished} == {0, 1}
    batch = s.admit()
    assert [r.uid for r in batch] == [2, 3]


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 12), tgt=st.integers(1, 5))
def test_property_scheduler_conserves_requests(n, tgt):
    s = IterationScheduler(target_batch=tgt)
    for i in range(n):
        s.submit(Request(uid=i, prompt_len=1, max_new_tokens=1))
    guard = 0
    while not s.idle():
        s.admit()
        s.step_complete([])
        guard += 1
        assert guard < 100
    assert len(s.finished) == n


def test_prt_capacity_eviction():
    # more unique (group, pattern) keys than entries forces misses
    pats = np.arange(64).reshape(1, 1, 64) % 16   # 64 groups, 1 batch
    st_ = pattern.prt_simulate(np.tile(pats, (1, 1, 1)), entries=8)
    assert st_.hit_rate == 0.0
    # batch 4 with identical rows: 3 of 4 accesses hit per (group, plane)
    pats4 = np.tile(pats, (4, 1, 1))
    st4 = pattern.prt_simulate(pats4, entries=1024)
    assert st4.hit_rate == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# slot-based continuous scheduling (property-style, via the _hyp shim)
# ---------------------------------------------------------------------------

def _drain_continuous(s, decode_steps_fn):
    """Drive schedule()/release() to completion; returns iteration trace."""
    trace = []
    guard = 0
    while not s.idle():
        admitted = s.schedule()
        trace.append({"admitted": [r.uid for r in admitted],
                      "running": len(s.running),
                      "admitted_tokens": sum(r.prompt_len
                                             for r in admitted)})
        for r in list(s.running):
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                s.release(r.uid)
        guard += 1
        assert guard < 10_000
    return trace


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20), slots=st.integers(1, 6),
       seed=st.integers(0, 99))
def test_property_slots_never_exceed_max_batch(n, slots, seed):
    rng = np.random.default_rng(seed)
    s = IterationScheduler(target_batch=slots, max_batch=slots)
    for i in range(n):
        s.submit(Request(uid=i, prompt_len=int(rng.integers(1, 9)),
                         max_new_tokens=int(rng.integers(1, 5))))
    for step in _drain_continuous(s, None):
        assert step["running"] <= slots
    used = [r.slot for r in s.running]
    assert len(s.free_slots) == slots and sorted(s.free_slots) == \
        list(range(slots)) and not used


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 25), slots=st.integers(1, 5),
       seed=st.integers(0, 99))
def test_property_every_uid_finishes_exactly_once(n, slots, seed):
    rng = np.random.default_rng(seed)
    s = IterationScheduler(max_batch=slots)
    for i in range(n):
        s.submit(Request(uid=i, prompt_len=1,
                         max_new_tokens=int(rng.integers(1, 6))))
    _drain_continuous(s, None)
    finished = [r.uid for r in s.finished]
    assert sorted(finished) == list(range(n))          # all, exactly once
    assert all(r.done and r.state == "done" for r in s.finished)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), budget=st.integers(1, 12),
       seed=st.integers(0, 99))
def test_property_prefill_budget_cap(n, budget, seed):
    rng = np.random.default_rng(seed)
    s = IterationScheduler(max_batch=8, prefill_budget=budget)
    for i in range(n):
        s.submit(Request(uid=i, prompt_len=int(rng.integers(1, 10)),
                         max_new_tokens=2))
    for step in _drain_continuous(s, None):
        if len(step["admitted"]) > 1:
            # beyond the exempt first request, the cap holds
            assert step["admitted_tokens"] <= budget


@settings(max_examples=15, deadline=None)
@given(slots=st.integers(1, 4), waves=st.integers(2, 4))
def test_property_freed_slots_are_reused(slots, waves):
    s = IterationScheduler(max_batch=slots)
    for i in range(slots * waves):
        s.submit(Request(uid=i, prompt_len=1, max_new_tokens=1))
    seen_slots = []
    while not s.idle():
        for r in s.schedule():
            seen_slots.append(r.slot)
        for r in list(s.running):
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                s.release(r.uid)
    # every wave reuses the same physical slots
    assert sorted(set(seen_slots)) == list(range(slots))
    assert len(seen_slots) == slots * waves


def test_release_unknown_uid_raises():
    s = IterationScheduler(max_batch=2)
    s.submit(Request(uid=1, prompt_len=1, max_new_tokens=1))
    s.schedule()
    with pytest.raises(KeyError):
        s.release(99)
