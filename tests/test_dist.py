"""Distribution: sharding rules, multi-device pjit step, compressed
all-reduce — multi-device cases run in a subprocess with 8 fake host
devices (the main process must keep 1 device for the smoke tests)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import Plan, param_spec
    import repro.configs as C
    cfg = C.get_smoke("llama3_2_1b")
    plan = Plan(dp_axes=("data",), fsdp=True)
    assert param_spec("['blocks']['attn']['wq']", (2, 64, 128), cfg,
                      plan) == P(None, "data", "model")
    assert param_spec("['blocks']['attn']['wo']", (2, 128, 64), cfg,
                      plan) == P(None, "model", "data")
    assert param_spec("['embed']", (256, 64), cfg, plan) == P("model", None)
    assert param_spec("['final_norm']['scale']", (64,), cfg, plan) == P(None)


def test_multi_device_train_step():
    res = run_subprocess(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_train_step
        from repro.models import lm
        from repro.launch import specs as sp

        cfg = C.get_smoke("llama3_2_1b")
        mesh = make_mesh((4, 2), ("data", "model"))
        built = build_train_step(cfg, mesh, bf16_compute=False)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = built.meta["optimizer"]
        opt_state = opt.init(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 4097), 0, cfg.vocab)}
        with mesh:
            p, o, m = built.fn(params, opt_state, batch)
            p, o, m2 = built.fn(p, o, batch)
        print(json.dumps({"loss0": float(m["loss"]),
                          "loss1": float(m2["loss"]),
                          "devices": len(jax.devices())}))
    """))
    assert res["devices"] == 8
    assert np.isfinite(res["loss0"])
    assert res["loss1"] < res["loss0"]  # one update helped on same batch


def test_compressed_allreduce():
    res = run_subprocess(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.compress import (init_error_state,
                                         make_compressed_allreduce)
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64)),
                 "b": jax.random.normal(jax.random.PRNGKey(1), (8, 4))}
        specs = {"w": P("data", None), "b": P("data", None)}
        err = init_error_state(grads)
        fn = make_compressed_allreduce(mesh, ("data",), specs)
        # per-shard mean across data axis == original (each shard reduces
        # to itself x8 /8); instead check error-feedback convergence on a
        # replicated tensor: simulate by repeating the same grad
        same = {"w": jnp.tile(grads["w"][:1], (8, 1)),
                "b": jnp.tile(grads["b"][:1], (8, 1))}
        with mesh:
            mean1, err1 = fn(same, err)
            mean2, err2 = fn(same, err1)
        exact = same
        e1 = float(jnp.abs(mean1["w"] - exact["w"]).max())
        # accumulated two-step average error shrinks with feedback
        acc = (np.asarray(mean1["w"]) + np.asarray(mean2["w"])) / 2
        e2 = float(np.abs(acc - np.asarray(exact["w"])).max())
        scale = float(jnp.abs(exact["w"]).max())
        print(json.dumps({"e1": e1 / scale, "e2": e2 / scale}))
    """))
    assert res["e1"] < 0.02          # int8 single-step error bound
    assert res["e2"] <= res["e1"] + 1e-6  # feedback does not diverge


def test_serve_step_multi_device():
    res = run_subprocess(textwrap.dedent("""
        import json, dataclasses, jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.launch.mesh import make_mesh
        from repro.dist import sharding as sh
        from repro.models import lm
        from repro.models.sail_linear import QuantPolicy, quantize_params

        cfg = C.get_smoke("qwen3_0_6b")
        mesh = make_mesh((4, 2), ("data", "model"))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        qp, _, _ = quantize_params(params, QuantPolicy(bits=4,
                                                       group_size=32,
                                                       min_size=1024))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                  cfg.vocab)
        with mesh:
            logits, cache = lm.prefill(qp, toks, cfg, cache_len=16,
                                       quant_kv=True)
            l2, cache = lm.decode_step(qp, toks[:, :1], cache, cfg,
                                       quant_kv=True)
        print(json.dumps({"finite": bool(np.isfinite(np.asarray(l2)).all()),
                          "shape": list(l2.shape)}))
    """))
    assert res["finite"] and res["shape"] == [4, 256]
