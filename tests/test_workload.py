"""Trace-driven workload generation: determinism (same seed => identical
trace), JSON round-trips, arrival-process invariants, length-distribution
bounds, and the serve_bench workload builder riding on the generator."""

import json
import os
import sys

import numpy as np
import pytest

from repro.serving.workload import (
    ArrivalSpec,
    LengthDist,
    Trace,
    TraceSpec,
    generate,
    spec_for_ratio,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def bursty_spec(seed=7, n=10):
    return TraceSpec(
        seed=seed,
        n_requests=n,
        vocab=97,
        prompt=LengthDist("uniform", low=4, high=12),
        output=LengthDist("constant", low=8, high=8),
        arrival=ArrivalSpec("bursty", gap=2.0, burst=5),
    )


def test_generate_deterministic():
    """The reproducibility contract: same spec => bit-identical trace."""
    a, b = generate(bursty_spec()), generate(bursty_spec())
    assert a.requests == b.requests
    assert a.trace_hash == b.trace_hash
    c = generate(bursty_spec(seed=8))
    assert c.requests != a.requests
    assert c.trace_hash != a.trace_hash


def test_trace_json_round_trip(tmp_path):
    tr = generate(bursty_spec())
    again = Trace.from_json(tr.to_json())
    assert again == tr
    assert again.trace_hash == tr.trace_hash
    path = tmp_path / "trace.json"
    tr.save(str(path))
    loaded = Trace.load(str(path))
    assert loaded == tr
    # the stored requests ARE the replay source (generator evolution
    # cannot silently change a saved trace)
    blob = json.loads(path.read_text())
    assert len(blob["requests"]) == tr.spec.n_requests
    assert blob["version"] == 1


def test_trace_version_gate():
    with pytest.raises(ValueError, match="version"):
        Trace.from_json({"version": 99, "spec": {}, "requests": []})


@pytest.mark.parametrize("process", ["fixed", "poisson", "bursty", "diurnal"])
def test_arrival_invariants(process):
    """Every process yields n nondecreasing iteration indices from 0."""
    spec = ArrivalSpec(process=process, gap=3.0, burst=4)
    rng = np.random.default_rng(3)
    arr = spec.arrival_iterations(rng, 24)
    assert arr.shape == (24,)
    assert arr.dtype == np.int64
    assert arr[0] == 0
    assert (np.diff(arr) >= 0).all()


def test_fixed_arrivals_are_exact():
    arr = ArrivalSpec("fixed", gap=3.0).arrival_iterations(np.random.default_rng(0), 5)
    assert arr.tolist() == [0, 3, 6, 9, 12]


def test_bursty_arrivals_come_in_bursts():
    spec = ArrivalSpec("bursty", gap=2.0, burst=5)
    arr = spec.arrival_iterations(np.random.default_rng(7), 10)
    # requests land in groups of `burst` simultaneous arrivals
    assert (arr[:5] == arr[0]).all()
    assert (arr[5:] == arr[5]).all()
    assert arr[5] > arr[0]


def test_length_dist_bounds():
    rng = np.random.default_rng(0)
    uni = LengthDist("uniform", low=3, high=9).sample(rng, 200)
    assert uni.min() >= 3 and uni.max() <= 9
    log = LengthDist("lognormal", low=2, high=40, mean=8.0, sigma=1.0).sample(rng, 200)
    assert log.min() >= 2 and log.max() <= 40
    const = LengthDist("constant", low=6, high=6).sample(rng, 5)
    assert (const == 6).all()


def test_length_dist_validation():
    with pytest.raises(ValueError, match="kind"):
        LengthDist("zipf")
    with pytest.raises(ValueError, match="low"):
        LengthDist("uniform", low=0)
    with pytest.raises(ValueError, match="< low"):
        LengthDist("uniform", low=5, high=4)
    with pytest.raises(ValueError, match="process"):
        ArrivalSpec("weekly")
    with pytest.raises(ValueError, match="amplitude"):
        ArrivalSpec("diurnal", amplitude=1.5)


def test_spec_for_ratio():
    spec = spec_for_ratio(2.0, n_requests=8, output_tokens=10)
    assert spec.output.expected == 10
    assert spec.prompt.expected == pytest.approx(20, rel=0.3)
    assert spec.prefill_decode_ratio == pytest.approx(2.0, rel=0.3)
    tr = generate(spec)
    assert len(tr.requests) == 8
    assert all(r.max_new_tokens == 10 for r in tr.requests)
    with pytest.raises(ValueError, match="positive"):
        spec_for_ratio(-1.0)


def test_prompt_tokens_in_vocab():
    tr = generate(bursty_spec())
    for r in tr.requests:
        assert all(0 <= t < 97 for t in r.prompt)
        assert len(r.prompt) >= 4


def test_build_workload_reproducible():
    """serve_bench's workload is a Trace, reproducible from (seed, spec),
    with the arrival process selectable by name."""
    from benchmarks.serve_bench import build_workload

    class _Cfg:
        vocab = 128

    a = build_workload(_Cfg, 6, 12, 3, seed=5, arrival="poisson")
    b = build_workload(_Cfg, 6, 12, 3, seed=5, arrival="poisson")
    assert a.requests == b.requests and a.trace_hash == b.trace_hash
    assert a.spec.arrival.process == "poisson"
    c = build_workload(_Cfg, 6, 12, 3, seed=6, arrival="poisson")
    assert c.requests != a.requests
