"""Tensor-parallel quantized serving (PR 10): partition-spec arithmetic,
shard_map bit-exactness properties, wire-cost regimes, and tp=2-vs-tp=1
greedy token identity.  Multi-device cases run in a subprocess with 8
fake host devices (the main process must keep 1 device for the smoke
tests)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# spec arithmetic (pure, single device)
# ---------------------------------------------------------------------------

def test_serving_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.serving.distributed import serving_param_spec

    # column-parallel: output dim on the model axis; quantized companions
    # (packed [(K/G)*wpg, N], scales [K/G, N]) follow the parent matrix
    assert serving_param_spec("['blocks']['attn']['wq']",
                              (64, 128)) == P(None, "model")
    assert serving_param_spec("['blocks']['attn']['wq'].packed",
                              (16, 128)) == P(None, "model")
    assert serving_param_spec("['blocks']['attn']['wq'].scales",
                              (2, 128)) == P(None, "model")
    assert serving_param_spec("['blocks']['mlp']['w_up'].packed",
                              (2, 16, 128)) == P(None, None, "model")
    # row-parallel: reduction dim on the model axis
    assert serving_param_spec("['blocks']['attn']['wo']",
                              (128, 64)) == P("model", None)
    assert serving_param_spec("['blocks']['mlp']['w_down'].packed",
                              (32, 64)) == P("model", None)
    assert serving_param_spec("['blocks']['mlp']['w_down'].scales",
                              (4, 64)) == P("model", None)
    # stacked-layer leading dim rides through unsharded
    assert serving_param_spec("['blocks']['attn']['wq']",
                              (2, 64, 128)) == P(None, None, "model")
    # codebooks and 1-D params replicate
    assert serving_param_spec("['blocks']['attn']['wq'].codebook",
                              (16,)) == P(None)
    assert serving_param_spec("['final_norm']['scale']", (64,)) == P(None)
    # serving divergence from the training rule: embeddings and lm_head
    # replicate so every shard computes the full logits row
    assert serving_param_spec("['embed']", (256, 64)) == P(None, None)
    assert serving_param_spec("['lm_head']", (64, 256)) == P(None, None)


def test_trim_spec_arithmetic():
    """_trim_spec drops axes the mesh lacks or that don't divide the dim
    — exercised standalone on a fake (1, 2) mesh so the arithmetic is
    covered without any devices."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import _trim_spec

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((1, 2), dtype=object)

    mesh = FakeMesh()
    # dividing dims keep their axis
    assert _trim_spec(P(None, "model"), (64, 128), mesh) == P(None, "model")
    assert _trim_spec(P("model", None), (4, 6), mesh) == P("model", None)
    # a non-dividing dim drops the axis (odd group count vs 2 shards)
    assert _trim_spec(P("model", None), (3, 8), mesh) == P(None, None)
    # an axis the mesh lacks drops too
    assert _trim_spec(P("pod", "model"), (8, 8), mesh) == P(None, "model")
    # rank fixup: short specs pad with None, long specs truncate
    assert _trim_spec(P("model"), (4, 6), mesh) == P("model", None)
    assert _trim_spec(P(None, "model", None), (4, 6), mesh) == P(None, "model")
    # size-1 axes always divide
    assert _trim_spec(P("data", "model"), (5, 4), mesh) == P("data", "model")


def test_tp_supported_and_local_config():
    import repro.configs as C
    from repro.serving.distributed import local_config, tp_supported

    cfg = C.get_smoke("tinymistral_248m")   # 8 heads, 2 kv, d_ff 128
    assert tp_supported(cfg, 1) is None
    assert tp_supported(cfg, 2) is None
    assert "n_kv" in tp_supported(cfg, 4)          # n_kv=2 % 4
    assert "n_heads" in tp_supported(cfg, 3)
    moe = dataclasses.replace(cfg, family="moe")
    assert "family" in tp_supported(moe, 2)
    biased = dataclasses.replace(cfg, attention_bias=True)
    assert "bias" in tp_supported(biased, 2)

    lcfg = local_config(cfg, 2)
    assert lcfg.n_heads == cfg.n_heads // 2
    assert lcfg.n_kv == cfg.n_kv // 2
    assert lcfg.d_ff == cfg.d_ff // 2
    # d_head is pinned: it defaults to d_model // n_heads and must not
    # change when n_heads shrinks
    assert lcfg.head_dim == cfg.head_dim
    assert local_config(cfg, 1) is cfg


def test_shard_alignment_and_localize():
    import jax.numpy as jnp
    from repro.core import quant
    from repro.serving.distributed import (localize_params,
                                           shard_alignment_error)

    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32) / 100.0
    tree = {"blocks": {"attn": {"wo": quant.quantize(w, 4, 32),
                                "wq": quant.quantize(w, 4, 32)},
                       "norm": {"scale": jnp.ones((64,))}}}
    # k=64, G=32 -> 2 groups: divides tp=2
    assert shard_alignment_error(tree, 2) is None
    assert shard_alignment_error(tree, 1) is None
    # G=64 -> 1 group on the row-parallel leaf: cannot split K across 2
    bad = {"blocks": {"attn": {"wo": quant.quantize(w, 4, 64)}}}
    err = shard_alignment_error(bad, 2)
    assert err is not None and "wo" in err
    # column-parallel leaves never constrain K
    ok = {"blocks": {"attn": {"wq": quant.quantize(w, 4, 64)}}}
    assert shard_alignment_error(ok, 2) is None

    local = localize_params(tree, 2)
    assert local["blocks"]["attn"]["wo"].k == 32       # row-parallel: K/tp
    assert local["blocks"]["attn"]["wq"].k == 64       # column: full K
    assert localize_params(tree, 1) is tree


# ---------------------------------------------------------------------------
# plan grammar / schema
# ---------------------------------------------------------------------------

def test_planspec_tp_wire_roundtrip():
    from repro.planning import PlanSpec

    spec = PlanSpec.parse("uniform:4a8,tp=2,wire=8")
    assert spec.tp == 2 and spec.wire == 8
    assert spec.solved
    assert PlanSpec.parse(spec.format()) == spec
    assert PlanSpec.from_json(spec.to_json()) == spec

    auto = PlanSpec.parse("auto:q4a8,tp=auto")
    assert auto.tp == "auto"
    assert not auto.solved               # needs the Planner to pin a count
    assert PlanSpec.parse(auto.format()) == auto

    # plans that never mention tp/wire serialize without the keys, so
    # pre-PR-10 spec hashes (and saved plan.json files) are preserved
    plain = PlanSpec.parse("uniform:4a8")
    assert plain.tp is None and plain.wire is None
    assert "tp" not in plain.to_json() and "wire" not in plain.to_json()
    assert "tp=" not in plain.format()

    with pytest.raises(ValueError):
        PlanSpec.parse("uniform:4,wire=16")
    with pytest.raises(ValueError):
        PlanSpec.parse("uniform:4,tp=0")


# ---------------------------------------------------------------------------
# wire-cost model (pure, single device)
# ---------------------------------------------------------------------------

def _smoke_setup():
    import jax
    import repro.configs as C
    from repro.models import lm
    from repro.models.sail_linear import QuantPolicy

    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, QuantPolicy(bits=4, group_size=32, min_size=1024)


def test_cost_model_regime_switch():
    """Sweeping the link bandwidth walks the plan through the wire-bound
    regime into compute/DRAM-bound — the transition is monotone and the
    sharded terms are exactly 1/tp of the single-device ones."""
    from repro import planning

    cfg, params, policy = _smoke_setup()
    elems = planning.tp_allreduce_elems(cfg)
    assert elems == 2 * cfg.n_layers * cfg.d_model

    base = planning.DecodeCostModel(batch=8)
    one = base.evaluate(params, policy)
    assert one.t_wire == 0.0 and one.bound in ("compute", "dram")

    bounds = []
    for bw in (1e5, 1e7, 1e9, 1e12, 1e15):
        tp2 = planning.DecodeCostModel(batch=8, tp=2, wire_bits=32,
                                       allreduce_elems=elems, link_bw=bw)
        cost = tp2.evaluate(params, policy)
        bounds.append(cost.bound)
        # sharding divides compute and DRAM exactly; the wire term is
        # untouched by the bit allocation
        assert cost.t_compute == pytest.approx(one.t_compute / 2)
        assert cost.t_dram == pytest.approx(one.t_dram / 2)
    assert bounds[0] == "wire"
    assert bounds[-1] in ("compute", "dram")
    first_free = bounds.index(bounds[-1])
    assert all(b == "wire" for b in bounds[:first_free])
    assert all(b != "wire" for b in bounds[first_free:])

    # wire=8 moves a quarter of the bytes of wire=32
    kw = dict(batch=8, tp=2, allreduce_elems=elems, link_bw=1e9)
    t32 = planning.DecodeCostModel(wire_bits=32, **kw).t_wire()
    t8 = planning.DecodeCostModel(wire_bits=8, **kw).t_wire()
    assert t8 == pytest.approx(t32 / 4)


def test_budgets_wire_bound_unreachable():
    """No bit allocation fixes a wire-bound plan: budgets() must refuse
    instead of handing the solver an unmeetable target."""
    from repro import planning

    cfg, _, _ = _smoke_setup()
    elems = planning.tp_allreduce_elems(cfg)
    slo = planning.Slo(1000.0, batch=8)
    choked = planning.DecodeCostModel(batch=8, tp=2, wire_bits=32,
                                      allreduce_elems=elems, link_bw=1e3)
    with pytest.raises(ValueError, match="wire-bound"):
        choked.budgets(slo)
    # per-shard budgets scale by the shard count once the wire fits
    fast = planning.DecodeCostModel(batch=8, tp=2, wire_bits=32,
                                    allreduce_elems=elems, link_bw=1e12)
    single = planning.DecodeCostModel(batch=8)
    b2, b1 = fast.budgets(slo), single.budgets(slo)
    assert b2.cycle_budget == pytest.approx(2 * b1.cycle_budget)


def test_planner_resolves_tp_auto():
    from repro import planning

    cfg, params, policy = _smoke_setup()
    plan = planning.PlanSpec.parse("uniform:4a8,tp=auto")
    planner = planning.Planner(params, cfg, plan, base=policy)

    # no SLO: nothing to meet, sharding buys nothing -> tp=1
    assert planner._resolve_tp(plan, None).tp == 1
    # trivially met target: the smallest grid point wins
    assert planner._resolve_tp(plan, planning.Slo(1e-6, batch=8)).tp == 1
    # unmeetable target: the sweep runs off the grid end
    worst = planner._resolve_tp(plan, planning.Slo(1e15, batch=8))
    assert worst.tp == planning.Planner.TP_GRID[-1]
    # resolving through solve() pins the count and the result is solved
    solved = planner.solve(slo=planning.Slo(1e-6, batch=8)).spec
    assert isinstance(solved.tp, int)
    assert solved.solved


# ---------------------------------------------------------------------------
# shard_map properties (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

def test_lut_matmul_shard_map_bitexact():
    """Column- and row-parallel shard_map runs of the LUT matmul match
    the single-device kernel bit-for-bit across wbits x abits.

    The data is constructed integer-valued (integer codebook, unit group
    scales, activation rows pinned to the quantizer's qmax so the
    per-token scale is exactly 1.0): every product and partial sum stays
    below 2^24, f32 arithmetic is exact, and any split of the reduction
    must agree to the bit."""
    res = run_subprocess(textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import quant
        from repro.kernels.lut_gemv import ops
        from repro.launch.mesh import make_mesh

        B, K, N, G, TP = 4, 128, 64, 32, 2
        mesh = make_mesh((1, TP), ("data", "model"))
        rng = np.random.default_rng(0)

        def qspec(qt, pk, sc):
            leaves, treedef = jax.tree_util.tree_flatten(qt)
            assert len(leaves) == 3        # packed, scales, codebook
            return jax.tree_util.tree_unflatten(treedef, [pk, sc, P(None)])

        def integer_qtensor(wb, ab):
            # integer codebook + unit scales -> dequant is exact integers
            if wb == 1:
                book = jnp.asarray([-1.0, 1.0], jnp.float32)
            else:
                book = (jnp.arange(1 << wb, dtype=jnp.float32)
                        - float(1 << (wb - 1)))
            codes = jnp.asarray(
                rng.integers(0, 1 << wb, size=(K, N)), jnp.uint32)
            return quant.QTensor(
                packed=quant.pack_grouped(codes, wb, G),
                scales=jnp.ones((K // G, N), jnp.float32),
                codebook=book, bits=wb, group_size=G, k=K, abits=ab)

        out = {}
        for wb in (1, 2, 3, 4, 8):
            for ab in (4, 6, 8):
                qt = integer_qtensor(wb, ab)
                qmax = (1 << (ab - 1)) - 1
                x = rng.integers(-qmax, qmax + 1,
                                 size=(B, K)).astype(np.float32)
                x[:, 0] = qmax            # row absmax == qmax -> scale 1.0
                x = jnp.asarray(x)

                single = ops.lut_matmul(x, qt, backend="jnp")

                col = shard_map(
                    lambda x, q: ops.lut_matmul(x, q, backend="jnp"),
                    mesh=mesh,
                    in_specs=(P(None, None),
                              qspec(qt, P(None, "model"), P(None, "model"))),
                    out_specs=P(None, "model"), check_rep=False)(x, qt)

                xq, xs = quant.quantize_activations(x, ab)
                single_int = ops.lut_matmul_quantized(
                    xq, xs, qt, backend="jnp")

                def row_body(xq, xs, q):
                    local = dataclasses.replace(q, k=q.k // TP)
                    part = ops.lut_matmul_quantized(
                        xq, xs, local, backend="jnp")
                    return jax.lax.psum(part, "model")

                row = shard_map(
                    row_body, mesh=mesh,
                    in_specs=(P(None, "model"), P(None, None),
                              qspec(qt, P("model", None), P("model", None))),
                    out_specs=P(None, None), check_rep=False)(xq, xs, qt)

                key = f"w{wb}a{ab}"
                out[key] = {
                    "scale_one": bool(jnp.all(xs == 1.0)),
                    "col": bool(np.array_equal(np.asarray(single),
                                               np.asarray(col))),
                    "row": bool(np.array_equal(np.asarray(single_int),
                                               np.asarray(row))),
                    "int_matches_float": bool(np.array_equal(
                        np.asarray(single), np.asarray(single_int))),
                }
        print(json.dumps(out))
    """))
    for key, cell in res.items():
        assert cell["scale_one"], f"{key}: activation scale not exactly 1"
        assert cell["col"], f"{key}: column-parallel diverged"
        assert cell["row"], f"{key}: row-parallel diverged"
        assert cell["int_matches_float"], f"{key}: int path diverged"


def test_int8_wire_allreduce():
    """wire=8 all-reduce: error bounded by the int8 rounding budget and
    bit-deterministic per seed; wire=32 matches the exact sum."""
    res = run_subprocess(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import tp_all_reduce, tp_context
        from repro.launch.mesh import make_mesh

        TP, B, D = 2, 8, 64
        mesh = make_mesh((1, TP), ("data", "model"))
        parts = jax.random.normal(jax.random.PRNGKey(7), (TP, B, D))
        exact = np.asarray(parts).sum(axis=0)

        def run(wire):
            def body(p):
                with tp_context("model", wire):
                    return tp_all_reduce(p[0])
            fn = shard_map(body, mesh=mesh,
                           in_specs=(P("model", None, None),),
                           out_specs=P(None, None), check_rep=False)
            return np.asarray(fn(parts))

        r8a, r8b, r32 = run(8), run(8), run(32)
        # one int8 round-off per shard, each at most scale/2
        budget = sum(np.abs(np.asarray(parts[i])).max() / 127.0
                     for i in range(TP))
        print(json.dumps({
            "exact32": bool(np.array_equal(r32, exact)),
            "deterministic": bool(np.array_equal(r8a, r8b)),
            "max_err": float(np.abs(r8a - exact).max()),
            "budget": float(budget),
            "nontrivial": bool(np.abs(r8a - exact).max() > 0.0),
        }))
    """))
    assert res["exact32"]
    assert res["deterministic"]
    assert res["max_err"] <= res["budget"]
    assert res["nontrivial"]        # the compressor actually ran


def test_engine_tp_identity_ring_and_paged():
    """tp=2 greedy decode is token-identical to tp=1 through the full
    engine (continuous batching, int8 KV) on both the ring and the paged
    pool, and the stats surface the wire accounting."""
    res = run_subprocess(textwrap.dedent("""
        import json
        import jax
        from repro.configs import get_smoke
        from repro.models import lm
        from repro.serving.engine import Engine, EngineConfig

        cfg = get_smoke("tinymistral_248m")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        PROMPTS = [[3, 5, 7, 2, 9], [4, 4, 1], [8, 2, 6, 1, 1, 5], [7]]

        def run(tp, paged):
            kw = dict(batch_size=4, cache_len=64, quantize=True, ql=4,
                      group_size=32, min_size=1024, quant_kv=True,
                      tp=tp, wire=32)
            if paged:
                kw["kv_block_size"] = 8
            eng = Engine(params, cfg, EngineConfig(**kw))
            for p in PROMPTS:
                eng.submit(list(p), 6)
            eng.run()
            toks = [eng.completions[u].tokens
                    for u in sorted(eng.completions)]
            return toks, eng.stats()

        out = {}
        for paged in (False, True):
            t1, s1 = run(1, paged)
            t2, s2 = run(2, paged)
            name = "paged" if paged else "ring"
            out[name + "_match"] = t1 == t2
            out[name + "_nonempty"] = all(len(t) == 6 for t in t2)
            if not paged:
                out["tp1_stats"] = s1["tp"]
                out["tp_stats"] = s2["tp"]
        print(json.dumps(out))
    """))
    assert res["ring_match"], "tp=2 diverged from tp=1 on the ring pool"
    assert res["paged_match"], "tp=2 diverged from tp=1 on the paged pool"
    assert res["ring_nonempty"] and res["paged_nonempty"]
    assert res["tp1_stats"] is None          # no tp section at tp=1
    tp = res["tp_stats"]
    assert tp["shards"] == 2 and tp["wire_bits"] == 32
    # batch * 2 * L * d_model * 4 bytes * 2(M-1)/M = 4*2*2*64*4*1
    assert tp["allreduce_bytes_per_iter"] == 4096


def test_plan_tp_overrides_engine_knob():
    """A plan carrying tp=/wire= is the precision contract: it overrides
    the EngineConfig knobs, and greedy output still matches tp=1."""
    res = run_subprocess(textwrap.dedent("""
        import json
        import jax
        from repro.configs import get_smoke
        from repro.models import lm
        from repro.serving.engine import Engine, EngineConfig

        cfg = get_smoke("tinymistral_248m")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)

        def run(**kw):
            eng = Engine(params, cfg, EngineConfig(
                batch_size=2, cache_len=64, quantize=True, ql=4,
                group_size=32, min_size=1024, quant_kv=True, **kw))
            eng.submit([3, 1, 4, 1, 5], 5)
            eng.run()
            return eng, [eng.completions[u].tokens
                         for u in sorted(eng.completions)]

        ref_eng, ref = run(tp=1)
        eng, toks = run(plan="uniform:4a8,tp=2,wire=8", tp=1)
        st = eng.stats()["tp"]
        print(json.dumps({
            "shards": st["shards"], "wire_bits": st["wire_bits"],
            "match": toks == ref,
        }))
    """))
    assert res["shards"] == 2
    assert res["wire_bits"] == 8
    # int8 wire on a 2-layer smoke model still decodes the same greedy
    # tokens as exact tp=1 here; divergence would only signal a numeric
    # gap, but on this seed the argmax margins absorb the compression
    assert res["match"]
