"""Test-suite path setup: make ``_hyp`` (and ``repro`` when PYTHONPATH is
unset) importable regardless of pytest's rootdir/import mode."""
import os
import sys

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(_HERE, "..", "src")
for p in (_HERE, os.path.abspath(_SRC)):
    if p not in sys.path:
        sys.path.insert(0, p)
