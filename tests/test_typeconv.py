"""Algorithm 1: bit-exact int -> IEEE-754 f32 with logic ops only."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import typeconv


def test_edge_cases():
    vals = np.array([0, 1, -1, 2, -2, 3, (1 << 24) - 1, -(1 << 24) + 1,
                     1 << 23, -(1 << 23), 12345, -98765], np.int32)
    out = np.asarray(typeconv.int_to_f32(jnp.asarray(vals), n=25))
    assert (out == vals.astype(np.float32)).all()
    assert (np.signbit(out) == np.signbit(vals.astype(np.float32))).all()


@pytest.mark.parametrize("n", [2, 5, 8, 16, 24, 25])
def test_all_widths(n):
    lim = 1 << (n - 1)
    rng = np.random.default_rng(n)
    vals = rng.integers(-lim + 1, lim, size=2000).astype(np.int32)
    out = np.asarray(typeconv.int_to_f32(jnp.asarray(vals), n=n))
    assert (out == vals.astype(np.float32)).all()


def test_exhaustive_small_width():
    n = 12
    vals = np.arange(-(1 << 11) + 1, 1 << 11, dtype=np.int32)
    out = np.asarray(typeconv.int_to_f32(jnp.asarray(vals), n=n))
    assert (out == vals.astype(np.float32)).all()


@settings(max_examples=50, deadline=None)
@given(v=st.integers(-(1 << 24) + 1, (1 << 24) - 1))
def test_property_bit_exact(v):
    out = np.asarray(typeconv.int_to_f32(jnp.asarray([v], jnp.int32), n=25))
    assert out[0] == np.float32(v)


def test_cycle_formulas():
    assert typeconv.logic_ops(25) == 25 * 25 / 2 + 13 * 24
    assert typeconv.sram_cycles(25) == 1.5 * 625 + 39 * 24


def test_f32_to_int_roundtrip():
    x = jnp.asarray([0.4, -0.6, 100.2, -7.5, 3.5])
    out = np.asarray(typeconv.f32_to_int(x))
    assert (out == np.array([0, -1, 100, -8, 4])).all()  # round-half-even
