"""Measured-roofline cost-model calibration.

The fit must recover known machine constants from synthetic timings (the
design matrix matches ``lut_gemv_cycles`` exactly), the artifact and the
``PlanSpec.calibration`` provenance must round-trip, and a Planner handed
a calibrated plan must price against the fitted machine.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy
from repro.planning import PlanSpec, Planner
from repro.planning.calibrate_cost import (DEFAULT_ABITS, DEFAULT_NBW,
                                           DEFAULT_WBITS, CalibrationResult,
                                           FITTED_FIELDS, fit_constants,
                                           machine_from_json)

B, K, N = 8, 512, 256


def _synth_points(machine):
    """Exact model-generated timings over the calibration grid."""
    pts = []
    for wb in DEFAULT_WBITS:
        for ab in DEFAULT_ABITS:
            for nbw in DEFAULT_NBW:
                cyc = cm.lut_gemv_cycles(machine, B, K, N, nbw, wb, ab,
                                         threads=1)
                pts.append(dict(wbits=wb, abits=ab, nbw=nbw,
                                t_s=cyc / machine.freq_hz))
    return pts


def test_fit_recovers_known_constants():
    true = dataclasses.replace(
        cm.SailMachine(), lookup_base_cycles=500.0,
        lookup_per_bit_cycles=12.0, rebuild_ctrl_cycles=4000.0,
        build_overhead=3.0)
    got = fit_constants(_synth_points(true), B, K, N)
    for field in ("lookup_base_cycles", "lookup_per_bit_cycles",
                  "rebuild_ctrl_cycles", "build_overhead"):
        want = getattr(true, field)
        assert got[field] == pytest.approx(want, rel=1e-6), field


def test_fit_is_nonnegative_on_noisy_data():
    rng = np.random.default_rng(0)
    pts = _synth_points(cm.SailMachine())
    for p in pts:
        p["t_s"] *= float(rng.uniform(0.5, 2.0))
    got = fit_constants(pts, B, K, N)
    assert all(v >= 0.0 for v in got.values())


def test_fitted_machine_reprices_grid_exactly():
    true = dataclasses.replace(cm.SailMachine(), build_overhead=2.5,
                               rebuild_ctrl_cycles=7000.0)
    pts = _synth_points(true)
    fitted = dataclasses.replace(cm.SailMachine(),
                                 **fit_constants(pts, B, K, N))
    for p in pts:
        modeled = cm.lut_gemv_cycles(fitted, B, K, N, p["nbw"], p["wbits"],
                                     p["abits"], threads=1)
        measured = p["t_s"] * true.freq_hz
        assert modeled == pytest.approx(measured, rel=1e-6)


def _fake_result():
    return CalibrationResult(
        machine_overrides={"lookup_base_cycles": 777.0, "dram_bw": 5e10,
                           "dram_efficiency": 1.0},
        points=(dict(wbits=4, abits=8, nbw=2, t_s=1e-4,
                     measured_cycles=3e5, modeled_cycles=2.9e5,
                     rel_err=0.033),),
        shape=(B, K, N), backend="cpu",
        max_rel_err=0.033, mean_rel_err=0.033, dram_bw_measured=5e10)


def test_calibration_result_roundtrip(tmp_path):
    res = _fake_result()
    path = str(tmp_path / "calib.json")
    res.save(path)
    back = CalibrationResult.load(path)
    assert back == res
    m = back.machine()
    assert m.lookup_base_cycles == 777.0 and m.dram_bw == 5e10
    assert m.rebuild_ctrl_cycles == cm.SailMachine().rebuild_ctrl_cycles


def test_machine_from_json_ignores_unknown_fields():
    m = machine_from_json({"machine_overrides": {
        "lookup_base_cycles": 111.0, "freq_hz": 1.0, "bogus": 9.0}})
    assert m.lookup_base_cycles == 111.0
    assert m.freq_hz == cm.SailMachine().freq_hz  # structural, not fitted
    assert set(FITTED_FIELDS) >= {"dram_bw", "build_overhead"}


def test_planspec_carries_calibration_provenance():
    prov = _fake_result().provenance()
    plan = PlanSpec(mode="auto", weight_bits=4, act_bits=8,
                    calibration=prov)
    back = PlanSpec.from_json(json.loads(json.dumps(plan.to_json())))
    assert back.calibration == prov
    bare = PlanSpec(mode="auto", weight_bits=4, act_bits=8)
    assert "calibration" not in bare.to_json()
    assert plan.spec_hash != bare.spec_hash


def test_planner_prices_against_fitted_machine():
    cfg = ModelConfig(name="tiny", family="dense", vocab=64, d_model=32,
                      n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu",
                      attn_chunk=16, max_seq=128)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    plan = PlanSpec(mode="auto", weight_bits=4, act_bits=8,
                    calibration=_fake_result().provenance())
    planner = Planner(params, cfg, plan,
                      base=QuantPolicy(bits=4, group_size=32, min_size=1024))
    m = planner.cost.machine
    assert m.lookup_base_cycles == 777.0
    assert m.dram_bw == 5e10 and m.dram_efficiency == 1.0
    # an uncalibrated plan keeps the paper machine
    bare = Planner(params, cfg,
                   PlanSpec(mode="auto", weight_bits=4, act_bits=8),
                   base=QuantPolicy(bits=4, group_size=32, min_size=1024))
    assert bare.cost.machine.lookup_base_cycles == \
        cm.SailMachine().lookup_base_cycles
