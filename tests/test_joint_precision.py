"""Joint (wbits, abits) allocation: policy grammar and spec round-trips,
activation-quantized serving numerics, exact-centered activation probes,
the product-grid solver, real-calibration-data hooks, the scan-segment
cap (compile-cost regression), and checkpoint round-trips of
activation-allocated trees."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import sensitivity as sens
from repro.core.quant import SUPPORTED_ABITS, quantize
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import (BitAllocation, QuantPolicy, QTensor,
                                      StackedQTensor, act_fake_quant,
                                      mm, quantize_params)


def tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", vocab=64, d_model=32,
                n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu",
                attn_chunk=16, max_seq=128)
    base.update(kw)
    return ModelConfig(**base)


def tiny_params(cfg=None, seed=0):
    return lm.init_params(jax.random.PRNGKey(seed), cfg or tiny_cfg())


POLICY = dict(group_size=32, min_size=1024)


def iter_qtensors(tree, prefix=""):
    if isinstance(tree, (QTensor, StackedQTensor)):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from iter_qtensors(v, prefix + f"['{k}']")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_qtensors(v, prefix + f"[{i}]")


# ---------------------------------------------------------------------------
# grammar + spec round-trips
# ---------------------------------------------------------------------------

def test_parse_bit_policy_activation_grammar():
    assert sens.parse_bit_policy("uniform:4a8") == {
        "mode": "uniform", "bits": 4, "abits": 8}
    r = sens.parse_bit_policy("rules:attn=5a6,mlp=3,default=4a8")
    assert r["rules"] == [("attn", 5), ("mlp", 3)]
    assert r["act_rules"] == [("attn", 6)]
    assert r["bits"] == 4 and r["abits"] == 8
    a = sens.parse_bit_policy("auto:q4a8,prt=measured,maxseg=2")
    assert a == {"mode": "auto", "match_uniform": 4, "abits": 8,
                 "prt": "measured", "max_segments": 2}
    # legacy weight-only forms are unchanged
    assert sens.parse_bit_policy("auto:q4") == {"mode": "auto",
                                                "match_uniform": 4}
    assert sens.parse_bit_policy("uniform:6") == {"mode": "uniform",
                                                  "bits": 6}
    with pytest.raises(ValueError):
        sens.parse_bit_policy("auto:q4a8,prt=sometimes")
    with pytest.raises(ValueError):
        sens.parse_bit_policy("uniform:4b8")


def test_policy_spec_roundtrip_with_activations():
    alloc = BitAllocation(per_path={"['a']": 5, "['b']": (2, 3)},
                          act_per_path={"['a']": 8, "['b']": (4, 6)})
    pol = QuantPolicy(bits=6, group_size=64, min_size=2048,
                      allocation=alloc, act_bits=8,
                      act_rules=(("head", 6),))
    back = QuantPolicy.from_spec(pol.to_spec())
    assert back == pol
    import json
    json.dumps(pol.to_spec())
    # legacy flat allocation specs still parse (weight-only checkpoints)
    legacy = BitAllocation.from_spec({"['x']": 4, "['y']": [2, 8]})
    assert legacy.per_path["['y']"] == (2, 8)
    assert legacy.lookup_act("['x']") is None


def test_abits_precedence_and_validation():
    alloc = BitAllocation(per_path={}, act_per_path={"['y']": 6})
    pol = QuantPolicy(bits=4, act_bits=8, act_rules=(("x", 4),),
                      allocation=alloc)
    assert pol.abits_for("['x']") == 4      # act_rules beat allocation
    assert pol.abits_for("['y']") == 6      # allocation beats fallback
    assert pol.abits_for("['z']") == 8      # fallback
    assert QuantPolicy(bits=4).abits_for("['z']") is None
    bad = QuantPolicy(bits=4, act_rules=(("x", 5),))
    with pytest.raises(ValueError):
        bad.abits_for("['x']")


def test_resolve_bit_policy_activation_strings():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    uni = sens.resolve_bit_policy("uniform:6a8", params, cfg, base)
    assert uni.bits == 6 and uni.act_bits == 8
    rules = sens.resolve_bit_policy("rules:mlp=4a6,default=6a8", params,
                                    cfg, base)
    assert rules.act_rules == (("mlp", 6),) and rules.act_bits == 8


# ---------------------------------------------------------------------------
# activation-quantized serving numerics
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(abits=st.sampled_from(SUPPORTED_ABITS), bits=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 99))
def test_property_mm_applies_activation_quant(abits, bits, seed):
    """mm on a QTensor carrying abits must equal the same matmul on
    explicitly fake-quantized activations — and differ from the f32
    path whenever quantization actually rounds."""
    from repro.kernels.lut_gemv.ops import lut_matmul
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    qt = quantize(w, bits, 32)
    qta = dataclasses.replace(qt, abits=int(abits))
    got = mm(x, qta)
    want = lut_matmul(act_fake_quant(x, abits), qt, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    f32 = mm(x, qt)
    assert float(jnp.max(jnp.abs(got - f32))) > 0.0


def test_quantized_leaves_carry_abits_and_segment():
    params = tiny_params()
    alloc = BitAllocation(per_path={},
                          act_per_path={"['blocks']['attn']['wq']": (8, 4)})
    pol = QuantPolicy(bits=4, allocation=alloc, act_bits=8, **POLICY)
    qtree, _, _ = quantize_params(params, pol)
    # abits-only change segments the stack exactly like weight bits do
    assert isinstance(qtree["blocks"], list) and len(qtree["blocks"]) == 2
    assert qtree["blocks"][0]["attn"]["wq"].abits == 8
    assert qtree["blocks"][1]["attn"]["wq"].abits == 4
    assert qtree["blocks"][0]["attn"]["wq"].bits == 4
    assert qtree["blocks"][1]["mlp"]["w_up"].abits == 8  # act_bits fallback


def test_act_quantized_model_close_to_f32_activations():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    toks = jnp.asarray([[1, 2, 3, 4]])
    base = QuantPolicy(bits=8, **POLICY)
    ref = lm.forward(quantize_params(params, base)[0], toks, cfg)[0]
    a8 = dataclasses.replace(base, act_bits=8)
    got = lm.forward(quantize_params(params, a8)[0], toks, cfg)[0]
    err = float(jnp.mean((got - ref) ** 2))
    assert 0.0 < err < 1e-3   # 8-bit activations: small but nonzero noise


# ---------------------------------------------------------------------------
# activation sensitivity probes
# ---------------------------------------------------------------------------

def test_activation_sensitivity_centered_and_ordered():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    pol = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    scores = sens.activation_sensitivity(params, cfg, toks, pol,
                                         abits_candidates=(4, 8))
    assert scores, "no quantizable units found"
    base = {errs[None] for errs in scores.values()}
    assert len(base) == 1          # every probe shares the exact center
    for key, errs in scores.items():
        # exact-centered probes interact with the quantized-weight center,
        # so a single unit may see tiny inversions — bound them to noise
        assert errs[4] >= errs[8] - 1e-3, key
    total4 = sum(errs[4] for errs in scores.values())
    total8 = sum(errs[8] for errs in scores.values())
    assert total4 > total8         # 4-bit activations hurt in aggregate
    layers = {k[1] for k in scores if k[0].startswith("['blocks']")}
    assert layers == {0, 1}


# ---------------------------------------------------------------------------
# joint allocator
# ---------------------------------------------------------------------------

def make_joint_units(n=5, k=64, seed=0):
    rng = np.random.default_rng(seed)
    units = []
    for i in range(n):
        ws = float(rng.uniform(0.1, 10.0))
        asc = float(rng.uniform(0.01, 1.0))
        units.append(sens.Unit(
            path=f"['w{i}']", layer=None, k=k, n=k, copies=1,
            errors={b: ws * 4.0 ** (-b) for b in (2, 3, 4, 5, 6, 8)},
            aerrors={ab: asc * 2.0 ** (-ab) for ab in SUPPORTED_ABITS}))
    return units


def uniform_cycles(units, wb, ab):
    return cm.mixed_decode_cycles(
        [(u.k, u.n, wb, ab, u.copies) for u in units], nbw="auto")


def test_joint_allocator_beats_uniform_within_cycle_budget():
    units = make_joint_units()
    budget = uniform_cycles(units, 4, 8)
    rep = sens.allocate_bits_joint(units, budget, group_size=32)
    assert rep.feasible
    assert rep.cycles_total <= budget * (1 + 1e-9)
    uniform_err = sum(u.errors[4] + u.aerrors[8] for u in units)
    assert rep.predicted_error <= uniform_err + 1e-12
    for wb, ab in rep.bits_by_unit.values():
        assert wb in (2, 3, 4, 5, 6, 8) and ab in SUPPORTED_ABITS


def test_joint_allocator_byte_budget_and_pins():
    units = make_joint_units(seed=3)
    budget = uniform_cycles(units, 6, 8)
    byte_budget = sum(sens.unit_bytes(u.k, u.n, 4, 32, u.copies)
                      for u in units)
    rep = sens.allocate_bits_joint(
        units, budget, group_size=32, byte_budget=byte_budget,
        pinned={("['w0']", None): 8}, pinned_act={("['w1']", None): 4})
    assert rep.bytes_total <= byte_budget
    assert rep.bits_by_unit[("['w0']", None)][0] == 8
    assert rep.bits_by_unit[("['w1']", None)][1] == 4


def test_joint_allocator_infeasible_budget_reports():
    units = make_joint_units(n=2)
    rep = sens.allocate_bits_joint(units, cycle_budget=1.0, group_size=32)
    assert not rep.feasible


def test_joint_allocator_requires_act_scores():
    u = sens.Unit(path="['w']", layer=None, k=64, n=64, copies=1,
                  errors={b: 1.0 for b in (2, 4, 8)})
    with pytest.raises(ValueError):
        sens.allocate_bits_joint([u], 1e9, group_size=32)


def test_calibrate_policy_joint_end_to_end():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    pol, rep = sens.calibrate_policy(
        params, cfg, base, match_uniform=4, tokens=toks,
        bits_candidates=(2, 4, 6, 8), abits_candidates=(4, 8))
    assert rep.feasible
    assert rep.cycles_total <= rep.cycle_budget * (1 + 1e-9)
    assert pol.allocation is not None and pol.allocation.act_per_path
    qtree, _, _ = quantize_params(params, pol)
    abits_seen = {qt.abits for _, qt in iter_qtensors(qtree)}
    assert abits_seen <= {4, 8}
    toks2 = jnp.asarray([[1, 2, 3]])
    logits, _ = lm.prefill(qtree, toks2, cfg, cache_len=8)
    assert np.isfinite(np.asarray(logits)).all()


# ---------------------------------------------------------------------------
# real-calibration-data hook
# ---------------------------------------------------------------------------

def test_tokens_from_calib_batches():
    a = np.zeros((2, 8), np.int32)
    b = np.ones((3, 8), np.int32)
    toks = sens._tokens_from_calib_batches([a, b])
    assert toks.shape == (5, 8)
    with pytest.raises(ValueError):
        sens._tokens_from_calib_batches([a, np.ones((2, 4), np.int32)])


def test_calibrate_policy_uses_heldout_batches():
    """The allocation must respond to the calibration data distribution:
    held-out batches concentrated on a few tokens vs broad random text
    probe different activation paths and move bits."""
    cfg = tiny_cfg(n_layers=2)
    params = tiny_params(cfg, seed=1)
    base = QuantPolicy(bits=4, **POLICY)
    narrow = [np.full((4, 16), 3, np.int32)]
    broad = [np.asarray(jax.random.randint(jax.random.PRNGKey(s),
                                           (4, 16), 0, cfg.vocab))
             for s in (0, 1)]
    pol_n, rep_n = sens.calibrate_policy(params, cfg, base,
                                         match_uniform=3,
                                         calib_batches=narrow,
                                         bits_candidates=(2, 3, 4, 6))
    pol_b, rep_b = sens.calibrate_policy(params, cfg, base,
                                         match_uniform=3,
                                         calib_batches=broad,
                                         bits_candidates=(2, 3, 4, 6))
    assert rep_n.feasible and rep_b.feasible
    assert rep_n.bits_by_unit != rep_b.bits_by_unit


# ---------------------------------------------------------------------------
# segment cap (compile-cost regression)
# ---------------------------------------------------------------------------

def scan_count(qtree, cfg, toks):
    """Number of lax.scan bodies the forward compiles — one per segment,
    each a separately traced/compiled computation."""
    jaxpr = jax.make_jaxpr(lambda p: lm.forward(p, toks, cfg)[0])(qtree)
    return sum(1 for e in jaxpr.jaxpr.eqns if e.primitive.name == "scan")


def test_segment_count_drives_compiled_scan_bodies():
    cfg = tiny_cfg(n_layers=4)
    params = tiny_params(cfg)
    toks = jnp.asarray([[1, 2, 3]])
    counts = {}
    for name, spec in {1: (4, 4, 4, 4), 2: (4, 4, 8, 8),
                       4: (4, 8, 4, 8)}.items():
        alloc = BitAllocation(
            per_path={"['blocks']['attn']['wq']": spec})
        qtree, _, _ = quantize_params(
            params, QuantPolicy(bits=4, allocation=alloc, **POLICY))
        counts[name] = scan_count(qtree, cfg, toks)
    # trace/compile cost grows linearly with segment count — the
    # regression the allocator's max_segments cap exists to bound
    assert counts == {1: 1, 2: 2, 4: 4}


def test_enforce_max_segments_cap_and_losslessness():
    units = []
    for p in ("['blocks']['a']", "['blocks']['b']"):
        for layer in range(4):
            units.append(sens.Unit(
                path=p, layer=layer, k=64, n=64, copies=1,
                errors={b: (layer + 1) * 4.0 ** (-b)
                        for b in (2, 4, 6, 8)}))
    # 3 natural segments: [0], [1, 2], [3]
    assign = {("['blocks']['a']", 0): 2, ("['blocks']['a']", 1): 4,
              ("['blocks']['a']", 2): 4, ("['blocks']['a']", 3): 6,
              ("['blocks']['b']", 0): 4, ("['blocks']['b']", 1): 4,
              ("['blocks']['b']", 2): 4, ("['blocks']['b']", 3): 4}
    assert sens.segment_count(assign) == 3
    # cap >= natural count: lossless identity
    same = sens.enforce_max_segments(units, assign, 3)
    assert same == assign
    # tighter cap: merged, within cap, every value adopted from the
    # original assignment of an adjacent segment (never invented)
    capped = sens.enforce_max_segments(units, assign, 2)
    assert sens.segment_count(capped) <= 2
    assert set(capped) == set(assign)
    for (p, layer), b in capped.items():
        assert b in {assign[(p, i)] for i in range(4)}


def test_max_segments_validated():
    with pytest.raises(ValueError, match="maxseg"):
        sens.parse_bit_policy("auto:q4a8,maxseg=0")
    with pytest.raises(ValueError, match="max_segments"):
        sens.enforce_max_segments([], {}, 0)


def test_calibrate_policy_max_segments():
    cfg = tiny_cfg(n_layers=4)
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    scores = sens.output_sensitivity(params, cfg, toks, base,
                                     bits_candidates=(2, 3, 4, 6))
    free, rep_free = sens.calibrate_policy(
        params, cfg, base, match_uniform=4, scores=scores,
        bits_candidates=(2, 3, 4, 6))
    capped, rep_cap = sens.calibrate_policy(
        params, cfg, base, match_uniform=4, scores=scores,
        bits_candidates=(2, 3, 4, 6), max_segments=2)
    assert sens.segment_count(rep_cap.bits_by_unit) <= 2
    assert rep_cap.predicted_error >= rep_free.predicted_error - 1e-12
    # the report must stay honest after capping: feasible only if the
    # coalesced assignment still fits the budget it was solved under
    assert rep_cap.feasible == (rep_cap.bytes_total
                                <= rep_cap.budget_bytes)
    qtree, _, _ = quantize_params(params, capped)
    segs = (len(qtree["blocks"])
            if isinstance(qtree["blocks"], list) else 1)
    assert segs <= 2


def test_calibrate_policy_joint_enforces_bpw_byte_budget():
    """A budget_bpw request is an explicit byte budget: joint mode must
    enforce it, not silently allocate unbounded bytes."""
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    scores = sens.output_sensitivity(params, cfg, toks, base,
                                     bits_candidates=(2, 4, 8))
    act_scores = sens.activation_sensitivity(params, cfg, toks, base,
                                             abits_candidates=(4, 8))
    pol, rep = sens.calibrate_policy(
        params, cfg, base, budget_bpw=3.0, scores=scores,
        act_scores=act_scores, bits_candidates=(2, 4, 8),
        abits_candidates=(4, 8))
    assert rep.byte_budget is not None
    assert rep.bytes_total <= rep.byte_budget


def test_measured_prt_calibration_uses_embedding_activations():
    """With prt="measured" and calibration tokens, the hit rates must be
    simulated on the tokens' embedding activations (the real-data
    stand-in), not the fixed synthetic batch — the discount responds to
    the model/data instead of being a global constant."""
    from repro.core import pattern
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    toks = sens.calibration_tokens(cfg.vocab, 2, 8)
    emb = np.asarray(jnp.take(params["embed"], toks, axis=0), np.float32)
    emb = emb.reshape(-1, emb.shape[-1])[:8]
    d_emb = pattern.prt_discount(2, 8, 4, emb)
    d_syn = pattern.prt_discount(2, 8, 4, None)
    assert d_emb != d_syn   # distinct data -> distinct measured discount
    assert 0.0 <= d_emb <= 1.0


def test_calibrate_policy_joint_rejects_weight_mode():
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    with pytest.raises(ValueError, match="mode='output'"):
        sens.calibrate_policy(params, cfg, base, mode="weight",
                              abits_candidates=(4, 8))


def test_calibrate_policy_weight_only_rejects_measured_prt():
    """prt= shapes the joint cycle budget only; a weight-only call must
    fail loudly instead of silently ignoring the requested pricing."""
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    base = QuantPolicy(bits=4, **POLICY)
    with pytest.raises(ValueError, match="joint"):
        sens.calibrate_policy(params, cfg, base, prt="measured")


# ---------------------------------------------------------------------------
# checkpoint round-trip with activation allocation
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_activation_allocation():
    from repro.checkpoint import restore_quantized, save_quantized
    params = tiny_params()
    alloc = BitAllocation(
        per_path={"['blocks']['attn']['wq']": (6, 4)},
        act_per_path={"['blocks']['attn']['wq']": (8, 4),
                      "['blocks']['mlp']['w_up']": 6})
    pol = QuantPolicy(bits=4, allocation=alloc, act_bits=8, **POLICY)
    qtree, _, _ = quantize_params(params, pol)
    with tempfile.TemporaryDirectory() as d:
        save_quantized(d, 1, qtree, pol)
        back, _ = restore_quantized(d, params)
        orig = {p: (q.bits, q.abits) for p, q in iter_qtensors(qtree)}
        got = {p: (q.bits, q.abits) for p, q in iter_qtensors(back)}
        assert orig == got and any(ab == 4 for _, ab in got.values())
        for a, b in zip(jax.tree_util.tree_leaves(qtree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_engine_activation_bit_policy():
    from repro.serving.engine import Engine, EngineConfig
    cfg = tiny_cfg()
    params = tiny_params(cfg)
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=8, group_size=32,
        quant_kv=False, bit_policy="rules:mlp=4a6,default=6a8"))
    abits = {p: q.abits for p, q in iter_qtensors(eng.params)}
    assert abits["['blocks']['mlp']['w_up']"] == 6
    assert abits["['blocks']['attn']['wq']"] == 8
    assert eng.stats()["mixed_precision"]
    eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 4
