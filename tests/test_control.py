"""SloController state machine + its integration into Engine.step():
hysteresis/deadband, shed/shrink flipping exactly at the modeled
feasibility boundary, escalation-to-resolve only on PRT-delta movement,
and trace-replay determinism of the controlled engine."""

import warnings

import jax
import pytest

import repro.configs as C
from repro.models import lm
from repro.planning import Slo
from repro.serving.control import ControllerConfig, SloController
from repro.serving.engine import Engine, EngineConfig
from repro.serving.workload import ArrivalSpec, LengthDist, TraceSpec, generate

# --- pure state-machine tests (no model) ----------------------------------


def test_config_coerce_and_validation():
    assert ControllerConfig.coerce(True) == ControllerConfig()
    assert ControllerConfig.coerce({"deadband": 0.5}).deadband == 0.5
    cfg = ControllerConfig(cooldown=0)
    assert ControllerConfig.coerce(cfg) is cfg
    with pytest.raises(TypeError):
        ControllerConfig.coerce("yes")
    with pytest.raises(ValueError):
        ControllerConfig(check_every=0)
    with pytest.raises(ValueError):
        ControllerConfig(hysteresis=0)
    with pytest.raises(ValueError):
        ControllerConfig(deadband=-0.1)


def make_ctl(**kw):
    """Controller over a synthetic linear machine: t_iter(b) = b * 0.1s,
    window of 1 so each observation is its own drift sample."""
    defaults = dict(check_every=1, deadband=0.25, hysteresis=2, cooldown=0, window=1, warmup=0)
    defaults.update(kw)
    return SloController(
        ControllerConfig(**defaults),
        slo=Slo(20.0, batch=4),  # budget: 4/20 = 0.2 s/iteration
        iter_seconds=lambda b: b * 0.1,
        planned_tps=40.0,
    )


def test_drift_deadband_and_hysteresis():
    ctl = make_ctl()
    # first in-budget check only anchors (drift defined relative to it)
    assert ctl.observe(1, 0.1, 1) is False
    assert ctl.drift() == 0.0
    # within the deadband: never an action, oob stays reset
    assert ctl.observe(1, 0.11, 2) is False
    assert abs(ctl.drift()) < 0.25
    # one out-of-band check is not enough (hysteresis=2)...
    assert ctl.observe(1, 0.2, 3) is False
    # ...re-entering the band resets the consecutive count...
    assert ctl.observe(1, 0.1, 4) is False
    assert ctl.observe(1, 0.2, 5) is False
    # ...two consecutive out-of-band checks finally act
    assert ctl.observe(1, 0.2, 6) is True


def test_drift_is_occupancy_normalized():
    """Occupancy swings are not drift: halving the batch halves both the
    measured and the modeled seconds, so the anchored ratio is unmoved."""
    ctl = make_ctl()
    assert ctl.observe(4, 0.4, 1) is False  # anchor at occupancy 4
    assert ctl.observe(1, 0.1, 2) is False  # same machine, occupancy 1
    assert ctl.drift() == pytest.approx(0.0, abs=1e-9)


def test_cooldown_blocks_actions():
    ctl = make_ctl(hysteresis=1, cooldown=10)
    assert ctl.observe(1, 0.1, 1) is False  # anchor
    assert ctl.observe(1, 0.2, 2) is True
    ctl.acted("replan", 2)
    # still drifting, but the cooldown has not elapsed — and the window
    # was cleared, so a fresh out-of-band sample is needed anyway
    assert ctl.observe(1, 0.2, 5) is False
    assert ctl.observe(1, 0.2, 30) is True
    assert ctl.actions["replan"] == 1


def test_batch_cap_flips_at_feasibility_boundary():
    """budget 0.2s, t_iter(b) = 0.1b: feasible through b=2, infeasible
    from b=3 — the cap sits exactly on the meets_slo flip."""
    ctl = make_ctl()
    assert ctl.meets_slo_at(2) is True
    assert ctl.meets_slo_at(3) is False
    assert ctl.batch_cap(4) == 2
    assert ctl.actions["shrink"] == 1  # tightened below the pool once
    assert ctl.batch_cap(4) == 2  # cached: no double-count
    assert ctl.actions["shrink"] == 1


def test_batch_cap_unconstrained_without_slo():
    ctl = SloController(ControllerConfig(), slo=None, iter_seconds=lambda b: b * 0.1)
    assert ctl.batch_cap(4) == 4
    assert ctl.meets_slo_at(4) is None
    assert ctl.actions["shrink"] == 0


def test_batch_cap_floors_at_min_batch():
    ctl = SloController(
        ControllerConfig(min_batch=2),
        slo=Slo(100.0, batch=4),  # budget 0.04s: infeasible even at b=1
        iter_seconds=lambda b: b * 0.1,
    )
    assert ctl.batch_cap(4) == 2


def test_decide_escalates_only_on_prt_delta():
    ctl = make_ctl(resolve_hit_delta=0.02)
    ctl.plan_hit_rate = 0.50
    assert ctl.decide(tapped_hit_rate=0.51) == "replan"  # within delta
    assert ctl.decide(tapped_hit_rate=0.60) == "resolve"  # allocation moves
    assert ctl.decide(tapped_hit_rate=None) == "replan"  # no signal
    ctl.plan_hit_rate = None
    assert ctl.decide(tapped_hit_rate=0.9) == "replan"  # no reference


def test_acted_and_shed_bookkeeping():
    ctl = make_ctl()
    ctl.record_shed()
    ctl.record_shed(2)
    assert ctl.actions["shed"] == 3
    with pytest.raises(ValueError, match="unknown action"):
        ctl.acted("panic", 1)


def test_plan_changed_reanchors():
    ctl = make_ctl(hysteresis=1)
    assert ctl.observe(1, 0.1, 1) is False
    assert ctl.observe(1, 0.2, 2) is True
    ctl.plan_changed(iter_seconds=lambda b: b * 0.2, planned_tps=20.0)
    assert ctl.drift() is None
    # the next check anchors against the NEW model instead of acting
    assert ctl.observe(1, 0.2, 3) is False
    assert ctl.drift() == 0.0


# --- engine integration ---------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def bursty_trace(seed=7, n=10):
    return generate(
        TraceSpec(
            seed=seed,
            n_requests=n,
            vocab=255,
            prompt=LengthDist("uniform", low=4, high=12),
            output=LengthDist("constant", low=6, high=6),
            arrival=ArrivalSpec("bursty", gap=2.0, burst=5),
        )
    )


def drive(params, cfg, ecfg, trace):
    eng = Engine(params, cfg, ecfg)
    pending = sorted(trace.requests, key=lambda r: r.arrival_iteration)
    i = 0
    while i < len(pending) or not eng.sched.idle():
        while i < len(pending) and pending[i].arrival_iteration <= eng.iterations:
            eng.submit(list(pending[i].prompt), pending[i].max_new_tokens)
            i += 1
        if not eng.step() and i < len(pending):
            eng.submit(list(pending[i].prompt), pending[i].max_new_tokens)
            i += 1
    return eng


def make_ecfg(**kw):
    return EngineConfig(batch_size=4, cache_len=64, quantize=True, ql=4, group_size=32, **kw)


def test_stats_drift_without_controller(tiny):
    """The staleness signal is reported on a plain engine run."""
    cfg, params = tiny
    eng = Engine(params, cfg, make_ecfg(plan="uniform:4"))
    eng.submit([1, 2, 3], max_new_tokens=4)
    eng.run()
    st = eng.stats()
    assert st["controller"] is None
    assert st["measured_tps"] is not None and st["measured_tps"] > 0
    assert st["planned_tps"] is not None and st["planned_tps"] > 0
    assert st["modeled_run_tps"] is not None
    assert st["drift"] is not None


def test_controller_sheds_under_infeasible_slo(tiny):
    """An SLO above the served plan's own modeled capacity makes the
    full pool infeasible: the controller must shrink the cap and shed
    the burst's excess admissions (deferred, not dropped)."""
    cfg, params = tiny
    probe = Engine(params, cfg, make_ecfg(plan="uniform:4"))
    planned = probe.planned_tps()
    trace = bursty_trace()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # engine warns: SLO not met
        eng = drive(params, cfg, make_ecfg(plan="uniform:4", slo=planned * 1.5, controller=True),
                    trace)
    st = eng.stats()
    assert st["requests"] == len(trace.requests)  # sheds defer, never drop
    c = st["controller"]
    assert c["batch_cap"] < 4
    assert c["shrink"] >= 1
    assert c["shed"] >= 1
    ctl = eng.controller
    assert ctl.meets_slo_at(c["batch_cap"]) is True
    assert ctl.meets_slo_at(c["batch_cap"] + 1) is False


def test_controller_quiet_when_slo_feasible(tiny):
    """A comfortably feasible SLO must produce no occupancy action and,
    on steady traffic, no replans (drift stays inside the deadband)."""
    cfg, params = tiny
    probe = Engine(params, cfg, make_ecfg(plan="uniform:4"))
    planned = probe.planned_tps()
    trace = generate(
        TraceSpec(
            seed=11,
            n_requests=6,
            vocab=255,
            prompt=LengthDist("constant", low=6, high=6),
            output=LengthDist("constant", low=8, high=8),
            arrival=ArrivalSpec("fixed", gap=3.0),
        )
    )
    eng = drive(params, cfg, make_ecfg(plan="uniform:4", slo=planned * 0.5, controller=True,
                                       tap_capacity=32),
                trace)
    c = eng.stats()["controller"]
    assert c["batch_cap"] == 4
    assert c["shed"] == 0 and c["shrink"] == 0
    assert c["replan"] == 0 and c["resolve"] == 0


def test_controller_replans_on_forced_drift(tiny):
    """With a zero deadband every post-anchor check is out-of-band, so
    the drift loop must fire a replan through the tap."""
    cfg, params = tiny
    knobs = {"deadband": 0.0, "check_every": 1, "hysteresis": 1, "cooldown": 0, "warmup": 1}
    eng = drive(params, cfg, make_ecfg(plan="uniform:4", controller=knobs, tap_capacity=32),
                bursty_trace(n=6))
    c = eng.stats()["controller"]
    assert c["replan"] + c["resolve"] >= 1
    assert eng.replan_count >= 1
    assert eng.stats()["plan_hash"] is not None


def test_controlled_replay_is_deterministic(tiny):
    """Same trace + same engine config => token-identical output, even
    with the controller acting (its decisions are iteration-clocked,
    not wall-clocked... except drift, which only gates replans that
    re-price without changing tokens)."""
    cfg, params = tiny
    trace = bursty_trace(seed=3, n=8)
    outs = []
    for _ in range(2):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            eng = drive(params, cfg, make_ecfg(plan="uniform:4", controller=True,
                                               tap_capacity=32),
                        trace)
        outs.append({u: tuple(cc.tokens) for u, cc in eng.completions.items()})
    assert outs[0] == outs[1]
