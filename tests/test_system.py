"""End-to-end system behaviour: train -> quantize -> serve (the paper's
full deployment path), plus MoE dispatch equivalence and the HLO cost
analyzer used by the roofline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm, moe
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.optim.adamw import AdamW
from repro.serving.engine import Engine, EngineConfig


def test_train_quantize_serve_pipeline():
    """The full SAIL deployment story on a tiny model."""
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=2e-3)
    opt_state = opt.init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8))

    @jax.jit
    def step(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True)(p)
        u, o, _ = opt.update(g, o, p)
        return opt.apply(p, u), o, l

    losses = []
    for _ in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], "training must reduce loss"

    # deploy quantized (the SAIL serving configuration)
    eng = Engine(params, cfg, EngineConfig(batch_size=4, cache_len=64,
                                           quantize=True, ql=4,
                                           group_size=32, quant_kv=True))
    for i in range(4):
        eng.submit([i + 1, 5, 9], max_new_tokens=5)
    done = eng.run()
    assert len(done) == 4 and all(len(c.tokens) == 5 for c in done)

    # quantized model still assigns finite logits
    toks = jnp.asarray([[1, 5, 9]])
    lq, _ = lm.prefill(eng.params, toks, cfg, cache_len=16)
    assert np.isfinite(np.asarray(lq)).all()


def test_moe_dispatch_equals_dense_at_high_capacity():
    cfg = dataclasses.replace(C.get_smoke("mixtral_8x7b"),
                              capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model))
    yd, _ = moe.apply_moe_dense(p, x, cfg)
    yp, _ = moe.apply_moe_dispatch(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yd), rtol=2e-4,
                               atol=2e-5)


def test_moe_capacity_drops_bounded():
    cfg = C.get_smoke("granite_moe_1b_a400m")  # cf=1.25
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    yd, _ = moe.apply_moe_dense(p, x, cfg)
    yp, _ = moe.apply_moe_dispatch(p, x, cfg)
    # dropped tokens make outputs differ, but most tokens survive
    close = np.isclose(np.asarray(yp), np.asarray(yd), rtol=1e-3,
                       atol=1e-4).mean()
    assert close > 0.5


def test_hlo_cost_trip_counts():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.hlo_cost import analyze

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.ones((64, 64))
    ws = jnp.ones((7, 64, 64))
    txt = jax.jit(scanned).lower(x, ws).compile().as_text()
    r = analyze(txt)
    expect = 2 * 64 * 64 * 64 * 7
    assert r["flops"] == pytest.approx(expect, rel=0.05), r["flops"]


def test_sail_linear_backend_switch():
    from repro.models import sail_linear as sl
    from repro.core.quant import quantize
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    qt = quantize(w, 4, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
    sl.set_backend("jnp")
    y1 = sl.mm(x, qt)
    sl.set_backend("pallas")
    y2 = sl.mm(x, qt)
    sl.set_backend("jnp")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_quantize_params_compression_ratios():
    cfg = C.get_smoke("llama3_2_1b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prev = None
    for ql in (8, 4, 2):
        _, b0, b1 = quantize_params(params, QuantPolicy(
            bits=ql, group_size=32, min_size=1024))
        ratio = b0 / b1
        if prev is not None:
            assert ratio > prev  # fewer bits -> more compression
        prev = ratio
