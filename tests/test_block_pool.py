"""Paged KV block pool: BlockSpaceManager refcount/partition invariants
under random op sequences, copy-on-write prefix sharing, preemption, and
the paged engine's token-identity + equal-memory-concurrency guarantees
against the fixed-slot pool."""
import jax
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import repro.configs as C
from repro.models import lm
from repro.serving.block_pool import BlockSpaceManager
from repro.serving.engine import Engine, EngineConfig


# --- pure pool properties (no model) ----------------------------------------


def test_allocate_free_round_trip():
    mgr = BlockSpaceManager(num_blocks=8, block_size=4)
    table, shared = mgr.allocate(1, (1, 2, 3, 4, 5))
    assert len(table) == 2 and shared == 0
    assert mgr.used_blocks == 2 and mgr.free_blocks == 6
    mgr.check_invariants()
    mgr.free(1)
    assert mgr.used_blocks == 0 and mgr.free_blocks == 8
    mgr.check_invariants()


def test_duplicate_uid_and_double_free_raise():
    mgr = BlockSpaceManager(num_blocks=4, block_size=4)
    mgr.allocate(1, (1, 2))
    with pytest.raises(KeyError):
        mgr.allocate(1, (1, 2))
    mgr.free(1)
    with pytest.raises(KeyError):
        mgr.free(1)


def test_prefix_sharing_reuses_blocks():
    """Identical prompts share every full block AND the partial frontier;
    the sharer allocates zero fresh blocks."""
    mgr = BlockSpaceManager(num_blocks=8, block_size=4)
    prompt = (1, 2, 3, 4, 5, 6)        # 2 blocks, frontier half-full
    t1, sh1 = mgr.allocate(1, prompt)
    t2, sh2 = mgr.allocate(2, prompt)
    assert sh1 == 0 and sh2 == 2
    assert t1 == t2
    assert mgr.used_blocks == 2        # shared, not duplicated
    mgr.check_invariants()
    mgr.free(1)
    assert mgr.used_blocks == 2        # uid 2 still holds them
    mgr.free(2)
    assert mgr.used_blocks == 0


def test_divergent_prompts_share_only_common_blocks():
    mgr = BlockSpaceManager(num_blocks=16, block_size=4)
    mgr.allocate(1, (1, 2, 3, 4, 9, 9))
    _, sh = mgr.allocate(2, (1, 2, 3, 4, 7, 7))
    assert sh == 1                     # first full block only
    assert mgr.used_blocks == 3
    mgr.check_invariants()


def test_append_inplace_alloc_and_cow():
    """The three append outcomes: within the frontier block (inplace), at
    a block boundary (alloc), and into a SHARED block (copy-on-write)."""
    mgr = BlockSpaceManager(num_blocks=8, block_size=4)
    prompt = (1, 2, 3, 4, 5, 6)
    mgr.allocate(1, prompt)
    mgr.allocate(2, prompt)
    # uid 1 writes position 6: inside the shared frontier block -> COW
    kind, src, dst = mgr.append_slot(1, 6)
    assert kind == "cow" and src != dst
    assert mgr.table(1)[1] == dst and mgr.table(2)[1] == src
    mgr.check_invariants()
    # uid 2 writes position 6: it is now the SOLE owner of src -> inplace
    res = mgr.append_slot(2, 6)
    assert res[0] == "inplace"
    # position 8 crosses a boundary -> fresh block
    kind, _, blk = mgr.append_slot(1, 8)
    assert kind == "alloc" and mgr.table(1)[2] == blk
    mgr.check_invariants()
    mgr.free(1)
    mgr.free(2)
    assert mgr.free_blocks == 8


def test_append_oom_returns_none():
    mgr = BlockSpaceManager(num_blocks=2, block_size=4)
    mgr.allocate(1, (1, 2, 3, 4, 5, 6, 7, 8))
    assert mgr.append_slot(1, 8) is None      # pool dry -> caller preempts
    mgr.preempt(1)
    assert mgr.free_blocks == 2 and mgr.stats()["preemptions"] == 1


def test_truncate_releases_rollback_tail():
    """Speculative rollback: truncate drops every table entry past the
    accepted frontier, returns the drop count, and is refcount-aware —
    a shared block survives until its last owner lets go."""
    mgr = BlockSpaceManager(num_blocks=8, block_size=4)
    mgr.allocate(1, tuple(range(1, 11)))      # 10 tokens -> 3 blocks
    assert mgr.truncate(1, 10) == 0           # frontier kept: no-op
    assert mgr.truncate(1, 5) == 1            # back to 2 blocks
    assert len(mgr.table(1)) == 2 and mgr.used_blocks == 2
    mgr.check_invariants()
    # regrow over the truncated tail: the freed block is reusable
    kind, _, _ = mgr.append_slot(1, 8)
    assert kind == "alloc" and mgr.used_blocks == 3
    mgr.check_invariants()
    # shared tail: the sharer's truncate must NOT free the owner's block
    mgr2 = BlockSpaceManager(num_blocks=8, block_size=4)
    prompt = (1, 2, 3, 4, 5, 6, 7, 8)
    mgr2.allocate(1, prompt)
    mgr2.allocate(2, prompt)                  # shares both blocks
    assert mgr2.truncate(2, 4) == 1
    assert mgr2.used_blocks == 2              # uid 1 still holds block 2
    assert len(mgr2.table(1)) == 2
    mgr2.check_invariants()
    mgr2.free(1)
    mgr2.free(2)
    assert mgr2.used_blocks == 0


def test_truncate_to_zero_frees_everything():
    mgr = BlockSpaceManager(num_blocks=4, block_size=4)
    mgr.allocate(7, (1, 2, 3, 4, 5))
    assert mgr.truncate(7, 0) == 2
    assert mgr.table(7) == [] and mgr.used_blocks == 0
    mgr.check_invariants()


def test_admission_cap_is_a_conservative_lower_bound():
    """admission_cap ignores intra-batch sharing (documented), so it
    lower-bounds actual admissions; once the registrant's blocks exist,
    the estimate prices sharers correctly (zero fresh blocks each)."""
    mgr = BlockSpaceManager(num_blocks=5, block_size=4)
    prompts = [(1, 2, 3, 4, 5)] * 3
    assert mgr.admission_cap(prompts) == 2    # 2 + 2 fresh, third won't fit
    mgr.allocate(0, prompts[0])
    # registry now holds both blocks: every sharer prices at 0 fresh
    assert mgr.admission_cap(prompts[1:]) == 2
    admitted = 1
    for uid, p in enumerate(prompts[1:], start=1):
        assert mgr.can_allocate(p)
        mgr.allocate(uid, p)
        admitted += 1
    assert admitted == 3 and mgr.used_blocks == 2
    mgr.check_invariants()


@settings(max_examples=30)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5),
                              st.integers(1, 9)), min_size=1, max_size=60))
def test_invariants_under_random_op_soup(ops):
    """No leak, no double-count, registry in sync: after ANY interleaving
    of allocate/append/preempt/free, the free+used partition and the
    refcount-vs-table-ownership equality hold; freeing every survivor
    returns the pool to fully free."""
    mgr = BlockSpaceManager(num_blocks=12, block_size=4)
    live = {}
    next_uid = 0
    for op, which, plen in ops:
        if op == 0:                                   # allocate
            prompt = tuple(range(1, plen + 1))
            if mgr.can_allocate(prompt):
                mgr.allocate(next_uid, prompt)
                live[next_uid] = plen
                next_uid += 1
        elif op == 1 and live:                        # append one position
            uid = sorted(live)[which % len(live)]
            res = mgr.append_slot(uid, live[uid])
            if res is not None:
                live[uid] += 1
        elif op == 2 and live:                        # preempt
            uid = sorted(live)[which % len(live)]
            mgr.preempt(uid)
            del live[uid]
        elif op == 3 and live:                        # complete
            uid = sorted(live)[which % len(live)]
            mgr.free(uid)
            del live[uid]
        mgr.check_invariants()
    for uid in list(live):
        mgr.free(uid)
    assert mgr.used_blocks == 0
    assert mgr.free_blocks == mgr.num_blocks
    mgr.check_invariants()


# --- engine integration ------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine(tiny, batch=4, **kw):
    cfg, params = tiny
    return Engine(params, cfg, EngineConfig(
        batch_size=batch, cache_len=64, quantize=True, ql=4,
        group_size=32, quant_kv=True, mode="continuous", **kw))


PREFIX = [5, 9, 2, 4, 11, 3, 8, 1]
PROMPTS = [PREFIX + [7, 6], PREFIX + [10, 12], PREFIX + [7, 6],
           [1, 2, 3], PREFIX + [13, 14, 15], PREFIX + [7, 6]]


def _serve(eng, prompts, max_new=6):
    uids = [eng.submit(list(p), max_new) for p in prompts]
    eng.run()
    return {u: eng.completions[u].tokens for u in uids}


def test_paged_tokens_identical_to_slot_pool(tiny):
    """The tentpole guarantee: gather/scatter through block tables is a
    layout change, not a numerics change — greedy outputs match the
    contiguous slot pool token for token."""
    ref = _serve(make_engine(tiny), PROMPTS)
    got = _serve(make_engine(tiny, kv_block_size=8), PROMPTS)
    assert got == ref


def test_prefix_sharing_token_identity_and_hits(tiny):
    """Requests sharing a prefix attend to the REGISTRANT'S blocks; that
    must be invisible in the output, and the pool must record the hits."""
    eng = make_engine(tiny, kv_block_size=8)
    got = _serve(eng, PROMPTS)
    ref = _serve(make_engine(tiny, kv_block_size=8, share_prefix=False),
                 PROMPTS)
    assert got == ref
    st_ = eng.block_mgr.stats()
    assert st_["shared_hits"] > 0
    assert st_["used_blocks"] == 0            # everything returned
    eng.block_mgr.check_invariants()


def test_preemption_resumes_with_identical_tokens(tiny):
    """A pool too small for the offered load forces preemption; the
    evicted request re-prefills from its committed tokens and must finish
    with exactly the unpreempted output."""
    ref = _serve(make_engine(tiny), PROMPTS, max_new=8)
    eng = make_engine(tiny, kv_block_size=8, kv_pool_blocks=7)
    got = _serve(eng, PROMPTS, max_new=8)
    assert got == ref
    assert eng.block_mgr.stats()["preemptions"] > 0
    assert any("resumed_iteration" in ev for ev in eng.events.values())


def test_equal_memory_admits_more_with_sharing(tiny):
    """The gate property at test scale: at one fixed KV byte budget, the
    paged pool with prefix sharing holds strictly more requests in
    flight than the slot pool."""
    prompts = [PREFIX + [i, i + 1] for i in range(8)]
    slot = make_engine(tiny, batch=2)          # 2 slots x 64 tokens
    _serve(slot, prompts)
    paged = make_engine(tiny, batch=8, kv_block_size=8,
                        kv_pool_blocks=16)     # same bytes: 16 x 8 tokens
    _serve(paged, prompts)
    assert paged.stats()["peak_active"] > slot.stats()["peak_active"]


def test_paged_rejects_oversized_and_wrong_mode(tiny):
    cfg, params = tiny
    eng = make_engine(tiny, kv_block_size=8)
    with pytest.raises(ValueError):
        eng.submit(list(range(60)), 10)        # 70 > 64-token lane
    with pytest.raises(ValueError):
        Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=64, quantize=False, mode="batch",
            kv_block_size=8))


def test_kv_bits_plan_threads_to_engine_and_stats(tiny):
    """PlanSpec.kv_bits overrides EngineConfig.quant_kv and lands in
    stats(); the paged pool prices its budget at that precision."""
    from repro import planning
    cfg, params = tiny
    eng = Engine(params, cfg, EngineConfig(
        batch_size=4, cache_len=64, quantize=True, ql=4, group_size=32,
        quant_kv=False, mode="continuous", kv_block_size=8,
        plan="uniform:4,kv=8"))
    assert eng.stats()["kv_bits"] == 8
    assert eng.cache["layers"]["k"].dtype == np.int8
    spec = planning.PlanSpec.parse("uniform:4,kv=8")
    assert planning.PlanSpec.from_json(spec.to_json()) == spec
    assert planning.PlanSpec.parse("auto:q4,kv=auto").solved is False
    # int8 KV prices below f32: more blocks per byte budget
    k8 = planning.kv_pool_blocks(1 << 20, 8, 2, 4, 64, 8)
    k32 = planning.kv_pool_blocks(1 << 20, 8, 2, 4, 64, 32)
    assert k8 > k32


def test_kv_auto_resolves_via_sensitivity_probe(tiny):
    """kv=auto makes the spec unsolved; Planner.solve probes per-layer
    KV quantization error and pins a concrete 8 or 32."""
    from repro import planning
    cfg, params = tiny
    plan = planning.PlanSpec.parse("uniform:4,kv=auto")
    result = planning.Planner(params, cfg, plan).solve()
    assert result.spec.kv_bits in (8, 32)
    assert result.spec.solved
    sens = result.kv_sensitivity
    assert sens is not None and sens["relative"] >= 0
    assert len(sens["per_layer"]) == lm.n_scan_blocks(cfg)
