"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import quant
from repro.kernels.decode_attn import ops as da_ops, ref as da_ref
from repro.kernels.lut_gemv import ops as lut_ops, ref as lut_ref
from repro.kernels.typeconv import ops as tc_ops


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 8])
@pytest.mark.parametrize("mkn", [(8, 256, 128), (3, 130, 70), (16, 512, 384),
                                 (1, 64, 512)])
def test_lut_matmul_sweep(bits, mkn):
    m, k, n = mkn
    gs = 64
    kk = -(-k // gs) * gs
    w = jax.random.normal(jax.random.PRNGKey(bits), (kk, n))
    qt = quant.quantize(w, bits, gs)
    x = jax.random.normal(jax.random.PRNGKey(m), (m, kk))
    y = lut_ops.lut_matmul(x, qt, backend="pallas", interpret=True)
    y_ref = lut_ref.lut_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_matmul_dtypes(dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    qt = quant.quantize(w, 4, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256)).astype(dtype)
    y = lut_ops.lut_matmul(x, qt, out_dtype=dtype, backend="pallas")
    y_ref = lut_ref.lut_matmul_ref(x, qt, out_dtype=dtype)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_lut_matmul_nf_codebook():
    from repro.core.quant import nf_codebook
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 64))
    qt = quant.quantize(w, 4, 64, codebook=nf_codebook(4))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    y = lut_ops.lut_matmul(x, qt, backend="pallas")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(lut_ref.lut_matmul_ref(x, qt)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n", [8, 16, 25])
def test_typeconv_kernel(n):
    lim = 1 << (n - 1)
    vals = np.random.default_rng(n).integers(
        -lim + 1, lim, size=777).astype(np.int32)
    out = tc_ops.int_to_f32(jnp.asarray(vals), n=n, backend="pallas")
    assert (np.asarray(out) == vals.astype(np.float32)).all()


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("window", [None, 48])
def test_decode_attn_sweep(quantized, window):
    key = jax.random.PRNGKey(0)
    b, h, kv, d, s = 2, 8, 2, 64, 200
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    lengths = jnp.array([150, 200], jnp.int32)
    if quantized:
        k, ks = quant.quantize_kv(k)
        v, vs = quant.quantize_kv(v)
    else:
        ks = vs = None
    out = da_ops.decode_attention(q, k, v, lengths, ks, vs, window=window,
                                  backend="pallas", bs=64)
    ref = da_ref.decode_attention_ref(q, k, v, lengths, ks, vs, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), m=st.integers(1, 9),
       kmul=st.integers(1, 3), n=st.integers(8, 130))
def test_property_lut_matmul(bits, m, kmul, n):
    k = 64 * kmul
    w = jax.random.normal(jax.random.PRNGKey(bits + m), (k, n))
    qt = quant.quantize(w, bits, 64)
    x = jax.random.normal(jax.random.PRNGKey(n), (m, k))
    y = lut_ops.lut_matmul(x, qt, backend="pallas")
    y_ref = lut_ref.lut_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)
