"""Hypothesis shim: real ``hypothesis`` when installed, otherwise a
minimal fixed-seed sample sweep with the same decorator surface.

Usage (drop-in for the subset this suite needs)::

    from _hyp import given, settings, strategies as st

The fallback draws ``max_examples`` deterministic samples per test (seeded
from the test name, so failures reproduce) and runs the test body once per
sample.  It implements ``integers``, ``sampled_from``, ``booleans``,
``floats``, ``just``, ``lists`` and ``tuples`` plus ``.map``/``.filter``
— enough for property-style tests without the dependency.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

except ImportError:
    import hashlib
    import types

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too restrictive")
            return _Strategy(draw)

    def _integers(min_value=None, max_value=None):
        lo = -(1 << 16) if min_value is None else int(min_value)
        hi = (1 << 16) if max_value is None else int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def _floats(min_value=-1e6, max_value=1e6, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def _just(value):
        return _Strategy(lambda rng: value)

    def _lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*strats):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

    strategies = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, booleans=_booleans,
        floats=_floats, just=_just, lists=_lists, tuples=_tuples)

    _DEFAULT_EXAMPLES = 10

    def given(**strats):
        def decorate(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the strategy parameters (it would treat them as
            # fixtures).
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_EXAMPLES)
                seed = int.from_bytes(hashlib.sha256(
                    fn.__qualname__.encode()).digest()[:4], "big")
                for i in range(n):
                    rng = np.random.default_rng((seed, i))
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__qualname__} failed on sweep sample "
                            f"{i}/{n}: {drawn!r}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._hyp_given = True
            return wrapper
        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def decorate(fn):
            if getattr(fn, "_hyp_given", False):
                fn._hyp_max_examples = max_examples
            return fn
        return decorate
