"""Docs stay verified: fenced python compiles, named repro.* symbols
import, intra-repo links resolve (the CI docs-check, run in-suite)."""
import os
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "tools"))

import check_docs  # noqa: E402


@pytest.mark.parametrize("path", check_docs.doc_files(),
                         ids=lambda p: os.path.relpath(p, _ROOT))
def test_doc_file_is_clean(path):
    assert os.path.exists(path), f"{path} missing"
    with open(path) as f:
        text = f.read()
    rel = os.path.relpath(path, _ROOT)
    errs = (check_docs.check_python_blocks(rel, text)
            + check_docs.check_symbols(rel, text)
            + check_docs.check_links(path, text))
    assert not errs, "\n".join(errs)
