"""Planning API: PlanSpec round-trips and grammar shims, SLO->budget
derivation, the DRAM-aware objective, Planner solve/replan, the
ActivationTap capture path, live plan swaps in the engine, checkpoint
plan provenance, and the joint-solver Pareto pruning regression."""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import sensitivity as sens
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.planning import (ActivationTap, DecodeCostModel, Planner,
                            PlanSpec, Slo)


def tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", vocab=64, d_model=32,
                n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu",
                attn_chunk=16, max_seq=128)
    base.update(kw)
    return ModelConfig(**base)


BASE = dict(group_size=32, min_size=1024)


@pytest.fixture(scope="module")
def tiny():
    cfg = tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def probes(tiny):
    """One set of sensitivity probes shared by every solver test."""
    cfg, params = tiny
    base = QuantPolicy(bits=4, **BASE)
    toks = sens.calibration_tokens(cfg.vocab, 2, 16)
    scores = sens.output_sensitivity(params, cfg, toks, base)
    act_scores = sens.activation_sensitivity(params, cfg, toks, base)
    return base, toks, scores, act_scores


# ---------------------------------------------------------------------------
# PlanSpec: JSON <-> grammar round-trips, shims
# ---------------------------------------------------------------------------

DOCUMENTED_SPECS = [
    "uniform:4",
    "uniform:4a8",
    "uniform:6",
    "rules:mlp=3,attn=5,default=4",
    "rules:mlp=4a6,attn=5a8,default=6a8",
    "rules:attn=5a6,mlp=3",
    "auto:q4",
    "auto:4.5bpw",
    "auto:q4a8",
    "auto:q4a8,prt=measured,maxseg=4",
    "auto:q4a8,prt=measured,slo=120",
]


@pytest.mark.parametrize("spec", DOCUMENTED_SPECS)
def test_planspec_grammar_and_json_roundtrip(spec):
    plan = PlanSpec.parse(spec)
    # grammar round-trip at the spec level (format() is canonical)
    assert PlanSpec.parse(plan.format()) == plan
    # JSON round-trip is exact
    assert PlanSpec.from_json(plan.to_json()) == plan
    # and file round-trip
    with tempfile.TemporaryDirectory() as d:
        plan.save(d + "/plan.json")
        assert PlanSpec.load(d + "/plan.json") == plan


def test_planspec_solved_json_roundtrip():
    plan = PlanSpec.parse("auto:q4a8,prt=measured").with_solution(
        {"['blocks']['mlp']['w_up']": (4, 4, 6, 6), "['lm_head']": 5},
        {"['blocks']['mlp']['w_up']": (8, 8, 6, 6), "['lm_head']": 8})
    assert plan.solved
    back = PlanSpec.from_json(plan.to_json())
    assert back == plan
    assert back.spec_hash == plan.spec_hash
    # the solved allocation has no grammar form, but the request does
    assert PlanSpec.parse(plan.format()).mode == "auto"


def test_planspec_validation():
    with pytest.raises(ValueError):
        PlanSpec(mode="nope")
    with pytest.raises(ValueError):
        PlanSpec(weight_bits=7)
    with pytest.raises(ValueError):
        PlanSpec(act_bits=5)
    with pytest.raises(ValueError):
        PlanSpec(prt="sometimes")
    with pytest.raises(ValueError):
        PlanSpec(max_segments=0)
    with pytest.raises(ValueError):
        PlanSpec(mode="uniform", weight_bits=None)
    with pytest.raises(ValueError):
        PlanSpec.parse("auto:q4a8,prt=sometimes")
    with pytest.raises(ValueError):
        PlanSpec.parse("uniform:4b8")


@pytest.mark.parametrize("spec", DOCUMENTED_SPECS)
def test_parse_bit_policy_shim_equivalence(spec):
    """The deprecated shim returns PlanSpec.parse's legacy dict form,
    with a DeprecationWarning."""
    with pytest.warns(DeprecationWarning):
        legacy = sens.parse_bit_policy(spec)
    assert legacy == PlanSpec.parse(spec).to_legacy_dict()
    # and the legacy dict itself round-trips into the same plan
    assert PlanSpec.from_legacy_dict(legacy) == PlanSpec.parse(spec)


def test_resolve_bit_policy_shim(tiny):
    cfg, params = tiny
    base = QuantPolicy(bits=4, **BASE)
    with pytest.warns(DeprecationWarning):
        pol = sens.resolve_bit_policy("uniform:6a8", params, cfg, base)
    assert pol.bits == 6 and pol.act_bits == 8
    with pytest.warns(DeprecationWarning):
        pol = sens.resolve_bit_policy("rules:mlp=4a6,default=6a8",
                                      params, cfg, base)
    assert pol.act_rules == (("mlp", 6),) and pol.act_bits == 8
    # the PlanSpec path produces the identical policy
    assert pol == PlanSpec.parse("rules:mlp=4a6,default=6a8").to_policy(base)


def test_planspec_policy_bridge_roundtrip():
    base = QuantPolicy(bits=4, **BASE)
    for spec in ("uniform:6a8", "rules:mlp=2a4,default=6"):
        pol = PlanSpec.parse(spec).to_policy(base)
        again = PlanSpec.from_policy(pol).to_policy(base)
        assert again == pol


def test_legacy_act_only_rules_preserved():
    """resolve_bit_policy applied rules and act_rules independently; an
    act_rules pattern with no weight rule must survive the PlanSpec
    bridge instead of being silently dropped."""
    base = QuantPolicy(bits=4, **BASE)
    legacy = {"mode": "rules", "rules": [("attn", 4)],
              "act_rules": [("mlp", 6)]}
    plan = PlanSpec.from_legacy_dict(legacy)
    pol = plan.to_policy(base)
    assert pol.rules == (("attn", 4),)
    assert pol.act_rules == (("mlp", 6),)
    assert PlanSpec.from_legacy_dict(plan.to_legacy_dict()) == plan
    assert PlanSpec.from_json(plan.to_json()) == plan
    # act-only rule tokens have a grammar form too
    assert PlanSpec.parse(plan.format()) == plan
    assert PlanSpec.parse("rules:mlp=a6,default=4").rules == (
        planning_rule("mlp", None, 6),)
    with pytest.raises(ValueError):
        PlanSpec.parse("rules:mlp=,default=4")


def planning_rule(pattern, wb, ab):
    from repro.planning import PlanRule
    return PlanRule(pattern, wb, ab)


def test_plan_cost_prices_cycles_at_the_quoted_batch(tiny):
    """evaluate(batch=) must reprice the whole iteration at that batch —
    lookup cycles scale with it — never divide batch-32 tokens by a
    batch-8 iteration time."""
    cfg, params = tiny
    cost = DecodeCostModel(batch=8)
    pol = QuantPolicy(bits=4, act_bits=8, **BASE)
    c8 = cost.evaluate(params, pol)
    c32 = cost.evaluate(params, pol, batch=32)
    assert c32.cycles > c8.cycles
    assert c32 == DecodeCostModel(batch=32).evaluate(params, pol)


# ---------------------------------------------------------------------------
# SLO -> budgets and the DRAM-aware objective
# ---------------------------------------------------------------------------

def test_slo_budget_derivation_monotone():
    cost = DecodeCostModel()
    targets = [10.0, 100.0, 1000.0, 10000.0]
    budgets = [cost.budgets(Slo(t, batch=8), fixed_bytes=4096)
               for t in targets]
    for lo, hi in zip(budgets, budgets[1:]):
        # a higher tokens/s target can only shrink both budgets
        assert hi.cycle_budget < lo.cycle_budget
        assert hi.byte_budget < lo.byte_budget
        assert hi.seconds < lo.seconds
    # exact decomposition: meeting both budgets implies meeting the SLO
    b = budgets[1]
    tps = cost.tokens_per_second(b.cycle_budget, b.byte_budget + 4096,
                                 batch=8)
    assert tps >= 100.0 * (1 - 1e-9)
    # the SLO is infeasible when fixed bytes alone exceed the stream
    with pytest.raises(ValueError):
        cost.budgets(Slo(1e18, batch=1), fixed_bytes=1 << 40)


def test_dram_term_penalizes_byte_heavy_plans(tiny):
    """The DRAM-aware objective: at equal-ish cycles, a byte-heavy plan
    loses once t_dram dominates — and the legacy compute-only model
    cannot see the difference."""
    cfg, params = tiny
    machine = dataclasses.replace(cm.SailMachine(), dram_bw=1e9)
    dram = DecodeCostModel(machine=machine)
    legacy = DecodeCostModel(machine=machine, include_dram=False)
    q4 = QuantPolicy(bits=4, act_bits=8, **BASE)
    q8 = QuantPolicy(bits=8, act_bits=8, **BASE)
    c4, c8 = dram.evaluate(params, q4), dram.evaluate(params, q8)
    assert c8.quant_bytes > c4.quant_bytes
    assert c8.dram_bound
    assert c8.tokens_per_second < c4.tokens_per_second
    # compute-only pricing: 8-bit lookups cost MORE cycles, but the gap
    # is the compute ratio, not the byte ratio — the byte-heavy penalty
    # under DRAM must exceed what cycles alone explain
    l4, l8 = legacy.evaluate(params, q4), legacy.evaluate(params, q8)
    assert l4.t_dram == 0.0 and l4.fixed_bytes == 0
    # once DRAM-bound, throughput tracks the byte footprint exactly —
    # the term the compute-only model was blind to
    assert c4.dram_bound and c8.dram_bound
    dram_ratio = c4.tokens_per_second / c8.tokens_per_second
    byte_ratio = c8.total_bytes / c4.total_bytes
    assert dram_ratio == pytest.approx(byte_ratio, rel=1e-9)
    assert legacy.evaluate(params, q8).t_dram == 0.0


def test_slo_solve_meets_target_and_dominates_fixed_budget(tiny, probes):
    """The bench's --slo --check claim, asserted at test scale: the
    SLO-derived plan meets its target under the DRAM-aware model and
    reaches lower predicted error than the byte-blind fixed-cycle-budget
    solve at equal modeled throughput."""
    cfg, params = tiny
    base, toks, scores, act_scores = probes
    machine = dataclasses.replace(cm.SailMachine(), dram_bw=2e9)
    cost = DecodeCostModel(machine=machine, prt="paper")
    bpol, brep = sens.calibrate_policy(
        params, cfg, base, match_uniform=4, match_uniform_abits=8,
        abits_candidates=sens.SUPPORTED_ABITS, scores=scores,
        act_scores=act_scores, machine=machine)
    bcost = cost.evaluate(params, bpol)
    planner = Planner(params, cfg, PlanSpec.parse("auto:q4a8"), base=base,
                      cost=cost, tokens=toks, scores=scores,
                      act_scores=act_scores)
    res = planner.solve(slo=Slo(bcost.tokens_per_second, batch=8))
    assert res.meets_slo
    assert res.cost.tokens_per_second >= bcost.tokens_per_second * (1 - 1e-9)
    assert res.report.predicted_error <= brep.predicted_error + 1e-12
    # the solved spec is self-contained: rebuilding the policy from its
    # JSON serves the identical tree
    back = PlanSpec.from_json(res.spec.to_json()).to_policy(base)
    assert back.allocation == res.policy.allocation


def test_slo_solve_error_monotone_in_target(tiny, probes):
    cfg, params = tiny
    base, toks, scores, act_scores = probes
    machine = dataclasses.replace(cm.SailMachine(), dram_bw=2e9)
    planner = Planner(params, cfg, PlanSpec.parse("auto:q4a8"), base=base,
                      cost=DecodeCostModel(machine=machine),
                      tokens=toks, scores=scores, act_scores=act_scores)
    ref = DecodeCostModel(machine=machine).evaluate(
        params, dataclasses.replace(base, act_bits=8))
    errs = []
    for frac in (0.5, 0.75, 1.0):
        res = planner.solve(slo=Slo(ref.tokens_per_second * frac, batch=8))
        errs.append(res.report.predicted_error)
    # tighter SLO (higher target) -> shrinking budgets -> error rises
    assert errs[0] <= errs[1] + 1e-12 <= errs[2] + 2e-12


# ---------------------------------------------------------------------------
# joint-solver Pareto pruning (ROADMAP scaling item)
# ---------------------------------------------------------------------------

def saturating_units(n_layers=32, paths=("a", "b", "c", "d", "e", "f"),
                     k=64, n=64, seed=0):
    """Synthetic calibration-shaped units at 32-layer/200-unit scale with
    realistic saturating error ladders (several wide precisions reach the
    same floor — exactly where dominated states appear)."""
    rng = np.random.default_rng(seed)
    units = []
    for p in paths:
        for layer in range(n_layers):
            sc = float(rng.uniform(0.5, 2.0))
            asc = float(rng.uniform(0.1, 0.5))
            errors = {b: sc * max(2.0 ** -b, 2.0 ** -5) for b in (2, 3, 4, 5, 6, 8)}
            aerrors = {ab: asc * max(2.0 ** -ab, 2.0 ** -6) for ab in (4, 6, 8)}
            units.append(sens.Unit(path=f"['{p}']", layer=layer, k=k, n=n,
                                   copies=1, errors=errors,
                                   aerrors=aerrors))
    return units


def test_pareto_pruning_identical_allocations_and_bounded_candidates():
    units = saturating_units()
    assert len(units) == 192    # ~200-unit/32-layer scale
    full = [(wb, ab) for wb in (2, 3, 4, 5, 6, 8) for ab in (4, 6, 8)]
    # bounded candidate count: saturation makes {6,8}-bit weight states
    # and 8-bit act states dominated wherever the floor is reached
    total = 0
    for u in units[:24]:
        kept = sens.pareto_state_filter(
            full, lambda s: u.errors[s[0]] + u.aerrors[s[1]],
            lambda s: s[0] * s[1])   # any cost monotone in both bits
        assert len(kept) < len(full)
        total += len(kept)
    assert total <= 24 * 10    # vs 24 * 18 unpruned
    # identical allocations with pruning on and off, across budgets
    ref_cycles = sens.allocate_bits_joint(units, 1e12, 32).cycles_total
    for frac in (0.5, 0.8):
        a = sens.allocate_bits_joint(units, ref_cycles * frac, 32,
                                     prune_states=True)
        b = sens.allocate_bits_joint(units, ref_cycles * frac, 32,
                                     prune_states=False)
        assert a.bits_by_unit == b.bits_by_unit
        assert a.predicted_error == b.predicted_error


def test_pareto_pruning_identity_on_real_probes(tiny, probes):
    """The smoke-config regression: pruned and unpruned solves agree on
    real sensitivity scores (pruning only removes states that cannot
    appear in any improving move)."""
    cfg, params = tiny
    base, toks, scores, act_scores = probes
    units = []
    flat = {jax.tree_util.keystr(p): w
            for p, w in jax.tree_util.tree_flatten_with_path(params)[0]}
    for key, errs in scores.items():
        path, layer = key
        w = flat[path]
        copies = int(w.shape[0]) if (layer is None and w.ndim > 2) else 1
        units.append(sens.Unit(path=path, layer=layer,
                               k=int(w.shape[-2]), n=int(w.shape[-1]),
                               copies=copies, errors=errs,
                               aerrors=act_scores[key]))
    ref = sens.allocate_bits_joint(units, 1e12, 32).cycles_total
    a = sens.allocate_bits_joint(units, ref * 0.6, 32, prune_states=True)
    b = sens.allocate_bits_joint(units, ref * 0.6, 32, prune_states=False)
    assert a.bits_by_unit == b.bits_by_unit


# ---------------------------------------------------------------------------
# ActivationTap + engine integration
# ---------------------------------------------------------------------------

def test_tap_capture_shapes_and_capacity():
    tap = ActivationTap(capacity=8)
    xs = np.arange(2 * 3 * 1 * 4, dtype=np.float32).reshape(2, 3, 1, 4)
    mask = np.array([True, False, True])
    tap.observe(xs, mask)
    assert tap.n_layers == 2 and len(tap) == 2    # masked lane dropped
    for _ in range(10):
        tap.observe(xs, mask)
    assert len(tap) == 8                          # ring capacity
    calib = tap.calib()
    assert set(calib) == {0, 1, None}
    assert calib[0].shape == (8, 4) and calib[None].ndim == 2
    tap.clear()
    assert tap.calib() is None


def test_engine_tap_captures_decode_inputs(tiny):
    cfg, params = tiny
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        plan="uniform:4a8", tap_capacity=64))
    eng.submit([1, 2, 3], max_new_tokens=6)
    eng.submit([4, 5], max_new_tokens=6)
    eng.run()
    assert eng.tap.n_layers == cfg.n_layers
    calib = eng.tap.calib()
    assert calib[0].shape[1] == cfg.d_model
    assert eng.stats()["tapped_rows"] == eng.tap.rows_seen > 0


def test_engine_token_identity_across_live_replan_swap(tiny):
    """Requantizing mid-serve under the same plan must not disturb a
    single token: the KV pool and scheduler state survive the swap."""
    cfg, params = tiny
    from repro.serving.engine import Engine, EngineConfig

    def run(swap_iterations=()):
        eng = Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=32, quantize=True, ql=4,
            group_size=32, plan="uniform:4a8", tap_capacity=32))
        eng.submit([1, 2, 3], max_new_tokens=8)
        eng.submit([4, 5, 6, 7], max_new_tokens=8)
        while True:
            more = eng.step()
            if eng.iterations in swap_iterations:
                # force the full requantize-and-swap path (same-policy
                # swaps are otherwise skipped as no-ops)
                eng.apply_plan(eng.plan, force_requantize=True)
            if not more:
                break
        return {c.uid: c.tokens for c in eng.completions.values()}, eng

    ref, _ = run()
    swapped, eng = run(swap_iterations=(3, 5))
    assert swapped == ref
    assert eng.replan_count == 2
    assert eng.stats()["replan_count"] == 2


def test_engine_replan_measures_prt_from_traffic(tiny):
    cfg, params = tiny
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        plan="uniform:4a8", tap_capacity=64))
    eng.submit([1, 2, 3], max_new_tokens=8)
    eng.run()
    res = eng.replan()
    assert 0.0 <= res.measured_prt_hit_rate <= 1.0
    st = eng.stats()
    assert st["replan_count"] == 1
    assert st["prt_hit_rate"] == res.measured_prt_hit_rate
    assert eng.plan.prt == "measured"
    # the swap kept the uniform serving plan's allocation semantics
    assert eng.quant_policy.bits == 4


def test_engine_plan_equals_legacy_bit_policy(tiny):
    cfg, params = tiny
    from repro.serving.engine import Engine, EngineConfig
    e_plan = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        plan="rules:mlp=2,default=6"))
    with pytest.warns(DeprecationWarning):
        e_legacy = Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=32, quantize=True, ql=4,
            group_size=32, bit_policy="rules:mlp=2,default=6"))
    assert e_plan.quant_policy == e_legacy.quant_policy
    with pytest.raises(ValueError):
        Engine(params, cfg, EngineConfig(
            quantize=True, plan="uniform:4",
            bit_policy="uniform:4"))
    with pytest.raises(ValueError):
        Engine(params, cfg, EngineConfig(quantize=False, plan="uniform:4"))


def test_engine_serves_solved_plan_without_recalibration(tiny, probes):
    """A solved auto plan (plan.json contents) must rebuild the exact
    policy with no Planner run — the deploy-time path."""
    cfg, params = tiny
    base, toks, scores, act_scores = probes
    from repro.serving.engine import Engine, EngineConfig
    planner = Planner(params, cfg, PlanSpec.parse("auto:q4a8"), base=base,
                      tokens=toks, scores=scores, act_scores=act_scores)
    res = planner.solve()
    eng = Engine(params, cfg, EngineConfig(
        batch_size=2, cache_len=32, quantize=True, ql=4, group_size=32,
        plan=res.spec.to_json()))
    assert eng.quant_policy.allocation == res.policy.allocation
    eng.submit([1, 2, 3], max_new_tokens=3)
    assert len(eng.run()) == 1


def test_engine_warns_on_unreachable_slo(tiny):
    """An SLO the served plan cannot meet must never pass silently —
    whether the plan arrived pre-solved or the solve just missed."""
    cfg, params = tiny
    from repro.serving.engine import Engine, EngineConfig
    with pytest.warns(UserWarning, match="below the requested SLO"):
        Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=32, quantize=True, ql=4,
            group_size=32, plan="uniform:4a8", slo=1e12))
    with pytest.warns(UserWarning, match="tap_capacity is ignored"):
        Engine(params, cfg, EngineConfig(
            batch_size=2, cache_len=32, quantize=True, ql=4,
            group_size=32, mode="batch", tap_capacity=8))


def test_legacy_auto_dict_with_solver_kwargs(tiny):
    """resolve_bit_policy forwarded arbitrary calibrate_policy kwargs in
    auto dicts; the compat shim must keep doing so."""
    cfg, params = tiny
    base = QuantPolicy(bits=4, **BASE)
    with pytest.warns(DeprecationWarning):
        pol = sens.resolve_bit_policy(
            {"mode": "auto", "match_uniform": 4, "calib_batch": 2,
             "calib_seq": 8, "bits_candidates": (3, 4, 6)},
            params, cfg, base)
    assert pol.allocation is not None


# ---------------------------------------------------------------------------
# checkpoint plan provenance
# ---------------------------------------------------------------------------

def test_checkpoint_carries_plan(tiny, probes):
    cfg, params = tiny
    base, toks, scores, act_scores = probes
    from repro import checkpoint as ckpt
    planner = Planner(params, cfg, PlanSpec.parse("auto:q4a8"), base=base,
                      tokens=toks, scores=scores, act_scores=act_scores)
    res = planner.solve()
    qtree, _, _ = quantize_params(params, res.policy)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_quantized(d, 0, qtree, res.policy, plan=res.spec)
        restored, extras = ckpt.restore_quantized(d, params)
        plan = ckpt.restored_plan(extras)
        assert plan == res.spec
        assert plan.spec_hash == res.spec.spec_hash
        # plan alone rebuilds the identical policy
        assert plan.to_policy(base).allocation == res.policy.allocation
        # and when no plan is passed, one is derived from the policy,
        # recording the caller's KV flag faithfully
        ckpt.save_quantized(d, 1, qtree, res.policy, quant_kv=False)
        _, extras1 = ckpt.restore_quantized(d, params, step=1)
        derived = ckpt.restored_plan(extras1)
        assert derived is not None and derived.quant_kv is False
        assert derived.to_policy(base).allocation == res.policy.allocation


# ---------------------------------------------------------------------------
# per-layer calibration plumbing
# ---------------------------------------------------------------------------

def test_per_layer_calib_reaches_solver(tiny, probes):
    """A per-layer calib mapping must price units at their own layer's
    hit rate — layers fed pathologically repetitive activations get a
    deeper discount than layers fed noise."""
    rng = np.random.default_rng(0)
    noise = rng.standard_normal((8, 32)).astype(np.float32)
    constant = np.ones((8, 32), np.float32)
    from repro.core.pattern import calib_for_layer, prt_hit_rate
    calib = {0: constant, 1: noise, None: noise}
    assert calib_for_layer(calib, 0) is constant
    assert calib_for_layer(calib, 5) is noise      # fallback
    assert calib_for_layer(noise, 3) is noise      # plain arrays pass
    h_const = prt_hit_rate(4, 8, constant)
    h_noise = prt_hit_rate(4, 8, noise)
    assert h_const > h_noise
    cost = DecodeCostModel(prt="measured", calib=calib)
    c0 = cost.unit_cycles(32, 32, 4, 8, layer=0)
    c1 = cost.unit_cycles(32, 32, 4, 8, layer=1)
    assert c0 < c1
