"""Per-architecture smoke tests + decode/forward consistency (the
assignment's reduced-config requirement) + SAIL quantized-serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import encdec, lm
from repro.models.sail_linear import QuantPolicy, quantize_params

ARCHS = C.ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = C.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        params = encdec.init_params(key, cfg)
        frames = jax.random.normal(key, (2, cfg.enc_seq, cfg.d_model))
        toks = jax.random.randint(key, (2, 9), 0, cfg.vocab)
        loss, _ = encdec.loss_fn(params, {"frames": frames, "tokens": toks},
                                 cfg)
        assert np.isfinite(float(loss))
        cache = encdec.serve_prefill(params, frames, cfg, cache_len=16)
        logits, cache = encdec.serve_decode_step(params, toks[:, :1], cache,
                                                 cfg)
        assert logits.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        return
    params = lm.init_params(key, cfg)
    pre = (jax.random.normal(key, (2, cfg.vision_tokens, cfg.d_model))
           if cfg.frontend == "vision" else None)
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab)
    loss, _ = lm.loss_fn(params, {"tokens": toks, "prefix_embeds": pre}, cfg)
    assert np.isfinite(float(loss))
    logits, cache = lm.prefill(params, toks[:, :-1], cfg, cache_len=32,
                               prefix_embeds=pre)
    logits, cache = lm.decode_step(params, toks[:, :1], cache, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["llama3_2_1b", "qwen3_0_6b", "hymba_1_5b",
                                  "mixtral_8x7b", "xlstm_350m",
                                  "granite_moe_1b_a400m"])
def test_decode_matches_forward(arch):
    """Prefill+decode must reproduce teacher-forced logits (KV-cache
    correctness — ring buffer, RoPE offsets, SSM/xLSTM state carry)."""
    cfg = C.get_smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits_full, _ = lm.forward(params, toks, cfg, moe_mode="dense")
    logits_p, cache = lm.prefill(params, toks[:, :8], cfg, cache_len=32)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_full[:, 7]),
                               rtol=2e-4, atol=2e-4)
    for t in range(8, 12):
        logits_d, cache = lm.decode_step(params, toks[:, t:t + 1], cache,
                                         cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_windowed_ring_cache_matches_full():
    """SWA arch: decoding with a window-sized ring cache must equal
    decoding with a full-length cache (window masking correctness)."""
    cfg = dataclasses.replace(C.get_smoke("h2o_danube_3_4b"), window=16)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 30), 0, cfg.vocab)
    _, cache_full = lm.prefill(params, toks[:, :8], cfg, cache_len=64)
    _, cache_ring = lm.prefill(params, toks[:, :8], cfg, cache_len=16)
    for t in range(8, 30):
        lf, cache_full = lm.decode_step(params, toks[:, t:t + 1],
                                        cache_full, cfg)
        lr, cache_ring = lm.decode_step(params, toks[:, t:t + 1],
                                        cache_ring, cfg)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=2e-4, atol=2e-4)


def test_quant_kv_decode_close():
    cfg = C.get_smoke("llama3_2_1b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    lf, cf = lm.prefill(params, toks[:, :8], cfg, cache_len=32)
    lq, cq = lm.prefill(params, toks[:, :8], cfg, cache_len=32,
                        quant_kv=True)
    # int8 KV: small relative error on logits
    denom = float(jnp.abs(lf).max())
    assert float(jnp.abs(lq - lf).max()) / denom < 0.08
    lf2, _ = lm.decode_step(params, toks[:, 8:9], cf, cfg)
    lq2, _ = lm.decode_step(params, toks[:, 8:9], cq, cfg, quant_kv=True)
    assert float(jnp.abs(lq2 - lf2).max()) / denom < 0.1


@pytest.mark.parametrize("ql", [2, 4, 8])
def test_sail_quantized_serving(ql):
    """Full SAIL path: quantized weights + quantized KV still decode to
    finite, vocab-shaped logits; Q8 stays close to f32."""
    cfg = C.get_smoke("tinymistral_248m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp, b0, b1 = quantize_params(params, QuantPolicy(bits=ql, group_size=32,
                                                     min_size=1024))
    assert b1 < b0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    lf, cf = lm.prefill(params, toks, cfg, cache_len=16)
    lq, cq = lm.prefill(qp, toks, cfg, cache_len=16)
    assert np.isfinite(np.asarray(lq)).all()
    if ql == 8:
        corr = np.corrcoef(np.asarray(lf).ravel(), np.asarray(lq).ravel())
        assert corr[0, 1] > 0.98
    lg, _ = lm.decode_step(qp, toks[:, :1], cq, cfg)
    assert lg.shape == (2, cfg.vocab) and np.isfinite(np.asarray(lg)).all()


def test_param_count_formula():
    for arch in ["llama2_7b", "llama2_13b", "mixtral_8x7b"]:
        cfg = C.get_config(arch)
        target = {"llama2_7b": 6.74e9, "llama2_13b": 13.0e9,
                  "mixtral_8x7b": 46.7e9}[arch]
        assert abs(cfg.param_count() - target) / target < 0.08, \
            (arch, cfg.param_count())
    mx = C.get_config("mixtral_8x7b")
    assert abs(mx.active_param_count() - 12.9e9) / 12.9e9 < 0.15
