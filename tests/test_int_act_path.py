"""The realized int-activation serve path.

Proves the executed datapath matches the ``abits`` semantics the
allocator prices: quantized activation codes enter the Pallas LUT-GEMV
kernel directly (dequant fused into the LUT build, per-token scale at
the accumulator store), bit-exact against the jnp oracle across the
(wbits x abits) grid — no fake-quant anywhere in the serve path — and
decode through the engine is token-identical across backends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.lut_gemv import ops as lut_ops
from repro.kernels.lut_gemv import ref as lut_ref
from repro.models import lm, sail_linear
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, einsum_q, mm

# Single-block shape (bm=8, bk=256, bn=256): no padding, one K step, so
# kernel and oracle run the identical f32 op sequence -> bitwise equal.
ALIGNED = (8, 256, 256)
GS = 64


def _qt(wbits, abits, k, n, gs=GS, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    return dataclasses.replace(quant.quantize(w, wbits, gs), abits=abits)


# ---------------------------------------------------------------------------
# kernel grid: pallas int path == jnp oracle, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wbits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("abits", [4, 6, 8, None])
def test_kernel_grid_bit_exact(wbits, abits):
    m, k, n = ALIGNED
    qt = _qt(wbits, abits, k, n, seed=wbits)
    x = jax.random.normal(jax.random.PRNGKey(17), (m, k))
    y = lut_ops.lut_matmul(x, qt, backend="pallas", interpret=True)
    assert y.shape == (m, n)
    if abits is None:
        y_ref = lut_ref.lut_matmul_ref(x, qt)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-6, atol=1e-6)
    else:
        xq, xs = quant.quantize_activations(x, abits)
        y_ref = lut_ref.lut_matmul_ref_int(xq, xs, qt)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.parametrize("abits", [4, 8])
def test_kernel_unaligned_shapes(abits):
    # m/n off the block grid: padding uses zero activation codes (exactly
    # zero contribution) so the valid slice still matches the oracle
    m, k, n = 3, 96, 100
    qt = _qt(4, abits, k, n, gs=32)
    x = jax.random.normal(jax.random.PRNGKey(5), (m, k))
    y = lut_ops.lut_matmul(x, qt, backend="pallas", interpret=True)
    xq, xs = quant.quantize_activations(x, abits)
    y_ref = lut_ref.lut_matmul_ref_int(xq, xs, qt)
    assert y.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_jnp_backend_is_the_int_oracle():
    m, k, n = ALIGNED
    qt = _qt(4, 8, k, n)
    x = jax.random.normal(jax.random.PRNGKey(2), (m, k))
    y = lut_ops.lut_matmul(x, qt, backend="jnp")
    xq, xs = quant.quantize_activations(x, 8)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(lut_ref.lut_matmul_ref_int(xq, xs, qt)))


def test_int_path_is_not_fake_quant():
    """Serve semantics are (x_q @ W) * s — scale after the int matmul —
    not (x_q * s) @ W fake-quant.  The two differ by f32 rounding."""
    m, k, n = ALIGNED
    qt = _qt(4, 4, k, n)
    x = jax.random.normal(jax.random.PRNGKey(3), (m, k))
    got = np.asarray(lut_ops.lut_matmul(x, qt, backend="jnp"))
    xq, xs = quant.quantize_activations(x, 4)
    oracle = np.asarray(lut_ref.lut_matmul_ref_int(xq, xs, qt))
    fake = np.asarray(lut_ref.lut_matmul_ref(
        (xq.astype(jnp.float32) * xs), qt))
    np.testing.assert_array_equal(got, oracle)
    if not np.array_equal(fake, oracle):      # rounding almost surely differs
        assert not np.array_equal(got, fake)


# ---------------------------------------------------------------------------
# model entry points: mm / einsum_q dispatch to the int path on abits
# ---------------------------------------------------------------------------

def test_mm_serves_int_path():
    m, k, n = ALIGNED
    qt = _qt(3, 6, k, n)
    x = jax.random.normal(jax.random.PRNGKey(7), (m, k))
    got = mm(x, qt)
    xq, xs = quant.quantize_activations(x, 6)
    want = lut_ref.lut_matmul_ref_int(xq, xs, qt, out_dtype=x.dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mm_leading_dims_int_path():
    qt = _qt(4, 8, 64, 32, gs=32)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 64))
    got = mm(x, qt)
    assert got.shape == (2, 3, 32)
    xq, xs = quant.quantize_activations(x.reshape(-1, 64), 8)
    want = lut_ref.lut_matmul_ref_int(xq, xs, qt).reshape(2, 3, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_einsum_q_int_path_moe_spec():
    # the dispatch einsum: x[t,d] x experts[e,d,f] -> y[t,e,f]
    e, d, f = 2, 64, 32
    w = jax.random.normal(jax.random.PRNGKey(11), (e, d, f))
    pol = QuantPolicy(bits=4, group_size=32, min_size=1)
    st = sail_linear._quantize_stacked(w, 4, pol, abits=8)
    x = jax.random.normal(jax.random.PRNGKey(12), (3, d))
    got = einsum_q("td,edf->tef", x, st)
    wd = sail_linear.dequantize_any(st)
    xq, xs = quant.quantize_activations(x, 8)
    y = jnp.einsum("td,edf->tef", xq.astype(jnp.float32), wd)
    want = (y * xs[..., 0][:, None, None]).astype(x.dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_einsum_scale_to_out_mapping():
    xs = jnp.arange(6, dtype=jnp.float32).reshape(3, 2, 1) + 1.0
    out = sail_linear._einsum_scale_to_out("ted,edf->tef", (3, 2, 64), xs)
    assert out is not None and out.shape == (3, 2, 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xs))
    # contracted subscript in the output -> not mappable, caller folds
    assert sail_linear._einsum_scale_to_out(
        "td,de->tde", (3, 64), xs[:, 0]) is None


def test_apply_act_quant_only_unwraps_probes():
    """Fake-quant survives only inside the ActQuantWeight probe; a plain
    QTensor passes through mm with activations untouched until the kernel."""
    qt = _qt(4, 8, 64, 32, gs=32)
    x = jax.random.normal(jax.random.PRNGKey(13), (4, 64))
    x2, w2 = sail_linear._apply_act_quant(x, qt)
    assert x2 is x and w2 is qt
    probe = sail_linear.ActQuantWeight(
        w=jnp.eye(64), gate=jnp.asarray(1.0), abits=8)
    x3, w3 = sail_linear._apply_act_quant(x, probe)
    np.testing.assert_array_equal(
        np.asarray(x3), np.asarray(sail_linear.act_fake_quant(x, 8)))
    assert isinstance(w3, jax.Array)


# ---------------------------------------------------------------------------
# engine decode under an a<b> plan: token-identical across backends
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return ModelConfig(name="tiny", family="dense", vocab=64, d_model=32,
                       n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu",
                       attn_chunk=16, max_seq=128)


def test_engine_decode_token_identity_across_backends():
    from repro.serving.engine import Engine, EngineConfig
    cfg = _tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    def decode(backend):
        sail_linear.set_backend(backend)
        try:
            eng = Engine(params, cfg, EngineConfig(
                batch_size=2, cache_len=32, quantize=True, ql=8,
                group_size=32, quant_kv=False,
                plan="rules:mlp=4a6,default=6a8"))
            abits = {q.abits for _, q in _iter_qtensors(eng.params)}
            assert abits & {4, 6, 8}      # the int path is actually in play
            eng.submit([1, 2, 3], max_new_tokens=6)
            done = eng.run()
            assert len(done) == 1
            return list(done[0].tokens)
        finally:
            sail_linear.set_backend("jnp")

    assert decode("jnp") == decode("pallas")


def _iter_qtensors(tree, prefix=""):
    from repro.core.quant import QTensor
    from repro.models.sail_linear import StackedQTensor
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, (QTensor, StackedQTensor)))[0]
    for path, leaf in flat:
        if isinstance(leaf, (QTensor, StackedQTensor)):
            yield jax.tree_util.keystr(path), leaf
