"""Continuous-batching serving engine over a fixed pool of KV-cache slots.

The paper's serving contribution (Sec. III-A) is iteration-based
scheduling: ONE model iteration serves every active user, so each layer's
weights are streamed once and reused batch-wide (weight temporal locality
— in the LLC on the paper's machine, in VMEM on TPU).  This engine makes
that iteration the scheduling quantum, Orca/vLLM-style:

  * ``init_cache`` allocates a fixed ``[max_batch, cache_len]`` KV pool
    once; requests are prefilled *into* free slots
    (``lm.prefill_into_slot``) and retired per-slot, so the batch never
    reshapes and the decode step compiles exactly once;
  * every ``step()`` admits waiting requests into free slots (FIFO, with
    a Sarathi-style cap on new prefill tokens per iteration), appends
    each active request's pending token, retires slots on EOS/max-tokens,
    and runs one masked decode iteration for all remaining slots;
  * a request arriving mid-decode joins the very next iteration instead
    of waiting for the cohort to drain — the weight-reuse window the
    paper optimizes is never wasted on a partially idle batch.

``mode="batch"`` keeps the old run-to-completion loop (admit a cohort,
decode it to the end, admit again) for A/B comparison — see
``benchmarks/serve_bench.py``.

Runs the SAIL path: weights SAIL-quantized (QTensor), KV cache optionally
int8.  Precision comes from a ``repro.planning.PlanSpec``
(``EngineConfig.plan`` / ``slo``; the engine always reports one —
``stats()["plan_hash"]``); with ``tap_capacity > 0`` an ``ActivationTap``
captures per-layer decode inputs and ``Engine.replan()`` recalibrates
measured PRT discounts from live traffic, hot-swapping the requantized
weights under the running KV pool.

The loop closes itself: ``EngineConfig.controller`` attaches a
``repro.serving.control.SloController`` that runs inside ``step()`` —
admissions are shed (deferred) and the decode batch shrunk to the
largest occupancy at which the plan's modeled iteration time still
meets the SLO, and ``replan()`` fires automatically when measured-vs-
modeled tokens/s drift leaves the deadband (with hysteresis, escalating
to a full re-solve only when the tapped PRT hit rate moved).  Every
engine — controller or not — reports ``measured_tps`` / ``planned_tps``
/ ``drift`` in ``stats()`` so a stale calibration is visible.  The
engine is synchronous and deterministic; streaming consumers hook
``submit(..., on_token=...)``.

``EngineConfig.kv_block_size`` swaps the slot pool for a *paged* block
pool (``lm.init_paged_cache`` + ``repro.serving.block_pool``): requests
hold per-lane block tables into a shared pool instead of a worst-case
``cache_len`` row, identical prompt prefixes share blocks copy-on-write,
and admission is gated on free blocks with recompute-style preemption
under pressure.  Paged-mode invariants:

- the pool reserves one extra physical *trash* block; every masked or
  retired lane's table entries point at it, so dead scatter writes never
  corrupt a live block;
- a paged lane never wraps: ``submit`` rejects requests whose
  prompt+max_new exceed the table capacity, which is what lets the paged
  and ring attention paths share one validity formula;
- shared prefix blocks are never rewritten — prefill scatters of a
  sharing request are trash-redirected over the shared span, and the
  first divergent write triggers copy-on-write — so sharers always
  attend to bit-identical KV;
- preemption is recompute-style and lossless: the victim's blocks are
  freed, its committed tokens become the resume prompt (re-prefilled on
  re-admission, front of the FIFO), and under greedy sampling the
  resumed request produces exactly the tokens it would have unpreempted.

KV precision is plan-driven: a ``PlanSpec.kv_bits`` of 8/32 (or
``"auto"``, resolved by the Planner's per-layer KV probe) overrides
``EngineConfig.quant_kv``; the pool's dtype is fixed at construction and
``apply_plan`` warns rather than reallocating mid-serve.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import DECODE, IterationScheduler, Request
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, quantize_params


@functools.partial(jax.jit, static_argnames=("seed", "temperature"))
def _sample_rows_jit(logits, uids, indices, seed: int, temperature: float):
    """One categorical draw per row under a per-row key folded from
    (engine seed, request uid, per-request sample index) — sampling that
    depends only on WHICH token of WHICH request is being drawn, never on
    global iteration count or batch composition."""
    base = jax.random.PRNGKey(seed)

    def draw(uid, idx, row):
        key = jax.random.fold_in(jax.random.fold_in(base, uid), idx)
        return jax.random.categorical(key, row / temperature)

    return jax.vmap(draw)(uids, indices, logits)


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8            # KV-pool slots (paper: 8 balances the pipe)
    cache_len: int = 4096
    quantize: bool = True
    ql: int = 4
    group_size: int = 128
    quant_kv: bool = True
    min_size: int = 1024           # quantize tensors >= this many elements
    # Precision plan: a repro.planning.PlanSpec (possibly solved / loaded
    # from plan.json), a grammar string ("uniform:<b>[a<ab>]",
    # "rules:<regex>=<b>[a<ab>],...", "auto:q<b>[a<ab>][,prt=...]
    # [,maxseg=<n>][,slo=<tps>]", "auto:<f>bpw"), or a PlanSpec JSON
    # dict.  Unsolved auto plans run the Planner at engine construction.
    plan: Any = None
    # target decode tokens/s at ``batch_size`` — makes an auto ``plan``
    # an SLO solve (cycle AND DRAM-byte budgets derived from the target);
    # set without ``plan`` it implies "auto:q<ql>a8,prt=measured".
    slo: Optional[float] = None
    # >0 attaches a repro.planning.ActivationTap of that row capacity:
    # every ``tap_every``-th decode iteration's per-layer block inputs
    # are captured for online PRT recalibration (Engine.replan).
    tap_capacity: int = 0
    tap_every: int = 1
    # keep the raw f32 weights resident so apply_plan/replan can
    # requantize mid-serve.  None (default) retains them exactly when a
    # tap is attached; set True for tap-less hot-swapping, False to
    # reclaim the memory even with a tap (replan then raises).
    retain_raw: Optional[bool] = None
    # autonomous SLO control loop: True (defaults), a knob dict, or a
    # repro.serving.control.ControllerConfig.  The controller sheds /
    # shrinks occupancy against the SLO and gates replans on measured-
    # vs-modeled drift (continuous mode only).
    controller: Any = None
    # DEPRECATED legacy surface (use ``plan``): None, QuantPolicy, policy
    # spec dict, or grammar string.
    bit_policy: Any = None
    eos_token: int = -1            # -1: never stop early
    temperature: float = 0.0       # 0 = greedy
    # PRNG root for temperature>0 sampling.  Tokens are drawn with a key
    # folded from (seed, request uid, per-request sample index), so a
    # request's sampled sequence is invariant to batch composition,
    # sheds, preemption/resume, and slot-vs-paged pool layout.
    seed: int = 0
    mode: str = "continuous"       # "continuous" | "batch" (run-to-completion)
    prefill_budget: Optional[int] = None  # new prefill tokens per iteration
    prompt_bucket: int = 16        # prompts padded to a multiple (compile reuse)
    # Paged KV pool (continuous mode, attention families).  Setting a
    # block size replaces the fixed [batch, cache_len] slot pool with a
    # shared pool of fixed-size blocks managed by a
    # repro.serving.block_pool.BlockSpaceManager: per-request block
    # tables, copy-on-write prefix sharing, block-gated admission, and
    # recompute-style preemption under pressure.
    kv_block_size: Optional[int] = None   # tokens per block; None = slot pool
    # pool sizing (first match wins): explicit block count, a byte budget
    # priced via planning.kv_pool_blocks, else batch_size slot-equivalents
    kv_pool_blocks: Optional[int] = None
    kv_budget_bytes: Optional[int] = None
    share_prefix: bool = True      # COW-share identical prompt prefixes
    preempt: bool = True           # evict newest request when the pool runs dry
    # Tensor-parallel serving (repro.serving.distributed): shard the
    # quantized weight tree over ``tp`` model-parallel shards and run
    # decode/prefill under shard_map.  A plan carrying a concrete
    # ``tp=``/``wire=`` dimension overrides these knobs.  tp > 1
    # requires mode="continuous", no tap, no draft, and tp visible
    # devices (CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N
    # before importing jax).
    tp: int = 1
    wire: int = 32                 # all-reduce bits: 32 exact, 8 compressed


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    latency_s: float
    ttft_s: float = 0.0            # submit -> first token available


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig):
        from repro import planning
        assert ecfg.mode in ("continuous", "batch"), ecfg.mode
        self.cfg = cfg
        self.ecfg = ecfg
        self.quant_policy: Optional[QuantPolicy] = None
        self.plan: Optional[planning.PlanSpec] = None
        self.plan_report = None
        self.replan_count = 0
        self.prt_hit_rate: Optional[float] = None
        self.tap: Optional[planning.ActivationTap] = None
        self.controller = None
        self.slo: Optional[planning.Slo] = None
        self._raw_params = None
        # plan pricing state (units + fixed DRAM bytes captured while the
        # raw tree is in hand; iteration-seconds memoized per occupancy)
        self._plan_units = None
        self._plan_fixed_bytes = 0
        self._iter_cache: Dict[Any, float] = {}
        # measured decode throughput (stats()["measured_tps"] / drift)
        self.decode_seconds = 0.0
        self._decode_tokens = 0
        # modeled seconds of the SAME iterations at their true occupancy
        # — the occupancy-matched reference side of stats()["drift"]
        self.modeled_seconds = 0.0
        if (ecfg.bit_policy is not None or ecfg.plan is not None) \
                and not ecfg.quantize:
            raise ValueError("a precision plan requires quantize=True")
        if ecfg.plan is not None and ecfg.bit_policy is not None:
            raise ValueError("pass plan= OR the deprecated bit_policy=, "
                             "not both")
        if ecfg.slo is not None and ecfg.bit_policy is not None:
            raise ValueError("slo= requires plan= — the deprecated "
                             "bit_policy surface has no SLO semantics "
                             "and would silently ignore the target")
        if ecfg.tap_capacity > 0:
            if ecfg.mode == "continuous":
                self.tap = planning.ActivationTap(ecfg.tap_capacity,
                                                  ecfg.tap_every)
            else:
                warnings.warn(
                    "tap_capacity is ignored in mode='batch' — the "
                    "ActivationTap hooks the continuous engine's masked "
                    "decode iteration", UserWarning, stacklevel=2)
        if ecfg.quantize:
            base = self._base_policy()
            plan_in = ecfg.plan
            if plan_in is None and ecfg.bit_policy is None \
                    and ecfg.slo is not None:
                # bare --slo: joint SLO solve anchored at the engine's ql
                plan_in = planning.PlanSpec(
                    mode="auto", weight_bits=ecfg.ql, act_bits=8,
                    prt="measured", quant_kv=ecfg.quant_kv)
            if plan_in is not None:
                plan_obj = planning.as_plan(plan_in)
                # SLOs are quoted at this engine's decode batch, whether
                # they arrive via EngineConfig.slo or the plan itself
                target = (ecfg.slo if ecfg.slo is not None
                          else plan_obj.target_tps)
                slo = (planning.Slo(target, batch=ecfg.batch_size)
                       if target is not None else None)
                self.slo = slo
                result = planning.resolve_plan(
                    plan_obj, params, cfg, base=base, slo=slo,
                    compute_cost=plan_obj.solved and slo is not None)
                if (slo is not None and result.cost is not None
                        and result.cost.tokens_per_second
                        < slo.target_tps * (1 - 1e-9)):
                    # an SLO the served plan cannot meet — whether it
                    # arrived pre-solved or the solver just found the
                    # budgets infeasible — must never pass silently
                    feas = getattr(result.report, "feasible", True)
                    warnings.warn(
                        f"plan {result.spec.spec_hash} models "
                        f"{result.cost.tokens_per_second:.1f} tok/s at "
                        f"batch {slo.batch}, below the requested SLO of "
                        f"{slo.target_tps:.1f}"
                        + ("" if feas else " (solver budgets infeasible "
                           "even at minimum precision)")
                        + "; lower the target, raise the batch, or "
                        "re-solve (Engine.replan(resolve=True))",
                        UserWarning, stacklevel=2)
                policy = result.policy
                self.plan = result.spec
                self.plan_report = result.report
            elif ecfg.bit_policy is not None:
                warnings.warn(
                    "EngineConfig.bit_policy is deprecated; use "
                    "EngineConfig.plan (a repro.planning.PlanSpec, "
                    "grammar string, or plan JSON)", DeprecationWarning,
                    stacklevel=2)
                from repro.core.sensitivity import _resolve_policy_like
                policy = _resolve_policy_like(ecfg.bit_policy, params,
                                              cfg, base)
                self.plan = planning.PlanSpec.from_policy(
                    policy, quant_kv=ecfg.quant_kv)
            else:
                policy = base
                self.plan = planning.PlanSpec.from_policy(
                    policy, quant_kv=ecfg.quant_kv)
            self.quant_policy = policy
            # price the plan while the raw tree is still in hand — the
            # cost-model units and fixed DRAM bytes behind planned_tps()
            # and the controller's occupancy cap
            self._plan_units = planning.policy_units(params, policy)
            self._plan_fixed_bytes = planning.unquantized_bytes(params,
                                                               policy)
            retain = (ecfg.retain_raw if ecfg.retain_raw is not None
                      else self.tap is not None)
            if retain:
                # a 7B-class model keeps ~28 GB of f32 resident here —
                # only pay it when hot-swap requantization is wanted
                self._raw_params = params
            self.params, b0, b1 = quantize_params(params, policy)
            self.compression = b0 / max(b1, 1)
        else:
            self.params, self.compression = params, 1.0
        # KV precision: the plan's kv_bits dimension (when concrete — the
        # Planner resolves "auto" before the spec reaches here) overrides
        # the legacy quant_kv flag; the pool dtype is fixed from here on.
        kvb = (self.plan.kv_bits
               if self.plan is not None
               and isinstance(self.plan.kv_bits, int) else None)
        self.kv_bits = kvb if kvb is not None else (8 if ecfg.quant_kv
                                                    else 32)
        self._quant_kv = self.kv_bits == 8
        # Tensor-parallel serving: a concrete plan tp=/wire= wins over
        # the EngineConfig knobs (the plan is the precision contract —
        # the Planner may have spent shards instead of bits to meet the
        # SLO).  The mesh is fixed for the engine's lifetime.
        plan_tp = (self.plan.tp if self.plan is not None
                   and isinstance(self.plan.tp, int) else None)
        plan_wire = (self.plan.wire if self.plan is not None
                     and self.plan.wire is not None else None)
        self.tp = plan_tp if plan_tp is not None else int(ecfg.tp)
        self.wire_bits = (plan_wire if plan_wire is not None
                          else int(ecfg.wire))
        self.tp_serving = None
        if self.tp > 1:
            from repro.serving.distributed import TPServing
            if ecfg.mode != "continuous":
                raise ValueError("tensor-parallel serving requires "
                                 "mode='continuous'")
            if self.tap is not None:
                raise ValueError(
                    "tensor-parallel serving and an ActivationTap cannot "
                    "coexist — the shard_map decode body has no "
                    "capture path")
            self.tp_serving = TPServing(cfg, self.tp, self.wire_bits)
            self.params = self.tp_serving.shard_params(self.params)
        self.sched = IterationScheduler(target_batch=ecfg.batch_size,
                                        max_batch=ecfg.batch_size,
                                        prefill_budget=ecfg.prefill_budget)
        self._uid = 0
        self.completions: Dict[int, Completion] = {}
        self._gen: Dict[int, List[int]] = {}
        self._t0: Dict[int, float] = {}
        self._ttft: Dict[int, float] = {}
        self._on_token: Dict[int, Callable[[int, int], None]] = {}
        self.events: Dict[int, Dict[str, int]] = {}   # per-uid iteration marks
        self.iterations = 0            # total model iterations (prefill+decode)
        self.prefill_iterations = 0
        self.decode_iterations = 0
        self.prefill_tokens = 0
        clen = ecfg.cache_len if cfg.window is None \
            else min(ecfg.cache_len, cfg.window)
        self._clen = clen
        self._orig_plen: Dict[int, int] = {}
        self.peak_active = 0
        self.paged = ecfg.kv_block_size is not None
        self.block_mgr = None
        if self.paged:
            if ecfg.mode != "continuous":
                raise ValueError("paged KV (kv_block_size) requires "
                                 "mode='continuous'")
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    f"paged KV is attention-only; family={cfg.family!r} "
                    "keeps recurrent state, not a block pool")
        if ecfg.mode == "continuous":
            self._cur = np.zeros((ecfg.batch_size,), np.int32)
            if self.paged:
                from repro.serving.block_pool import BlockSpaceManager
                bs = int(ecfg.kv_block_size)
                self._mbs = -(-clen // bs)     # table columns per lane
                nblocks = self._paged_pool_blocks(bs)
                self.block_mgr = BlockSpaceManager(
                    nblocks, bs, share_prefix=ecfg.share_prefix)
                # one extra physical block: the trash block every dead
                # table entry / masked lane points at
                self._trash = nblocks
                self.cache = lm.init_paged_cache(
                    self.params, cfg, ecfg.batch_size, nblocks + 1, bs,
                    self._quant_kv)
                self._tables_np = np.full(
                    (ecfg.batch_size, self._mbs), self._trash, np.int32)
                self._len_np = np.zeros((ecfg.batch_size,), np.int64)
            else:
                self.cache = lm.init_cache(self.params, cfg,
                                           ecfg.batch_size, clen,
                                           self._quant_kv)
            if self.tp_serving is not None:
                self.cache = self.tp_serving.shard_cache(self.cache)
        # self-speculative decoding: the plan's draft= sub-spec requants
        # the SAME raw tree aggressively; the draft tree stays resident
        # alongside the conservative one for the engine's lifetime
        self.spec_decoder = None
        draft = self.plan.draft if self.plan is not None else None
        if draft is not None:
            from repro import planning
            from repro.serving.speculative import SpeculativeDecoder
            if not isinstance(draft, planning.DraftSpec):
                raise ValueError(
                    "plan.draft is unresolved ('auto') — solve the plan "
                    "(Planner / resolve_plan with an SLO) before serving")
            if ecfg.mode != "continuous":
                raise ValueError("speculative decoding (plan draft=) "
                                 "requires mode='continuous'")
            if self.tp_serving is not None:
                raise ValueError(
                    "speculative decoding is not supported under "
                    "tensor-parallel serving — the draft/verify round "
                    "runs outside the shard_map entry points")
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "speculative decoding needs a pure-attention family "
                    "— recurrent state cannot roll back to the accepted "
                    f"frontier (family={cfg.family!r})")
            if cfg.pos == "sinusoidal":
                raise ValueError(
                    "speculative decoding does not support sinusoidal "
                    "positions (multi-token verify embeds at "
                    "pos_offset=0, like decode_step)")
            if self.tap is not None:
                raise ValueError(
                    "speculative decoding and an ActivationTap cannot "
                    "coexist — the round bypasses the tapped decode step")
            self.spec_decoder = SpeculativeDecoder(
                params, cfg, draft, self.quant_policy)
        if ecfg.controller:
            if ecfg.mode != "continuous":
                warnings.warn(
                    "controller is ignored in mode='batch' — the "
                    "SloController hooks the continuous engine's "
                    "iteration loop", UserWarning, stacklevel=2)
            else:
                from repro.serving.control import (ControllerConfig,
                                                   SloController)
                self.controller = SloController(
                    ControllerConfig.coerce(ecfg.controller),
                    slo=self.slo,
                    iter_seconds=(self._modeled_iter_seconds
                                  if self._plan_units is not None
                                  else None),
                    planned_tps=self.planned_tps(),
                    plan_hit_rate=self.prt_hit_rate,
                    tokens_per_iter=(self.spec_decoder.expected_tokens()
                                     if self.spec_decoder is not None
                                     else 1.0))

    # --- client API -------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               on_token: Optional[Callable[[int, int], None]] = None) -> int:
        """Queue a request; returns its uid.

        ``on_token(uid, token)`` (optional) is invoked as each generated
        token is committed — the streaming hook.
        """
        # speculative rounds write k extra candidate positions past the
        # committed frontier; lanes must never wrap over them
        spec_extra = (self.spec_decoder.k + 1
                      if self.spec_decoder is not None else 0)
        if self.paged:
            need = len(prompt) + max_new_tokens + spec_extra
            room = self._mbs * int(self.ecfg.kv_block_size)
            if need > room:
                raise ValueError(
                    f"request needs {need} KV positions but a paged lane "
                    f"holds {room} ({self._mbs} blocks x "
                    f"{self.ecfg.kv_block_size}) — paged lanes never "
                    "wrap; raise cache_len or shorten the request")
        elif spec_extra and self.ecfg.mode == "continuous":
            need = len(prompt) + max_new_tokens + spec_extra
            if need > self._clen:
                raise ValueError(
                    f"request needs {need} KV positions (prompt + "
                    f"max_new + draft lookahead) but the ring holds "
                    f"{self._clen} — speculative rollback forbids ring "
                    "wrap; raise cache_len or shorten the request")
        self._uid += 1
        self.sched.submit(Request(uid=self._uid, prompt_len=len(prompt),
                                  max_new_tokens=max_new_tokens,
                                  arrived_at=time.perf_counter()))
        self._orig_plen[self._uid] = len(prompt)
        self._gen[self._uid] = list(prompt)
        self._t0[self._uid] = time.perf_counter()
        if on_token is not None:
            self._on_token[self._uid] = on_token
        return self._uid

    def step(self) -> bool:
        """One engine iteration: admit+prefill into free slots, commit each
        active slot's pending token (retiring on EOS/max-tokens), then run
        one masked decode for every remaining slot.  Returns True while
        work remains."""
        if self.ecfg.mode != "continuous":
            self._serve_batch()
            return not self.sched.idle()
        ctl = self.controller
        cap = None
        if ctl is not None and ctl.cfg.shed:
            free_cap = self._block_free_cap() if self.paged else None
            cap = ctl.batch_cap(self.ecfg.batch_size, free_cap=free_cap)
        admitted = self.sched.schedule(
            max_active=cap,
            can_admit=self._try_allocate if self.paged else None)
        if (cap is not None and self.sched.waiting and self.sched.free_slots
                and self.sched.active >= cap):
            # free slots exist but the SLO cap is binding: these
            # admissions are shed (deferred in FIFO), not dropped
            ctl.record_shed()
        if admitted:
            # group same-padded-length admissions into ONE prefill pass:
            # a K-request burst streams each layer's weights once, not K
            # times (the paper's weight temporal locality, applied to
            # prefill as well as decode)
            groups: Dict[int, List[Request]] = {}
            for req in admitted:
                groups.setdefault(self._padded_len(req), []).append(req)
            for padded, reqs in groups.items():
                self._prefill_slots(reqs, padded)
        # commit pending tokens, retire finished slots
        for req in list(self.sched.running):
            finished = req.generated >= req.max_new_tokens  # max_new == 0
            if not finished:
                tok = int(self._cur[req.slot])
                self._gen[req.uid].append(tok)
                req.generated += 1
                cb = self._on_token.get(req.uid)
                if cb is not None:
                    cb(req.uid, tok)
                finished = (tok == self.ecfg.eos_token or
                            req.generated >= req.max_new_tokens)
            if finished:
                self._finish(req)
        # one masked decode iteration serves every still-active slot
        active = list(self.sched.running)
        spec = self.spec_decoder
        if self.paged and active:
            # every active lane appends one KV position this iteration
            # (k+1 for a speculative round: k drafts re-written by
            # verify, plus the bonus slot): grant block slots first (COW
            # off shared blocks, preempt the newest when the pool runs
            # dry)
            active = self._ensure_append_blocks(
                active, n=(spec.k + 1) if spec is not None else 1)
        self.peak_active = max(self.peak_active, len(active))
        if spec is not None and active:
            self._speculative_round(active, ctl)
            return not self.sched.idle()
        if active:
            mask = np.zeros((self.ecfg.batch_size,), bool)
            for req in active:
                mask[req.slot] = True
            capture = (self.tap is not None
                       and self.tap.should_capture(self.decode_iterations))
            t0 = time.perf_counter()
            if self.tp_serving is not None:
                out = self.tp_serving.decode_step(
                    self.params, jnp.asarray(self._cur[:, None]),
                    self.cache, quant_kv=self._quant_kv,
                    active_mask=jnp.asarray(mask),
                    block_tables=(jnp.asarray(self._tables_np)
                                  if self.paged else None))
            else:
                out = lm.decode_step(
                    self.params, jnp.asarray(self._cur[:, None]),
                    self.cache, self.cfg, quant_kv=self._quant_kv,
                    active_mask=jnp.asarray(mask),
                    capture_layer_inputs=capture,
                    block_tables=(jnp.asarray(self._tables_np)
                                  if self.paged else None))
            if capture:
                logits, self.cache, layer_inputs = out
                self.tap.observe(layer_inputs, mask)
            else:
                logits, self.cache = out
            self.iterations += 1
            self.decode_iterations += 1
            uids = np.zeros((self.ecfg.batch_size,), np.uint32)
            sidx = np.zeros((self.ecfg.batch_size,), np.uint32)
            for req in active:
                uids[req.slot] = req.uid
                sidx[req.slot] = self._sample_index(req.uid)
            nxt = self._sample(logits, uids, sidx)
            # _sample's np.asarray blocks on the device, so dt covers the
            # whole iteration (incl. any tap-capture sync)
            dt = time.perf_counter() - t0
            self.decode_seconds += dt
            self._decode_tokens += len(active)
            exp = self._modeled_iter_seconds(len(active))
            if exp is not None:
                self.modeled_seconds += exp
            if self.paged:
                self._len_np[mask] += 1
            for req in active:
                self._cur[req.slot] = nxt[req.slot]
                self.events[req.uid].setdefault("first_decode_iteration",
                                                self.iterations)
            if ctl is not None and ctl.observe(len(active), dt,
                                              self.decode_iterations):
                self._controller_step()
        return not self.sched.idle()

    def run(self) -> List[Completion]:
        """Serve until all submitted requests finish (the drain loop)."""
        while self.step():
            pass
        return list(self.completions.values())

    # --- paged-pool internals ---------------------------------------------
    def _paged_pool_blocks(self, bs: int) -> int:
        """Pool size in blocks (excluding the trash block): explicit
        count, else a byte budget priced by planning.kv_pool_blocks, else
        batch_size worst-case slot-equivalents.  Clamped so one maximal
        request always fits."""
        from repro import planning
        ecfg = self.ecfg
        if ecfg.kv_pool_blocks is not None:
            n = int(ecfg.kv_pool_blocks)
        elif ecfg.kv_budget_bytes is not None:
            n = planning.kv_pool_blocks(
                ecfg.kv_budget_bytes, bs, lm.n_scan_blocks(self.cfg),
                self.cfg.n_kv, self.cfg.head_dim, self.kv_bits)
        else:
            n = ecfg.batch_size * self._mbs
        return max(n, self._mbs)

    def _block_free_cap(self) -> int:
        """How many requests the block pool could hold right now: the
        active set plus a non-mutating greedy estimate of admissible
        waiters (prefix sharing included) — the memory bound fed to
        SloController.batch_cap."""
        prompts = [tuple(self._gen[r.uid][:r.prompt_len])
                   for r in self.sched.waiting]
        return self.sched.active + self.block_mgr.admission_cap(prompts)

    def _try_allocate(self, req: Request) -> bool:
        """Scheduler admission gate: allocate the request's prefill
        blocks (sharing any registered prefix).  Called only when
        admission is otherwise certain, so allocating here is safe; a
        False return stops this iteration's admissions (FIFO holds)."""
        prompt = tuple(self._gen[req.uid][:req.prompt_len])
        if not self.block_mgr.can_allocate(prompt):
            return False
        self.block_mgr.allocate(req.uid, prompt)
        return True

    def _ensure_append_blocks(self, active: List[Request],
                              n: int = 1) -> List[Request]:
        """Grant every active lane physical slots for this iteration's
        KV writes: in-place into its frontier block, a fresh block at a
        block boundary, or a copy-on-write split off a shared block.
        ``n`` > 1 (speculative rounds) grants a RANGE of consecutive
        positions up front — the draft writes k of them and verify all
        n.  When the pool runs dry the newest arrival is preempted
        (recompute-style) and the grant retried.  Returns the requests
        that still decode this iteration; COW copies are applied to the
        device pool in one batched scatter."""
        bs = int(self.ecfg.kv_block_size)
        cows: List[tuple] = []
        preempted: set = set()
        granted: List[Request] = []
        for req in active:
            if req.uid in preempted:
                continue
            for j in range(n):
                if req.uid in preempted:
                    break
                pos = int(self._len_np[req.slot]) + j
                while True:
                    res = self.block_mgr.append_slot(req.uid, pos)
                    if res is not None:
                        kind, src, dst = res
                        if kind in ("alloc", "cow"):
                            self._tables_np[req.slot, pos // bs] = dst
                        if kind == "cow":
                            cows.append((src, dst))
                        break
                    victim = self._pick_victim()
                    if victim is None:
                        raise MemoryError(
                            "KV block pool exhausted and preemption is "
                            "disabled (EngineConfig.preempt=False) — grow "
                            "kv_pool_blocks/kv_budget_bytes")
                    self._preempt(victim)
                    preempted.add(victim.uid)
                    if victim is req:
                        break
            if req.uid not in preempted:
                granted.append(req)
        if cows:
            src = jnp.asarray(np.asarray([s for s, _ in cows], np.int32))
            dst = jnp.asarray(np.asarray([d for _, d in cows], np.int32))
            self.cache["layers"] = lm._copy_blocks_jit(
                self.cache["layers"], src, dst)
        return [r for r in granted if r.uid not in preempted]

    def _pick_victim(self) -> Optional[Request]:
        """Preemption victim: the newest running request (FIFO priority —
        the oldest work keeps its blocks)."""
        if not self.ecfg.preempt:
            return None
        for cand in reversed(self.sched.running):
            if self.block_mgr.has_table(cand.uid):
                return cand
        return None

    def _preempt(self, victim: Request) -> None:
        """Recompute-style eviction: free the victim's blocks, trash its
        table row, and requeue it at the FRONT of the waiting queue with
        its committed tokens as the resume prompt.  Under greedy
        sampling the resumed request regenerates the exact suffix it
        would have produced unpreempted."""
        uid, slot = victim.uid, victim.slot
        self.block_mgr.preempt(uid)
        self._tables_np[slot, :] = self._trash
        self._len_np[slot] = 0
        self.sched.preempt(uid)
        # resume prompt = original prompt + every committed token; the
        # re-prefill recomputes their KV and re-samples the pending token
        victim.prompt_len = len(self._gen[uid])
        ev = self.events.setdefault(uid, {})
        ev["preemptions"] = ev.get("preemptions", 0) + 1
        ev["preempted_iteration"] = self.iterations

    # --- continuous internals ---------------------------------------------
    def _speculative_round(self, active: List[Request], ctl) -> None:
        """One self-speculative round: fused k-token draft under the
        aggressive tree, one batched (k+1)-token verify under the
        conservative tree, then commit-accepted / rollback-rejected (see
        ``repro.serving.speculative``).

        The accepted prefix is committed token by token with the same
        EOS/max-new checks as the top-of-step commit; the round's
        correction (first rejection) or bonus (all accepted) token
        becomes the new pending ``_cur``.  Rollback is one device write
        of per-lane lengths back to the accepted frontier — verify
        already overwrote every draft KV slot at conservative precision,
        and slots past the frontier are unreadable (held > position)
        until rewritten in order — plus a paged block-table truncation.
        """
        spec = self.spec_decoder
        k = spec.k
        bsz = self.ecfg.batch_size
        mask = np.zeros((bsz,), bool)
        uids = np.zeros((bsz,), np.uint32)
        sidx = np.zeros((bsz,), np.uint32)
        for req in active:
            mask[req.slot] = True
            uids[req.slot] = req.uid
            sidx[req.slot] = self._sample_index(req.uid)
        prev_len = np.asarray(self.cache["length"]).copy()
        amask = jnp.asarray(mask)
        tables = jnp.asarray(self._tables_np) if self.paged else None
        temp = self.ecfg.temperature
        t0 = time.perf_counter()
        d_toks, d_logits, self.cache = lm.draft_tokens(
            spec.draft_params, jnp.asarray(self._cur[:, None]),
            self.cache, self.cfg, k, quant_kv=self._quant_kv,
            active_mask=amask, block_tables=tables, temperature=temp,
            seed=self.ecfg.seed, uids=jnp.asarray(uids),
            indices=jnp.asarray(sidx))
        draft_np = np.asarray(d_toks)
        # rewind: verify re-feeds the round from its first position
        self.cache["length"] = jnp.asarray(prev_len)
        vt = np.concatenate([self._cur[:, None], draft_np], axis=1)
        v_logits, self.cache = lm.verify_step(
            self.params, jnp.asarray(vt), self.cache, self.cfg,
            quant_kv=self._quant_kv, active_mask=amask,
            block_tables=tables)
        n_acc, nxt = spec.accept(
            draft_np, np.asarray(v_logits),
            np.asarray(d_logits) if temp > 0 else None,
            temperature=temp, seed=self.ecfg.seed, uids=uids,
            indices=sidx)
        # np.asarray above blocked on the device: dt is the whole round
        dt = time.perf_counter() - t0
        self.iterations += 1
        self.decode_iterations += 1
        produced = 0
        # rule-level acceptance (draft quality): lanes that hit max_new or
        # EOS mid-prefix truncate the COMMIT, not the acceptance stat —
        # conflating them would bias assumed_acceptance() low and misprice
        # expected tokens/round for the controller
        accepted_total = int(n_acc[mask].sum())
        new_len = prev_len.astype(np.int64).copy()
        for req in active:
            s, uid = req.slot, req.uid
            self.events[uid].setdefault("first_decode_iteration",
                                        self.iterations)
            finished = False
            for j in range(int(n_acc[s])):
                tok = int(draft_np[s, j])
                self._gen[uid].append(tok)
                req.generated += 1
                produced += 1
                cb = self._on_token.get(uid)
                if cb is not None:
                    cb(uid, tok)
                if (tok == self.ecfg.eos_token
                        or req.generated >= req.max_new_tokens):
                    finished = True
                    break
            new_len[s] = len(self._gen[uid])
            if finished:
                self._finish(req)
                continue
            # correction (first rejection) or bonus (all accepted)
            self._cur[s] = int(nxt[s])
            produced += 1
            if self.paged:
                dropped = self.block_mgr.truncate(uid, int(new_len[s]))
                if dropped:
                    keep = len(self.block_mgr.table(uid))
                    self._tables_np[s, keep:keep + dropped] = self._trash
                self._len_np[s] = int(new_len[s])
        # one device write rolls every lane back to its accepted frontier
        self.cache["length"] = jnp.asarray(new_len.astype(np.int32))
        self.decode_seconds += dt
        self._decode_tokens += produced
        exp = self._modeled_iter_seconds(len(active))
        if exp is not None:
            self.modeled_seconds += exp
        spec.note_round(len(active), accepted_total)
        if ctl is not None and ctl.observe(len(active), dt,
                                           self.decode_iterations):
            self._controller_step()

    def _padded_len(self, req: Request) -> int:
        # recurrent families (ssm/hybrid) fold every input token into the
        # state, so right-padding would pollute it — prefill exact-length;
        # attention families bucket-pad for compile-cache reuse (causal
        # masking + the ring-cache validity window ignore the padding).
        bucket = 1 if self.cfg.family in ("ssm", "hybrid") \
            else max(1, self.ecfg.prompt_bucket)
        plen = req.prompt_len
        return max(min(-(-plen // bucket) * bucket,
                       max(self._clen, plen)), plen)

    def _prefill_slots(self, reqs: List[Request], padded: int) -> None:
        """One prefill pass admits a same-length group into its slots.

        Paged mode scatters the freshly computed KV through each
        request's block table instead of into a contiguous slot row;
        padding rows and shared-prefix rows are redirected to the trash
        block (shared blocks are append-only for sharers — the KV they
        attend to is the registrant's, bit-identical by construction)."""
        b = len(reqs)
        toks = np.zeros((b, padded), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, req in enumerate(reqs):
            toks[i, :req.prompt_len] = self._gen[req.uid][:req.prompt_len]
            lengths[i] = req.prompt_len
        slots = np.asarray([req.slot for req in reqs], np.int32)
        if self.paged:
            bs = int(self.ecfg.kv_block_size)
            phys = np.full((b, padded), self._trash, np.int32)
            offs = np.tile(
                (np.arange(padded) % bs).astype(np.int32), (b, 1))
            for i, req in enumerate(reqs):
                table = self.block_mgr.table(req.uid)
                nsh = self.block_mgr.shared_prefix_blocks(req.uid)
                row = np.full((self._mbs,), self._trash, np.int32)
                row[:len(table)] = table
                self._tables_np[req.slot] = row
                for t in range(req.prompt_len):
                    j = t // bs
                    if j >= nsh:   # shared blocks keep the registrant's KV
                        phys[i, t] = table[j]
            if self.tp_serving is not None:
                logits, self.cache = self.tp_serving.prefill_into_blocks(
                    self.params, jnp.asarray(toks), self.cache, slots,
                    phys.ravel(), offs.ravel(),
                    quant_kv=self._quant_kv,
                    lengths=jnp.asarray(lengths))
            else:
                logits, self.cache = lm.prefill_into_blocks(
                    self.params, jnp.asarray(toks), self.cache, slots,
                    phys.ravel(), offs.ravel(), self.cfg,
                    quant_kv=self._quant_kv, lengths=jnp.asarray(lengths))
            for req in reqs:
                self._len_np[req.slot] = req.prompt_len
        elif self.tp_serving is not None:
            logits, self.cache = self.tp_serving.prefill_into_slot(
                self.params, jnp.asarray(toks), self.cache, slots,
                quant_kv=self._quant_kv, lengths=jnp.asarray(lengths))
        else:
            logits, self.cache = lm.prefill_into_slot(
                self.params, jnp.asarray(toks), self.cache, slots,
                self.cfg, quant_kv=self._quant_kv,
                lengths=jnp.asarray(lengths))
        self.iterations += 1
        self.prefill_iterations += 1
        self.prefill_tokens += int(lengths.sum())
        first = self._sample(
            logits, [req.uid for req in reqs],
            [self._sample_index(req.uid) for req in reqs])
        now = time.perf_counter()
        for i, req in enumerate(reqs):
            self._cur[req.slot] = int(first[i])
            # preserved across preemption: TTFT is submit -> FIRST token
            self._ttft.setdefault(req.uid, now - self._t0[req.uid])
            req.state = DECODE
            ev = self.events.setdefault(req.uid, {})
            if "admitted_iteration" in ev:
                ev["resumed_iteration"] = self.iterations
            else:
                ev["admitted_iteration"] = self.iterations

    def _finish(self, req: Request) -> None:
        slot = req.slot
        self.sched.release(req.uid)
        if self.paged and self.block_mgr.has_table(req.uid):
            self.block_mgr.free(req.uid)
            self._tables_np[slot, :] = self._trash
            self._len_np[slot] = 0
        # slice at the ORIGINAL prompt length: after a preemption
        # req.prompt_len includes committed tokens (the resume prompt)
        gen = self._gen[req.uid][self._orig_plen.get(req.uid,
                                                     req.prompt_len):]
        self.completions[req.uid] = Completion(
            uid=req.uid, tokens=gen,
            latency_s=time.perf_counter() - self._t0[req.uid],
            ttft_s=self._ttft.get(req.uid, 0.0))
        self.events[req.uid]["finished_iteration"] = self.iterations

    # --- batch-mode (run-to-completion) internals -------------------------
    def _serve_batch(self) -> None:
        batch = self.sched.admit()
        if not batch:
            return
        ecfg, cfg = self.ecfg, self.cfg
        b = len(batch)
        maxlen = max(r.prompt_len for r in batch)
        toks = np.zeros((b, maxlen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            p = self._gen[r.uid][:r.prompt_len]
            toks[i, :len(p)] = p
            lengths[i] = len(p)
        self.peak_active = max(self.peak_active, b)
        logits, cache = lm.prefill(
            self.params, jnp.asarray(toks), cfg, cache_len=self._clen,
            quant_kv=self._quant_kv, lengths=jnp.asarray(lengths))
        self.iterations += 1
        self.prefill_iterations += 1
        self.prefill_tokens += int(lengths.sum())
        cur = self._sample(logits, [r.uid for r in batch],
                           [self._sample_index(r.uid) for r in batch])
        now = time.perf_counter()
        for r in batch:
            self._ttft[r.uid] = now - self._t0[r.uid]
        # iteration loop: one decode step serves the whole batch
        active = list(batch)
        steps = max(r.max_new_tokens for r in batch)
        done_at: Dict[int, int] = {}
        for step in range(steps):
            for i, r in enumerate(active):
                if r.uid not in done_at:
                    if r.max_new_tokens <= 0:
                        done_at[r.uid] = step
                        continue
                    self._gen[r.uid].append(int(cur[i]))
                    cb = self._on_token.get(r.uid)
                    if cb is not None:
                        cb(r.uid, int(cur[i]))
                    if (int(cur[i]) == ecfg.eos_token or
                            step + 1 >= r.max_new_tokens):
                        done_at[r.uid] = step
            if len(done_at) == len(active) or step == steps - 1:
                break
            logits, cache = lm.decode_step(
                self.params, cur[:, None], cache, cfg,
                quant_kv=self._quant_kv)
            self.iterations += 1
            self.decode_iterations += 1
            cur = self._sample(logits, [r.uid for r in active],
                               [self._sample_index(r.uid)
                                for r in active])
        for r in active:
            gen = self._gen[r.uid][r.prompt_len:]
            self.completions[r.uid] = Completion(
                uid=r.uid, tokens=gen,
                latency_s=time.perf_counter() - self._t0[r.uid],
                ttft_s=self._ttft.get(r.uid, 0.0))
        self.sched.step_complete([r.uid for r in active])
        # mark any remaining (shouldn't happen in sync mode)
        self.sched.running = [r for r in self.sched.running
                              if r.uid not in self.completions]

    # --- planning ---------------------------------------------------------
    def _base_policy(self) -> QuantPolicy:
        return QuantPolicy(bits=self.ecfg.ql,
                           group_size=self.ecfg.group_size,
                           min_size=self.ecfg.min_size)

    # --- plan pricing / control loop --------------------------------------
    def _plan_cost_model(self, batch: int):
        """DecodeCostModel matching the served plan's knobs (fitted
        machine when the plan carries calibration provenance)."""
        from repro import planning
        kw: Dict[str, Any] = {"batch": int(batch)}
        if self.plan is not None:
            kw["prt"] = self.plan.prt
            kw["nbw"] = self.plan.nbw
            if self.plan.calibration is not None:
                kw["machine"] = planning.machine_from_json(
                    self.plan.calibration)
                disp = planning.dispatch_from_json(self.plan.calibration)
                if disp is not None:
                    kw["dispatch_cycles"] = disp
        if self.tp_serving is not None:
            kw["tp"] = self.tp_serving.tp
            kw["wire_bits"] = self.tp_serving.wire_bits
            kw["allreduce_elems"] = planning.tp_allreduce_elems(self.cfg)
        return planning.DecodeCostModel(**kw)

    def _modeled_iter_seconds(self, occupancy: int) -> Optional[float]:
        """Modeled seconds of one scheduling quantum at the given
        occupancy (memoized per plan; lookup cycles scale with batch, so
        this is nondecreasing — the controller's feasibility curve).

        Plain decode: one masked iteration.  Speculative: one whole
        round, ``k * t_draft + t_verify`` — t_draft under the aggressive
        tree's units, t_verify at batch x (k+1) token positions under
        the conservative units (``planning.speculative_round_seconds``).
        The plan hash in the memo key covers the draft sub-spec."""
        if self._plan_units is None:
            return None
        key = (self.plan.spec_hash if self.plan is not None else None,
               int(occupancy))
        got = self._iter_cache.get(key)
        if got is None:
            from repro import planning
            cost = self._plan_cost_model(occupancy)
            if self.spec_decoder is not None:
                got = planning.speculative_round_seconds(
                    cost, self._plan_units, self.spec_decoder.draft_units,
                    self.quant_policy.group_size, self._plan_fixed_bytes,
                    self.spec_decoder.k)
            else:
                cycles = cost.cycles(self._plan_units)
                total = (cost.qbytes(self._plan_units,
                                     self.quant_policy.group_size)
                         + self._plan_fixed_bytes)
                got = cost.iteration_seconds(cycles, total)
            self._iter_cache[key] = got
        return got

    def planned_tps(self, batch: Optional[int] = None) -> Optional[float]:
        """Modeled decode tokens/s of the served plan at ``batch``
        occupancy (default: the full pool) — the reference side of
        ``stats()["drift"]``.  None when serving unquantized.  Under
        speculative decoding one quantum commits E[accepted + 1] tokens
        per lane, so throughput scales by the acceptance curve."""
        b = self.ecfg.batch_size if batch is None else int(batch)
        secs = self._modeled_iter_seconds(b)
        if secs is None:
            return None
        tpi = (self.spec_decoder.expected_tokens()
               if self.spec_decoder is not None else 1.0)
        return b * tpi / max(secs, 1e-30)

    def measured_tps(self) -> Optional[float]:
        """Measured decode-phase tokens/s over the whole run (tokens
        produced per wall second of masked decode iterations)."""
        if self.decode_seconds <= 0 or self._decode_tokens == 0:
            return None
        return self._decode_tokens / self.decode_seconds

    def modeled_run_tps(self) -> Optional[float]:
        """Modeled tokens/s of the iterations actually run, each priced
        at its true occupancy — the occupancy-matched counterpart of
        :meth:`measured_tps` (``planned_tps`` prices the full pool)."""
        if self.modeled_seconds <= 0 or self._decode_tokens == 0:
            return None
        return self._decode_tokens / self.modeled_seconds

    def _tapped_hit_rate(self) -> Optional[float]:
        """PRT hit rate of the tapped traffic at the served plan's
        operating point (the escalation signal: compare against the rate
        the plan was priced with)."""
        if self.tap is None:
            return None
        calib = self.tap.calib()
        if calib is None:
            return None
        from repro.core import cost_model as cm
        from repro.core import pattern
        merged = calib.get(None) if isinstance(calib, dict) else calib
        wbits = (self.plan.weight_bits if self.plan is not None
                 and self.plan.weight_bits is not None else self.ecfg.ql)
        abits = (self.plan.act_bits if self.plan is not None
                 and self.plan.act_bits is not None else 8)
        nbw = self.plan.nbw if self.plan is not None else "auto"
        if not isinstance(nbw, int):
            k = int(merged.shape[-1])
            nbw = cm.best_nbw_for_unit(k, k, wbits, abits,
                                       batch=self.ecfg.batch_size)
        return pattern.prt_hit_rate(nbw, abits, merged)

    def _controller_step(self) -> None:
        """Apply the drift loop's requested action: replan (re-price on
        tapped traffic) or, when the tapped PRT hit rate moved enough to
        change the allocation, escalate to a full re-solve.  Without a
        tap (or raw weights) the action is recorded as skipped — the
        drift stays visible in stats() but nothing can act on it."""
        ctl = self.controller
        it = self.decode_iterations
        can = (self.tap is not None and self._raw_params is not None
               and self.tap.rows_seen > 0)
        if not can:
            ctl.acted("skipped", it)
            return
        action = ctl.decide(self._tapped_hit_rate(), self.prt_hit_rate)
        self.replan(resolve=(action == "resolve"))
        ctl.acted(action, it)

    def apply_plan(self, plan, force_requantize: bool = False) -> None:
        """Hot-swap the engine onto a new (solved) plan mid-serve.

        Requantizes the retained raw weights under the plan's policy and
        swaps the parameter tree; the KV pool, scheduler, and every
        in-flight request are untouched (the cache layout is independent
        of the plan's scan segmentation), so decoding continues without
        dropping a token.  Accepts a PlanSpec, grammar string/JSON, or a
        ``Planner`` ``PlanResult``.

        When the new plan resolves to the policy already being served
        (e.g. a discount-only replan), the requantization pass is
        skipped — it would produce byte-identical weights; pass
        ``force_requantize=True`` to run it anyway.
        """
        from repro import planning
        if self._raw_params is None:
            raise ValueError("apply_plan needs the raw weights resident "
                             "— construct the engine with quantize=True "
                             "and retain_raw=True (or a tap attached)")
        hit = None
        report = None
        if isinstance(plan, planning.PlanResult):
            hit = plan.measured_prt_hit_rate
            spec, policy, report = plan.spec, plan.policy, plan.report
        else:
            spec = planning.as_plan(plan)
            policy = spec.to_policy(self._base_policy())
        if isinstance(spec.kv_bits, int) and spec.kv_bits != self.kv_bits:
            warnings.warn(
                f"plan requests kv_bits={spec.kv_bits} but the KV pool "
                f"was allocated {self.kv_bits}-bit at construction — KV "
                "precision cannot hot-swap under in-flight requests; "
                "rebuild the engine to change it", UserWarning,
                stacklevel=2)
        want_tp = spec.tp if isinstance(spec.tp, int) else None
        if want_tp is not None and want_tp != self.tp:
            warnings.warn(
                f"plan requests tp={want_tp} but the engine serves "
                f"tp={self.tp} — the mesh is fixed at construction; "
                "rebuild the engine to change shard count", UserWarning,
                stacklevel=2)
        if spec.wire is not None and spec.wire != self.wire_bits:
            warnings.warn(
                f"plan requests wire={spec.wire} but the engine serves "
                f"wire={self.wire_bits} — all-reduce precision is fixed "
                "at construction; rebuild the engine to change it",
                UserWarning, stacklevel=2)
        if force_requantize or policy != self.quant_policy:
            self.params, b0, b1 = quantize_params(self._raw_params,
                                                  policy)
            self.compression = b0 / max(b1, 1)
            if self.tp_serving is not None:
                # the fresh tree replaces the sharded one: re-place it on
                # the mesh so decode keeps running sharded without a
                # resharding transfer on first use
                self.params = self.tp_serving.shard_params(self.params)
        self.quant_policy = policy
        self.plan = spec
        # the report must track the plan actually served — a stale one
        # would describe a different allocation in stats/replans
        self.plan_report = report
        self.replan_count += 1
        if hit is not None:
            self.prt_hit_rate = hit
        # re-price: the swapped plan has its own units / feasibility
        # curve, and the controller must re-anchor drift against it
        from repro import planning
        self._plan_units = planning.policy_units(self._raw_params, policy)
        self._plan_fixed_bytes = planning.unquantized_bytes(
            self._raw_params, policy)
        self._iter_cache.clear()
        if spec.target_tps is not None:
            self.slo = planning.Slo(spec.target_tps,
                                    batch=spec.slo_batch
                                    or self.ecfg.batch_size)
        # draft sub-spec hot-swap: requantize the draft tree when the new
        # plan drafts differently, or drop it when the plan stopped
        # speculating (the pending _cur token carries over either way)
        draft = spec.draft if isinstance(spec.draft, planning.DraftSpec) \
            else None
        if draft is None:
            self.spec_decoder = None
        elif (self.spec_decoder is None
              or self.spec_decoder.spec != draft):
            from repro.serving.speculative import SpeculativeDecoder
            self.spec_decoder = SpeculativeDecoder(
                self._raw_params, self.cfg, draft, policy)
        if self.controller is not None:
            self.controller.slo = self.slo
            self.controller.plan_changed(
                iter_seconds=self._modeled_iter_seconds,
                planned_tps=self.planned_tps(),
                plan_hit_rate=self.prt_hit_rate,
                tokens_per_iter=(self.spec_decoder.expected_tokens()
                                 if self.spec_decoder is not None
                                 else 1.0))

    def replan(self, planner=None, resolve: bool = False):
        """Online recalibration from live traffic (ROADMAP: "PRT hit
        rates from live traffic").

        Feeds the ActivationTap's captured per-layer batches to a
        ``Planner.replan`` — measured PRT discounts refresh from real
        activations, and ``resolve=True`` additionally re-solves the
        allocation — then hot-swaps the result via :meth:`apply_plan`.
        Pass an existing ``planner`` to reuse its cached sensitivity
        probes across replans; otherwise a fresh one wraps the engine's
        current plan.  Returns the ``PlanResult``.
        """
        from repro import planning
        if self.tap is None:
            raise ValueError("no ActivationTap attached — set "
                             "EngineConfig.tap_capacity > 0 (taps only "
                             "attach in mode='continuous')")
        if self._raw_params is None:
            raise ValueError("replan needs the raw weights resident — "
                             "construct the engine with quantize=True "
                             "and retain_raw=True (or rely on the tap "
                             "default)")
        if planner is None:
            planner = planning.Planner(self._raw_params, self.cfg,
                                       self.plan,
                                       base=self._base_policy())
            planner.last = planning.PlanResult(
                spec=self.plan, policy=self.quant_policy,
                report=self.plan_report)
        result = planner.replan(self.tap, resolve=resolve)
        self.apply_plan(result)
        return result

    # --- shared -----------------------------------------------------------
    def _sample_index(self, uid: int) -> int:
        """Per-request sample counter: how many tokens this request has
        had sampled AND committed so far (0 at the prefill sample).
        Derived from committed state only, so it is invariant to batch
        composition, iteration count, preemption/resume (the resumed
        re-prefill re-samples the pending token under its original
        index), and slot-vs-paged pool layout."""
        return len(self._gen[uid]) - self._orig_plen.get(uid, 0)

    def _sample(self, logits, uids=None, indices=None) -> np.ndarray:
        """Sample one token per logits row.

        ``uids``/``indices`` carry each row's (request uid, per-request
        sample index); rows without a live request (masked slots) pass
        uid 0 and their draws are discarded by the caller.  Greedy
        ignores them entirely."""
        if self.ecfg.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        if uids is None:
            uids = np.zeros((logits.shape[0],), np.uint32)
            indices = np.zeros((logits.shape[0],), np.uint32)
        return np.asarray(_sample_rows_jit(
            logits, jnp.asarray(np.asarray(uids, np.uint32)),
            jnp.asarray(np.asarray(indices, np.uint32)),
            self.ecfg.seed, self.ecfg.temperature))

    def _tp_stats(self) -> Dict[str, Any]:
        """Observability for tensor-parallel serving: shard count, wire
        precision, modeled wire seconds and their share of the full-pool
        iteration, and the per-shard all-reduce bytes one decode
        iteration moves."""
        b = self.ecfg.batch_size
        tw = (self._plan_cost_model(b).t_wire(b)
              if self._plan_units is not None else None)
        secs = self._modeled_iter_seconds(b)
        return {"shards": self.tp_serving.tp,
                "wire_bits": self.tp_serving.wire_bits,
                "allreduce_bytes_per_iter":
                    self.tp_serving.allreduce_bytes_per_iter(b),
                "modeled_t_wire_s": tw,
                "modeled_wire_share": (tw / secs if tw is not None
                                       and secs else None)}

    def stats(self) -> Dict[str, Any]:
        lats = [c.latency_s for c in self.completions.values()]
        ttfts = [c.ttft_s for c in self.completions.values()]
        toks = sum(len(c.tokens) for c in self.completions.values())
        measured = self.measured_tps()
        planned = self.planned_tps()
        modeled = self.modeled_run_tps()
        # measured-vs-modeled decode tokens/s drift: the "is the
        # calibration stale?" signal, reported with or without a
        # controller.  Occupancy-matched (each iteration priced at its
        # true occupancy) raw ratio — absolute value is only meaningful
        # when the plan carries host calibration (plan_calibrated);
        # the controller's internal drift is anchor-normalized.
        ref = modeled if modeled is not None else planned
        drift = (measured / ref - 1.0
                 if measured is not None and ref else None)
        return {"requests": len(self.completions),
                "measured_tps": measured,
                "planned_tps": planned,
                "modeled_run_tps": modeled,
                "drift": drift,
                "controller": (self.controller.stats()
                               if self.controller is not None else None),
                "generated_tokens": toks,
                # paged-pool observability: peak concurrent decode lanes
                # (the gate metric), served KV precision, pool stats
                "peak_active": self.peak_active,
                "kv_bits": self.kv_bits,
                # tensor-parallel serving: shard count, wire precision,
                # modeled wire share (None when serving single-device)
                "tp": (self._tp_stats() if self.tp_serving is not None
                       else None),
                "block_pool": (self.block_mgr.stats()
                               if self.paged else None),
                # self-speculative decoding: draft plan, rounds,
                # acceptance rate (None when not speculating)
                "speculative": (self.spec_decoder.stats()
                                if self.spec_decoder is not None
                                else None),
                "iterations": self.iterations,
                "prefill_iterations": self.prefill_iterations,
                "decode_iterations": self.decode_iterations,
                "prefill_tokens": self.prefill_tokens,
                "weight_compression": round(self.compression, 2),
                "mixed_precision": bool(self.quant_policy is not None
                                        and self.quant_policy.is_mixed()),
                # plan provenance: serve_bench artifacts track churn by
                # hash; replan_count/prt_hit_rate expose online recalib
                "plan_hash": (self.plan.spec_hash
                              if self.plan is not None else None),
                "plan_mode": (self.plan.mode
                              if self.plan is not None else None),
                # whether the served plan was priced against fitted
                # (measured-hardware) cost-model constants
                "plan_calibrated": bool(self.plan is not None
                                        and self.plan.calibration
                                        is not None),
                "replan_count": self.replan_count,
                "prt_hit_rate": self.prt_hit_rate,
                "tapped_rows": (self.tap.rows_seen
                                if self.tap is not None else 0),
                "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
                "p99_latency_s": float(np.percentile(lats, 99))
                if lats else 0.0,
                "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0}
