"""Continuous-batching serving engine over a fixed pool of KV-cache slots.

The paper's serving contribution (Sec. III-A) is iteration-based
scheduling: ONE model iteration serves every active user, so each layer's
weights are streamed once and reused batch-wide (weight temporal locality
— in the LLC on the paper's machine, in VMEM on TPU).  This engine makes
that iteration the scheduling quantum, Orca/vLLM-style:

  * ``init_cache`` allocates a fixed ``[max_batch, cache_len]`` KV pool
    once; requests are prefilled *into* free slots
    (``lm.prefill_into_slot``) and retired per-slot, so the batch never
    reshapes and the decode step compiles exactly once;
  * every ``step()`` admits waiting requests into free slots (FIFO, with
    a Sarathi-style cap on new prefill tokens per iteration), appends
    each active request's pending token, retires slots on EOS/max-tokens,
    and runs one masked decode iteration for all remaining slots;
  * a request arriving mid-decode joins the very next iteration instead
    of waiting for the cohort to drain — the weight-reuse window the
    paper optimizes is never wasted on a partially idle batch.

``mode="batch"`` keeps the old run-to-completion loop (admit a cohort,
decode it to the end, admit again) for A/B comparison — see
``benchmarks/serve_bench.py``.

Runs the SAIL path: weights SAIL-quantized (QTensor), KV cache optionally
int8.  The engine is synchronous and deterministic; streaming consumers
hook ``submit(..., on_token=...)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import DECODE, IterationScheduler, Request
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, quantize_params


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8            # KV-pool slots (paper: 8 balances the pipe)
    cache_len: int = 4096
    quantize: bool = True
    ql: int = 4
    group_size: int = 128
    quant_kv: bool = True
    min_size: int = 1024           # quantize tensors >= this many elements
    # Mixed-precision spec: None (uniform ``ql``), a QuantPolicy, a policy
    # spec dict, or a string — "uniform:<b>[a<ab>]",
    # "rules:<regex>=<b>[a<ab>],...", "auto:q<b>" / "auto:<f>bpw"
    # (sensitivity-calibrated weight allocation), or
    # "auto:q<b>a<ab>[,prt=measured][,maxseg=<n>]" (JOINT weight +
    # activation allocation under the projected-cycle budget of uniform
    # (b, ab)).  ``a<ab>`` selects the lutmm activation precision; see
    # repro.core.sensitivity.parse_bit_policy.
    bit_policy: Any = None
    eos_token: int = -1            # -1: never stop early
    temperature: float = 0.0       # 0 = greedy
    mode: str = "continuous"       # "continuous" | "batch" (run-to-completion)
    prefill_budget: Optional[int] = None  # new prefill tokens per iteration
    prompt_bucket: int = 16        # prompts padded to a multiple (compile reuse)


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    latency_s: float
    ttft_s: float = 0.0            # submit -> first token available


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig):
        assert ecfg.mode in ("continuous", "batch"), ecfg.mode
        self.cfg = cfg
        self.ecfg = ecfg
        self.quant_policy: Optional[QuantPolicy] = None
        if ecfg.bit_policy is not None and not ecfg.quantize:
            raise ValueError("bit_policy requires quantize=True")
        if ecfg.quantize:
            policy = QuantPolicy(bits=ecfg.ql, group_size=ecfg.group_size,
                                 min_size=ecfg.min_size)
            if ecfg.bit_policy is not None:
                from repro.core.sensitivity import resolve_bit_policy
                policy = resolve_bit_policy(ecfg.bit_policy, params, cfg,
                                            policy)
            self.quant_policy = policy
            self.params, b0, b1 = quantize_params(params, policy)
            self.compression = b0 / max(b1, 1)
        else:
            self.params, self.compression = params, 1.0
        self.sched = IterationScheduler(target_batch=ecfg.batch_size,
                                        max_batch=ecfg.batch_size,
                                        prefill_budget=ecfg.prefill_budget)
        self._uid = 0
        self.completions: Dict[int, Completion] = {}
        self._gen: Dict[int, List[int]] = {}
        self._t0: Dict[int, float] = {}
        self._ttft: Dict[int, float] = {}
        self._on_token: Dict[int, Callable[[int, int], None]] = {}
        self.events: Dict[int, Dict[str, int]] = {}   # per-uid iteration marks
        self.iterations = 0            # total model iterations (prefill+decode)
        self.prefill_iterations = 0
        self.decode_iterations = 0
        self.prefill_tokens = 0
        clen = ecfg.cache_len if cfg.window is None \
            else min(ecfg.cache_len, cfg.window)
        self._clen = clen
        if ecfg.mode == "continuous":
            self.cache = lm.init_cache(self.params, cfg, ecfg.batch_size,
                                       clen, ecfg.quant_kv)
            self._cur = np.zeros((ecfg.batch_size,), np.int32)

    # --- client API -------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               on_token: Optional[Callable[[int, int], None]] = None) -> int:
        """Queue a request; returns its uid.

        ``on_token(uid, token)`` (optional) is invoked as each generated
        token is committed — the streaming hook.
        """
        self._uid += 1
        self.sched.submit(Request(uid=self._uid, prompt_len=len(prompt),
                                  max_new_tokens=max_new_tokens,
                                  arrived_at=time.time()))
        self._gen[self._uid] = list(prompt)
        self._t0[self._uid] = time.time()
        if on_token is not None:
            self._on_token[self._uid] = on_token
        return self._uid

    def step(self) -> bool:
        """One engine iteration: admit+prefill into free slots, commit each
        active slot's pending token (retiring on EOS/max-tokens), then run
        one masked decode for every remaining slot.  Returns True while
        work remains."""
        if self.ecfg.mode != "continuous":
            self._serve_batch()
            return not self.sched.idle()
        admitted = self.sched.schedule()
        if admitted:
            # group same-padded-length admissions into ONE prefill pass:
            # a K-request burst streams each layer's weights once, not K
            # times (the paper's weight temporal locality, applied to
            # prefill as well as decode)
            groups: Dict[int, List[Request]] = {}
            for req in admitted:
                groups.setdefault(self._padded_len(req), []).append(req)
            for padded, reqs in groups.items():
                self._prefill_slots(reqs, padded)
        # commit pending tokens, retire finished slots
        for req in list(self.sched.running):
            finished = req.generated >= req.max_new_tokens  # max_new == 0
            if not finished:
                tok = int(self._cur[req.slot])
                self._gen[req.uid].append(tok)
                req.generated += 1
                cb = self._on_token.get(req.uid)
                if cb is not None:
                    cb(req.uid, tok)
                finished = (tok == self.ecfg.eos_token or
                            req.generated >= req.max_new_tokens)
            if finished:
                self._finish(req)
        # one masked decode iteration serves every still-active slot
        active = list(self.sched.running)
        if active:
            mask = np.zeros((self.ecfg.batch_size,), bool)
            for req in active:
                mask[req.slot] = True
            logits, self.cache = lm.decode_step(
                self.params, jnp.asarray(self._cur[:, None]), self.cache,
                self.cfg, quant_kv=self.ecfg.quant_kv,
                active_mask=jnp.asarray(mask))
            self.iterations += 1
            self.decode_iterations += 1
            nxt = self._sample(logits)
            for req in active:
                self._cur[req.slot] = nxt[req.slot]
                self.events[req.uid].setdefault("first_decode_iteration",
                                                self.iterations)
        return not self.sched.idle()

    def run(self) -> List[Completion]:
        """Serve until all submitted requests finish (the drain loop)."""
        while self.step():
            pass
        return list(self.completions.values())

    # --- continuous internals ---------------------------------------------
    def _padded_len(self, req: Request) -> int:
        # recurrent families (ssm/hybrid) fold every input token into the
        # state, so right-padding would pollute it — prefill exact-length;
        # attention families bucket-pad for compile-cache reuse (causal
        # masking + the ring-cache validity window ignore the padding).
        bucket = 1 if self.cfg.family in ("ssm", "hybrid") \
            else max(1, self.ecfg.prompt_bucket)
        plen = req.prompt_len
        return max(min(-(-plen // bucket) * bucket,
                       max(self._clen, plen)), plen)

    def _prefill_slots(self, reqs: List[Request], padded: int) -> None:
        """One prefill pass admits a same-length group into its slots."""
        b = len(reqs)
        toks = np.zeros((b, padded), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, req in enumerate(reqs):
            toks[i, :req.prompt_len] = self._gen[req.uid][:req.prompt_len]
            lengths[i] = req.prompt_len
        slots = np.asarray([req.slot for req in reqs], np.int32)
        logits, self.cache = lm.prefill_into_slot(
            self.params, jnp.asarray(toks), self.cache, slots, self.cfg,
            quant_kv=self.ecfg.quant_kv, lengths=jnp.asarray(lengths))
        self.iterations += 1
        self.prefill_iterations += 1
        self.prefill_tokens += int(lengths.sum())
        first = self._sample(logits)
        now = time.time()
        for i, req in enumerate(reqs):
            self._cur[req.slot] = int(first[i])
            self._ttft[req.uid] = now - self._t0[req.uid]
            req.state = DECODE
            self.events[req.uid] = {"admitted_iteration": self.iterations}

    def _finish(self, req: Request) -> None:
        self.sched.release(req.uid)
        gen = self._gen[req.uid][req.prompt_len:]
        self.completions[req.uid] = Completion(
            uid=req.uid, tokens=gen,
            latency_s=time.time() - self._t0[req.uid],
            ttft_s=self._ttft.get(req.uid, 0.0))
        self.events[req.uid]["finished_iteration"] = self.iterations

    # --- batch-mode (run-to-completion) internals -------------------------
    def _serve_batch(self) -> None:
        batch = self.sched.admit()
        if not batch:
            return
        ecfg, cfg = self.ecfg, self.cfg
        b = len(batch)
        maxlen = max(r.prompt_len for r in batch)
        toks = np.zeros((b, maxlen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            p = self._gen[r.uid][:r.prompt_len]
            toks[i, :len(p)] = p
            lengths[i] = len(p)
        logits, cache = lm.prefill(
            self.params, jnp.asarray(toks), cfg, cache_len=self._clen,
            quant_kv=ecfg.quant_kv, lengths=jnp.asarray(lengths))
        self.iterations += 1
        self.prefill_iterations += 1
        self.prefill_tokens += int(lengths.sum())
        cur = self._sample(logits)
        now = time.time()
        for r in batch:
            self._ttft[r.uid] = now - self._t0[r.uid]
        # iteration loop: one decode step serves the whole batch
        active = list(batch)
        steps = max(r.max_new_tokens for r in batch)
        done_at: Dict[int, int] = {}
        for step in range(steps):
            for i, r in enumerate(active):
                if r.uid not in done_at:
                    if r.max_new_tokens <= 0:
                        done_at[r.uid] = step
                        continue
                    self._gen[r.uid].append(int(cur[i]))
                    cb = self._on_token.get(r.uid)
                    if cb is not None:
                        cb(r.uid, int(cur[i]))
                    if (int(cur[i]) == ecfg.eos_token or
                            step + 1 >= r.max_new_tokens):
                        done_at[r.uid] = step
            if len(done_at) == len(active) or step == steps - 1:
                break
            logits, cache = lm.decode_step(
                self.params, cur[:, None], cache, cfg,
                quant_kv=ecfg.quant_kv)
            self.iterations += 1
            self.decode_iterations += 1
            cur = self._sample(logits)
        for r in active:
            gen = self._gen[r.uid][r.prompt_len:]
            self.completions[r.uid] = Completion(
                uid=r.uid, tokens=gen,
                latency_s=time.time() - self._t0[r.uid],
                ttft_s=self._ttft.get(r.uid, 0.0))
        self.sched.step_complete([r.uid for r in active])
        # mark any remaining (shouldn't happen in sync mode)
        self.sched.running = [r for r in self.sched.running
                              if r.uid not in self.completions]

    # --- shared -----------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        if self.ecfg.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        key = jax.random.PRNGKey(self.iterations)
        return np.asarray(jax.random.categorical(
            key, logits / self.ecfg.temperature, axis=-1))

    def stats(self) -> Dict[str, Any]:
        lats = [c.latency_s for c in self.completions.values()]
        ttfts = [c.ttft_s for c in self.completions.values()]
        toks = sum(len(c.tokens) for c in self.completions.values())
        return {"requests": len(self.completions),
                "generated_tokens": toks,
                "iterations": self.iterations,
                "prefill_iterations": self.prefill_iterations,
                "decode_iterations": self.decode_iterations,
                "prefill_tokens": self.prefill_tokens,
                "weight_compression": round(self.compression, 2),
                "mixed_precision": bool(self.quant_policy is not None
                                        and self.quant_policy.is_mixed()),
                "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
                "p99_latency_s": float(np.percentile(lats, 99))
                if lats else 0.0,
                "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0}
