"""Batched serving engine — the paper's tensor-level scheduling in system
form (Sec. III-A).

Iteration-based serving: each engine step runs ONE model iteration for the
whole active batch, so every layer's weights are streamed once per
iteration and reused across all users (weight temporal locality — on TPU
that reuse happens in VMEM; the analytic LLC model lives in
core/scheduler.py).  Slots freed by finished requests are back-filled from
the waiting queue at iteration granularity.

Runs the SAIL path: weights SAIL-quantized (QTensor), KV cache optionally
int8.  The engine is deliberately synchronous and deterministic —
production async wrappers (request queues, streaming) belong to the RPC
layer, not the execution engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import IterationScheduler, Request
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, quantize_params


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8            # the pipeline-balancing batch (paper: 8)
    cache_len: int = 4096
    quantize: bool = True
    ql: int = 4
    group_size: int = 128
    quant_kv: bool = True
    min_size: int = 1024           # quantize tensors >= this many elements
    eos_token: int = -1            # -1: never stop early
    temperature: float = 0.0       # 0 = greedy


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    latency_s: float


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = ecfg
        if ecfg.quantize:
            self.params, b0, b1 = quantize_params(
                params, QuantPolicy(bits=ecfg.ql,
                                    group_size=ecfg.group_size,
                                    min_size=ecfg.min_size))
            self.compression = b0 / max(b1, 1)
        else:
            self.params, self.compression = params, 1.0
        self.sched = IterationScheduler(target_batch=ecfg.batch_size,
                                        max_batch=ecfg.batch_size)
        self._uid = 0
        self.completions: Dict[int, Completion] = {}
        self._gen: Dict[int, List[int]] = {}
        self._t0: Dict[int, float] = {}
        self.iterations = 0

    # --- client API -------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int) -> int:
        self._uid += 1
        self.sched.submit(Request(uid=self._uid, prompt_len=len(prompt),
                                  max_new_tokens=max_new_tokens))
        self._gen[self._uid] = list(prompt)
        self._t0[self._uid] = time.time()
        return self._uid

    def run(self) -> List[Completion]:
        """Serve until all submitted requests finish."""
        while not self.sched.idle():
            self._serve_batch()
        return list(self.completions.values())

    # --- internals ----------------------------------------------------------
    def _serve_batch(self) -> None:
        batch = self.sched.admit()
        if not batch:
            return
        ecfg, cfg = self.ecfg, self.cfg
        b = len(batch)
        maxlen = max(r.prompt_len for r in batch)
        toks = np.zeros((b, maxlen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            p = self._gen[r.uid][:r.prompt_len]
            toks[i, :len(p)] = p
            lengths[i] = len(p)
        clen = ecfg.cache_len if cfg.window is None \
            else min(ecfg.cache_len, cfg.window)
        logits, cache = lm.prefill(
            self.params, jnp.asarray(toks), cfg, cache_len=clen,
            quant_kv=ecfg.quant_kv, lengths=jnp.asarray(lengths))
        cur = self._sample(logits)
        # iteration loop: one decode step serves the whole batch
        active = list(batch)
        steps = max(r.max_new_tokens for r in batch)
        done_at: Dict[int, int] = {}
        for step in range(steps):
            for i, r in enumerate(active):
                if r.uid not in done_at:
                    self._gen[r.uid].append(int(cur[i]))
                    if (int(cur[i]) == ecfg.eos_token or
                            step + 1 >= r.max_new_tokens):
                        done_at[r.uid] = step
            self.iterations += 1
            if len(done_at) == len(active) or step == steps - 1:
                break
            logits, cache = lm.decode_step(
                self.params, cur[:, None], cache, cfg,
                quant_kv=ecfg.quant_kv)
            cur = self._sample(logits)
        for r in active:
            gen = self._gen[r.uid][r.prompt_len:]
            self.completions[r.uid] = Completion(
                uid=r.uid, tokens=gen,
                latency_s=time.time() - self._t0[r.uid])
        self.sched.step_complete([r.uid for r in active])
        # mark any remaining (shouldn't happen in sync mode)
        self.sched.running = [r for r in self.sched.running
                              if r.uid not in self.completions]

    def _sample(self, logits) -> np.ndarray:
        if self.ecfg.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        key = jax.random.PRNGKey(self.iterations)
        return np.asarray(jax.random.categorical(
            key, logits / self.ecfg.temperature, axis=-1))

    def stats(self) -> Dict[str, Any]:
        lats = [c.latency_s for c in self.completions.values()]
        toks = sum(len(c.tokens) for c in self.completions.values())
        return {"requests": len(self.completions),
                "generated_tokens": toks,
                "iterations": self.iterations,
                "weight_compression": round(self.compression, 2),
                "mean_latency_s": float(np.mean(lats)) if lats else 0.0}
