"""Self-speculative decoding: two precision plans over ONE weight tree.

SAIL's LUT-GEMV makes precision a *serving-time* knob: the same raw
weight tree quantizes to any bit width, and lower bits stream fewer
bytes per token.  That makes the classic draft-model speculative-decoding
recipe free of a second model: the *draft* is the same network
requantized aggressively (e.g. q4 when the conservative plan serves q8),
resident alongside the served tree — no ``apply_plan`` thrash, no extra
architecture.

One speculative **round** per engine iteration:

1. **draft** — ``lm.draft_tokens`` runs k single-token decode steps under
   the draft tree fused into ONE jitted dispatch, sampling between steps
   (argmax when greedy, else categorical on the DRAFT_SALT key stream).
   Draft KV lands in the shared cache at draft precision.
2. **verify** — ``lm.verify_step`` feeds the pending token plus all k
   drafts through the conservative tree in one batched multi-token
   forward, overwriting every draft-written KV slot with conservative
   KV.  Row i is the target distribution for draft i+1; row k prices the
   bonus token.
3. **accept / rollback** — the standard speculative-sampling rule
   (:meth:`SpeculativeDecoder.accept`): exact argmax equality in greedy
   mode; the p/q coin-flip with residual resampling at temperature > 0,
   on key streams salted so they never collide with the engine's
   committed-token sampler.  The engine commits the accepted prefix,
   resets per-lane cache lengths to the accepted frontier (the whole
   rollback for the ring layout), and truncates paged block-table tails
   via ``BlockSpaceManager.truncate``.

Where the speedup comes from: a round commits E[accepted]+1 tokens for
2 dispatches (draft + verify) instead of 1 dispatch per token, amortizing
per-iteration fixed costs — dispatch, host-side sampling and scheduling
— and, on the paper's machine, streaming the conservative weights once
per k+1 tokens instead of once per token.  The planner prices the
draft/verify bit gap with ``planning.speculative_round_seconds`` against
a *measured* acceptance curve (:func:`measure_acceptance`).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.sail_linear import QuantPolicy, quantize_params

#: planning-time acceptance assumed before anything is measured — the
#: q4-vs-q8 teacher-forced agreement measured on the smoke model (~0.83)
#: rounded down; a DraftSpec.acceptance or measured curve overrides it.
DEFAULT_ACCEPTANCE = 0.8


def draft_policy(base: QuantPolicy, draft) -> QuantPolicy:
    """The draft tree's quantization policy: uniform at the DraftSpec's
    aggressive bits, inheriting the conservative policy's grouping knobs
    (so both trees index the same LUT machinery)."""
    return QuantPolicy(
        bits=draft.weight_bits,
        group_size=base.group_size,
        min_size=base.min_size,
        skip_embed=base.skip_embed,
        codebook=base.codebook,
        act_bits=draft.act_bits,
    )


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def _stream_uniform(seed: int, uid: int, idx: int, salt: int) -> float:
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed),
                                              int(uid)), int(idx)), salt)
    return float(jax.random.uniform(key))


def _stream_categorical(seed: int, uid: int, idx: int, salt: int,
                        probs: np.ndarray) -> int:
    """Inverse-CDF draw from ``probs`` on the salted per-request stream
    (host-side; B is small and V is the smoke vocab on this path)."""
    u = _stream_uniform(seed, uid, idx, salt)
    return int(min(np.searchsorted(np.cumsum(probs), u),
                   len(probs) - 1))


class SpeculativeDecoder:
    """Holds the draft weight tree and the acceptance machinery.

    Constructed by the engine while the raw f32 tree is in hand (the
    engine may drop it afterwards); the draft tree stays resident for
    the engine's lifetime — requantizing per round would defeat the
    point.  ``draft_units`` are captured for the cost model so the
    planner and controller can price ``t_draft`` without the raw tree.
    """

    def __init__(self, raw_params, cfg, draft, base_policy: QuantPolicy):
        from repro import planning
        self.cfg = cfg
        self.spec = draft                    # planning.DraftSpec
        self.k = int(draft.k)
        self.policy = draft_policy(base_policy, draft)
        self.draft_units = planning.policy_units(raw_params, self.policy)
        self.draft_params, _, _ = quantize_params(raw_params, self.policy)
        # counters behind stats()["speculative"]
        self.rounds = 0
        self.drafted = 0
        self.accepted = 0

    # -- planning-side numbers --------------------------------------------

    def assumed_acceptance(self) -> float:
        """Per-token acceptance used for pricing: the DraftSpec's
        measured/solved value when present, the running measurement once
        rounds have accumulated, else the default."""
        if self.drafted >= 64:
            return self.accepted / self.drafted
        if self.spec.acceptance is not None:
            return float(self.spec.acceptance)
        return DEFAULT_ACCEPTANCE

    def expected_tokens(self) -> float:
        from repro.planning import expected_tokens_per_round
        return expected_tokens_per_round(self.assumed_acceptance(), self.k)

    # -- accept / rollback -------------------------------------------------

    def accept(self, draft: np.ndarray, verify_logits: np.ndarray,
               draft_logits: Optional[np.ndarray],
               temperature: float = 0.0, seed: int = 0,
               uids: Optional[np.ndarray] = None,
               indices: Optional[np.ndarray] = None):
        """The speculative-sampling acceptance rule, vectorized over lanes.

        draft: [B, k] drafted tokens.  verify_logits: [B, k+1, V] from the
        conservative tree (row i conditions on the pending token plus
        drafts 0..i-1).  Returns ``(n_acc [B], next_tok [B])``: the
        accepted prefix length per lane and the round's new pending token
        (the correction resampled at the first rejection, or the bonus
        draw when everything was accepted).

        Greedy (temperature == 0) degenerates to exact argmax equality —
        the draft is deterministic, so accept iff it matches what the
        conservative tree would have produced; the output token sequence
        is then identical to non-speculative decode.
        """
        b, k = draft.shape
        if temperature <= 0.0:
            targets = np.argmax(verify_logits, axis=-1)        # [B, k+1]
            matches = draft == targets[:, :k]
            n_acc = np.where(matches.all(axis=1), k,
                             np.argmin(matches, axis=1)).astype(np.int64)
            next_tok = targets[np.arange(b), n_acc]
            return n_acc, next_tok
        p = _softmax(verify_logits.astype(np.float64) / temperature)
        q = _softmax(draft_logits.astype(np.float64) / temperature)
        n_acc = np.zeros((b,), np.int64)
        next_tok = np.zeros((b,), np.int64)
        for i in range(b):
            uid = int(uids[i])
            base_idx = int(indices[i])
            a = k
            for j in range(k):
                d = int(draft[i, j])
                ratio = p[i, j, d] / max(q[i, j, d], 1e-30)
                u = _stream_uniform(seed, uid, base_idx + j, lm.ACCEPT_SALT)
                if u > ratio:
                    a = j
                    resid = np.maximum(p[i, j] - q[i, j], 0.0)
                    z = resid.sum()
                    probs = resid / z if z > 0 else p[i, j]
                    next_tok[i] = _stream_categorical(
                        seed, uid, base_idx + j, lm.RESAMPLE_SALT, probs)
                    break
            if a == k:
                next_tok[i] = _stream_categorical(
                    seed, uid, base_idx + k, lm.BONUS_SALT, p[i, k])
            n_acc[i] = a
        return n_acc, next_tok

    # -- observability -----------------------------------------------------

    def note_round(self, lanes: int, accepted: int) -> None:
        self.rounds += 1
        self.drafted += lanes * self.k
        self.accepted += int(accepted)

    def stats(self) -> Dict[str, Any]:
        return {
            "k": self.k,
            "draft_bits": self.policy.bits,
            "draft_act_bits": self.policy.act_bits,
            "rounds": self.rounds,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "acceptance_rate": (self.accepted / self.drafted
                                if self.drafted else None),
            "expected_tokens_per_round": self.expected_tokens(),
        }


def measure_acceptance(raw_params, cfg, base_policy: QuantPolicy,
                       draft_bits: int, act_bits: Optional[int] = None,
                       prompt=None, n_tokens: int = 32) -> float:
    """Measured per-token greedy acceptance of a draft bit width.

    Teacher-forced agreement: generate a greedy reference continuation
    under the CONSERVATIVE tree (quantized with ``base_policy``), then
    feed the same sequence through the draft tree and count positions
    where the draft's argmax matches the reference's next token — exactly
    the event "draft token accepted" of a greedy speculative round.  One
    number per (draft_bits, act_bits); independent of k, so the planner's
    grid reuses it across k candidates.
    """
    dp = QuantPolicy(bits=int(draft_bits), group_size=base_policy.group_size,
                     min_size=base_policy.min_size,
                     skip_embed=base_policy.skip_embed,
                     codebook=base_policy.codebook,
                     act_bits=act_bits)
    cons, _, _ = quantize_params(raw_params, base_policy)
    draft, _, _ = quantize_params(raw_params, dp)
    if prompt is None:
        prompt = [1, 2, 3, 5, 8, 13]
    prompt = [int(t) % cfg.vocab for t in prompt]
    cache_len = min(cfg.window or 4096, len(prompt) + n_tokens + 1)

    def feed(params, seq):
        """Greedy-teacher-forced argmax after each position of ``seq``."""
        logits, cache = lm.prefill(
            params, jnp.asarray([seq[:1]], jnp.int32), cfg, cache_len)
        preds = [int(jnp.argmax(logits[0]))]
        for t in seq[1:]:
            logits, cache = lm.decode_step(
                params, jnp.asarray([[t]], jnp.int32), cache, cfg)
            preds.append(int(jnp.argmax(logits[0])))
        return preds

    # reference continuation under the conservative tree
    logits, cache = lm.prefill(
        cons, jnp.asarray([prompt], jnp.int32), cfg, cache_len)
    ref = []
    tok = int(jnp.argmax(logits[0]))
    for _ in range(n_tokens):
        ref.append(tok)
        logits, cache = lm.decode_step(
            cons, jnp.asarray([[tok]], jnp.int32), cache, cfg)
        tok = int(jnp.argmax(logits[0]))
    seq = prompt + ref
    preds = feed(draft, seq)
    # preds[i] is the draft's argmax after consuming seq[:i+1]; it is an
    # accepted draft token when it equals the reference token seq[i+1]
    hits = sum(1 for i in range(len(prompt) - 1, len(seq) - 1)
               if preds[i] == seq[i + 1])
    return hits / max(len(seq) - len(prompt), 1)
