"""SloController: the autonomous control loop over ``Engine.step()``.

PRs 5-6 exposed the control surface — ``Engine.replan()``, plan
provenance in ``stats()``, ``meets_slo`` in the cost model — but nothing
closed the loop.  This module is the closing piece: a small deterministic
state machine the engine consults every iteration, with three escalating
responses to the measurements the engine already produces:

  * **shed / shrink** (occupancy control, purely model-driven and
    deterministic): the modeled iteration time ``t_iter(b)`` is
    nondecreasing in occupancy ``b`` (lookup cycles scale with batch),
    so an SLO — which bounds iteration latency at
    ``slo.seconds_per_iteration`` — admits a maximal feasible occupancy
    ``batch_cap``.  When the solved plan's ``meets_slo`` goes false at
    the pool size, the controller *shrinks* the effective decode batch
    to the cap, and admissions beyond it are *shed* (deferred in the
    FIFO, never dropped) until slots free up.
  * **replan** (drift control, measurement-driven with hysteresis):
    measured decode tokens/s is compared against the plan's modeled
    tokens/s over a sliding window.  Because the cost model prices a
    different machine than the host running the engine, drift is
    *anchored*: the first post-warmup window establishes the
    measured/modeled scale, and subsequent windows are judged relative
    to it — drift therefore means "the machine no longer behaves the way
    it did when this plan was priced", i.e. the calibration is stale.
    Only when |drift| stays outside the deadband for ``hysteresis``
    consecutive checks AND the cooldown has elapsed does the controller
    ask for a replan — no plan churn on noise.
  * **resolve** (allocation control): a replan re-prices the current
    allocation with PRT discounts measured on tapped traffic.  The
    expensive full re-solve is requested only when the tapped PRT
    hit-rate has moved by more than ``resolve_hit_delta`` from the rate
    the current plan was priced with — the only signal under which the
    solver would actually change the allocation.

The controller itself never touches the engine: it consumes numbers
(``observe``, ``decide``, ``batch_cap``) and counts its actions, and
``Engine.step()`` applies them.  That keeps the state machine unit-
testable without a model.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, Optional

ACTIONS = ("shed", "shrink", "replan", "resolve", "skipped")

#: tolerance on the modeled-feasibility comparison — a plan solved
#: exactly onto its SLO budget must not flip infeasible on float noise
_SLO_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Knobs of the SLO control loop (surfaced via ``EngineConfig``)."""

    # drift loop
    check_every: int = 8  # decode iterations between drift checks
    deadband: float = 0.25  # |anchored drift| tolerated without action
    hysteresis: int = 2  # consecutive out-of-band checks before acting
    cooldown: int = 32  # decode iterations after an action before another
    window: int = 32  # sliding window (decode iterations) for measured tps
    warmup: int = 2  # initial decode iterations ignored (jit compile)
    anchor: bool = True  # scale modeled tps by the first window's ratio
    # occupancy loop
    shed: bool = True  # defer admissions above the feasible batch cap
    min_batch: int = 1  # shrink floor (never cap below this)
    # escalation
    resolve_hit_delta: float = 0.02  # tapped PRT hit-rate delta forcing re-solve

    def __post_init__(self):
        if self.check_every < 1:
            raise ValueError(f"check_every must be >= 1, got {self.check_every}")
        if self.deadband < 0:
            raise ValueError(f"deadband must be >= 0, got {self.deadband}")
        if self.hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got {self.hysteresis}")
        if self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.min_batch < 1:
            raise ValueError(f"min_batch must be >= 1, got {self.min_batch}")
        if self.resolve_hit_delta < 0:
            raise ValueError(f"resolve_hit_delta must be >= 0, got {self.resolve_hit_delta}")

    @staticmethod
    def coerce(value: Any) -> "ControllerConfig":
        """EngineConfig.controller sugar: True / dict / ControllerConfig."""
        if isinstance(value, ControllerConfig):
            return value
        if value is True:
            return ControllerConfig()
        if isinstance(value, dict):
            return ControllerConfig(**value)
        raise TypeError(
            f"controller must be True, a dict of knobs, or a ControllerConfig, got {value!r}"
        )


class SloController:
    """The control-loop state machine (see module docstring).

    ``iter_seconds(b)`` models one decode iteration at occupancy ``b``
    (the engine supplies its memoized plan pricing); ``planned_tps`` is
    the modeled decode tokens/s at the full pool; ``slo`` bounds the
    modeled iteration latency; ``plan_hit_rate`` is the PRT hit rate the
    served plan was priced with (None until a measured replan ran).
    """

    def __init__(
        self,
        cfg: Optional[ControllerConfig] = None,
        slo=None,
        iter_seconds: Optional[Callable[[int], float]] = None,
        planned_tps: Optional[float] = None,
        plan_hit_rate: Optional[float] = None,
        tokens_per_iter: float = 1.0,
    ):
        self.cfg = cfg or ControllerConfig()
        self.slo = slo
        self._iter_seconds = iter_seconds
        self.planned_tps = planned_tps
        self.plan_hit_rate = plan_hit_rate
        # tokens each lane commits per scheduling quantum: 1 for plain
        # decode; E[accepted + 1] under speculative decoding, where one
        # "iteration" is a whole draft+verify round and the SLO's
        # per-iteration budget must be priced per committed token
        self.tokens_per_iter = float(tokens_per_iter)
        self.actions: Dict[str, int] = {a: 0 for a in ACTIONS}
        self.checks = 0
        self._window: deque = deque(maxlen=self.cfg.window)
        self._oob = 0  # consecutive out-of-band drift checks
        self._seen = 0  # decode iterations observed (incl. warmup)
        self._last_action_iter: Optional[int] = None
        self._anchor_scale: Optional[float] = None
        self._last_drift: Optional[float] = None
        self._cap: Optional[int] = None
        self._cap_pool: Optional[int] = None
        self._prev_cap: Optional[int] = None

    # -- occupancy control -------------------------------------------------

    def meets_slo_at(self, occupancy: int) -> Optional[bool]:
        """Does the modeled plan meet the SLO at this occupancy?

        The SLO bounds one masked decode iteration at
        ``slo.seconds_per_iteration`` (equivalently: each active slot's
        decode rate at its fair share ``target_tps / slo_batch``), and
        ``t_iter`` is nondecreasing in occupancy — so this flips false
        exactly once, at the feasibility boundary ``batch_cap``.
        """
        if self.slo is None or self._iter_seconds is None:
            return None
        # an SLO quotes tokens/s; one scheduling quantum delivers
        # tokens_per_iter tokens per lane (1 plain, E[accepted+1] for a
        # speculative round), so the latency budget scales with it
        budget = self.slo.seconds_per_iteration * self.tokens_per_iter
        return self._iter_seconds(int(occupancy)) <= budget * (1 + _SLO_EPS)

    def batch_cap(self, pool: int, free_cap: Optional[int] = None) -> int:
        """Largest occupancy (<= pool) at which the plan still meets the
        SLO, floored at ``min_batch``.  A cap below the pool counts one
        ``shrink`` action each time it tightens.

        ``free_cap``: optional second bound from KV memory — with a paged
        block pool, occupancy is feasible only if the blocks exist to
        back it, so the cap is ``min(modeled cap, free_cap)``.  The memory
        bound does not count ``shrink`` actions (that counter tracks the
        modeled-SLO lever; block exhaustion is reported by the engine's
        ``block_pool`` stats instead).
        """
        pool = int(pool)
        key = (pool, None if free_cap is None else int(free_cap))
        if self._cap is not None and self._cap_pool == key:
            return self._cap
        cap = pool
        if self.slo is not None and self._iter_seconds is not None:
            cap = 0
            for b in range(1, pool + 1):
                if not self.meets_slo_at(b):
                    break  # t_iter is nondecreasing: no later b can pass
                cap = b
            cap = max(cap, self.cfg.min_batch)
            cap = min(cap, pool)
        if self._prev_cap is not None and cap < self._prev_cap:
            self.actions["shrink"] += 1
        elif self._prev_cap is None and cap < pool:
            self.actions["shrink"] += 1
        self._prev_cap = cap
        if free_cap is not None:
            cap = max(min(cap, int(free_cap)), self.cfg.min_batch)
        self._cap, self._cap_pool = cap, key
        return cap

    def record_shed(self, n: int = 1) -> None:
        """The engine deferred ``n`` admissions that free slots could
        have taken, because occupancy sits at the cap."""
        self.actions["shed"] += int(n)

    # -- drift control -----------------------------------------------------

    def measured_tps(self) -> Optional[float]:
        """Windowed decode throughput (tokens/s over the sliding window)."""
        if not self._window:
            return None
        toks = sum(t for t, _, _ in self._window)
        secs = sum(s for _, s, _ in self._window)
        if secs <= 0:
            return None
        return toks / secs

    def drift(self) -> Optional[float]:
        """Last computed anchored drift (None before the anchor is set)."""
        return self._last_drift

    def _expected_seconds(self, occupancy: int) -> Optional[float]:
        """Modeled seconds of one iteration at this occupancy — the
        per-iteration reference the drift window accumulates.  Comparing
        at the iteration's OWN occupancy keeps legitimate occupancy
        swings (requests finishing, bursts landing) out of the drift
        signal; only behavior-vs-model change remains."""
        if self._iter_seconds is not None:
            return self._iter_seconds(int(occupancy))
        if self.planned_tps is not None and self.planned_tps > 0:
            return occupancy / self.planned_tps
        return None

    def observe(self, tokens: int, seconds: float, iteration: int) -> bool:
        """Feed one decode iteration (``tokens`` = occupancy, i.e. slots
        decoded; ``seconds`` = measured wall time); returns True when the
        drift loop wants an action (the engine then calls :meth:`decide`
        and applies/reports the result via :meth:`acted`).

        ``iteration`` is the engine's decode-iteration counter — the
        controller's clock for warmup, check cadence, and cooldown.
        """
        self._seen += 1
        if self._seen <= self.cfg.warmup:
            return False  # jit-compile iterations would poison the window
        expected = self._expected_seconds(tokens)
        if expected is None:
            return False
        self._window.append((int(tokens), float(seconds), float(expected)))
        if iteration % self.cfg.check_every != 0:
            return False
        secs = sum(s for _, s, _ in self._window)
        exp = sum(e for _, _, e in self._window)
        if secs <= 0 or exp <= 0:
            return False
        self.checks += 1
        # throughput-like ratio: > 1 means the window ran FASTER than
        # the model expected at its occupancy mix
        ratio = exp / secs
        if self.cfg.anchor:
            if self._anchor_scale is None:
                # first post-warmup window calibrates the measured/modeled
                # scale; drift is then relative behavior change
                self._anchor_scale = ratio
                self._last_drift = 0.0
                return False
            self._last_drift = ratio / self._anchor_scale - 1.0
        else:
            self._last_drift = ratio - 1.0
        if abs(self._last_drift) <= self.cfg.deadband:
            self._oob = 0  # hysteresis: deadband re-entry resets the count
            return False
        self._oob += 1
        if self._oob < self.cfg.hysteresis:
            return False
        if (
            self._last_action_iter is not None
            and iteration - self._last_action_iter < self.cfg.cooldown
        ):
            return False
        return True

    def decide(
        self, tapped_hit_rate: Optional[float] = None, plan_hit_rate: Optional[float] = None
    ) -> str:
        """Escalation policy: ``"resolve"`` only when the tapped PRT
        hit-rate delta would actually move the allocation, else
        ``"replan"`` (re-price only)."""
        ref = plan_hit_rate if plan_hit_rate is not None else self.plan_hit_rate
        if (
            tapped_hit_rate is not None
            and ref is not None
            and abs(tapped_hit_rate - ref) > self.cfg.resolve_hit_delta
        ):
            return "resolve"
        return "replan"

    def acted(self, action: str, iteration: int) -> None:
        """Record an applied (or skipped) action and arm the cooldown."""
        if action not in ACTIONS:
            raise ValueError(f"unknown action {action!r} (expected one of {ACTIONS})")
        self.actions[action] += 1
        self._last_action_iter = int(iteration)
        self._oob = 0
        self._window.clear()

    def plan_changed(
        self,
        iter_seconds: Optional[Callable[[int], float]] = None,
        planned_tps: Optional[float] = None,
        plan_hit_rate: Optional[float] = None,
        tokens_per_iter: Optional[float] = None,
    ) -> None:
        """The engine swapped plans: re-anchor drift against the new
        model and recompute the occupancy cap on next use."""
        if iter_seconds is not None:
            self._iter_seconds = iter_seconds
        if planned_tps is not None:
            self.planned_tps = planned_tps
        if plan_hit_rate is not None:
            self.plan_hit_rate = plan_hit_rate
        if tokens_per_iter is not None:
            self.tokens_per_iter = float(tokens_per_iter)
        self._anchor_scale = None
        self._last_drift = None
        self._oob = 0
        self._window.clear()
        self._cap = None
        self._cap_pool = None

    # -- reporting ---------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "batch_cap": self._cap,
            "checks": self.checks,
            "drift": self._last_drift,
            "measured_window_tps": self.measured_tps(),
            **{a: self.actions[a] for a in ACTIONS},
        }
