"""Block-space manager for the paged KV cache.

Host-side bookkeeping only: this module never touches device arrays. The
engine owns the physical pool (``lm.init_paged_cache``); this class owns
which physical block holds which logical block of which request.

Invariants (enforced by ``check_invariants``, exercised by property tests):

- Every physical block is either on the free list or has a refcount >= 1;
  the two sets partition ``range(num_blocks)`` at all times.
- A block's refcount equals the number of request tables that contain it,
  so ``sum(refcounts) == sum(len(table) for table in tables)``.
- Block tables are append-only per request until eviction: entries are
  only ever appended (``append_slot``) or swapped in place by copy-on-write;
  they shrink only when the whole request is freed or preempted.
- A block appears in the prefix registry only while its contents are
  immutable: registration is dropped the moment a sole owner is about to
  write into it, and copy-on-write redirects writers away from shared
  blocks, so registry hits always reference bit-identical KV rows.
- Prefix keys are the exact token prefix (a tuple), chained per block:
  block ``j`` of a prompt is registered under ``tokens[: min((j+1)*bs, n)]``,
  including the partial frontier block, so two identical prompts share
  every block and prompts diverging mid-block share every block before
  the divergent one.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class BlockSpaceManager:
    """Refcounted pool of fixed-size KV blocks with prefix sharing.

    ``num_blocks`` counts *usable* blocks; the engine typically allocates
    one extra physical "trash" block (index ``num_blocks``) that masked
    scatter lanes write into — that block is never managed here.
    """

    num_blocks: int
    block_size: int
    share_prefix: bool = True

    _free: List[int] = field(default_factory=list)
    _ref: Dict[int, int] = field(default_factory=dict)
    _tables: Dict[int, List[int]] = field(default_factory=dict)
    _shared: Dict[int, int] = field(default_factory=dict)  # uid -> shared prefix blocks
    _key_to_block: Dict[Tuple[int, ...], int] = field(default_factory=dict)
    _block_to_key: Dict[int, Tuple[int, ...]] = field(default_factory=dict)

    # counters for stats()
    peak_used: int = 0
    alloc_count: int = 0  # fresh blocks handed out
    shared_hits: int = 0  # table entries satisfied by the prefix registry
    cow_count: int = 0
    preemptions: int = 0

    def __post_init__(self) -> None:
        if self.num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        self._free = list(range(self.num_blocks))

    # -- capacity ---------------------------------------------------------

    def blocks_needed(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def _match_prefix(self, prompt: Tuple[int, ...]) -> int:
        """Number of leading blocks of ``prompt`` already in the registry."""
        if not self.share_prefix:
            return 0
        n = 0
        for j in range(self.blocks_needed(len(prompt))):
            end = min((j + 1) * self.block_size, len(prompt))
            if prompt[:end] not in self._key_to_block:
                break
            n += 1
        return n

    def can_allocate(self, prompt: Sequence[int]) -> bool:
        prompt = tuple(prompt)
        need = self.blocks_needed(len(prompt)) - self._match_prefix(prompt)
        return need <= len(self._free)

    def admission_cap(self, prompts: Sequence[Sequence[int]]) -> int:
        """How many of ``prompts`` (FIFO order) fit in the current free pool.

        Pure estimate — no state is mutated. Intra-batch sharing between the
        candidate prompts themselves is ignored, so the cap is conservative.
        """
        free = len(self._free)
        cap = 0
        for prompt in prompts:
            prompt = tuple(prompt)
            need = self.blocks_needed(len(prompt)) - self._match_prefix(prompt)
            if need > free:
                break
            free -= need
            cap += 1
        return cap

    # -- registry ---------------------------------------------------------

    def _register(self, block: int, key: Tuple[int, ...]) -> None:
        if not self.share_prefix:
            return
        if key in self._key_to_block:
            return  # first writer wins; duplicates keep their private copy
        self._key_to_block[key] = block
        self._block_to_key[block] = key

    def _unregister(self, block: int) -> None:
        key = self._block_to_key.pop(block, None)
        if key is not None:
            del self._key_to_block[key]

    # -- lifecycle --------------------------------------------------------

    def allocate(self, uid: int, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Build ``uid``'s block table for ``prompt``.

        Returns ``(table, n_shared)`` where the first ``n_shared`` table
        entries are registry hits the engine must NOT rewrite during
        prefill (their KV rows are already populated and shared).
        """
        if uid in self._tables:
            raise KeyError(f"uid {uid} already has a block table")
        prompt = tuple(prompt)
        nb = self.blocks_needed(len(prompt))
        n_shared = self._match_prefix(prompt)
        if nb - n_shared > len(self._free):
            raise MemoryError(
                f"need {nb - n_shared} free blocks, have {len(self._free)}"
            )
        table: List[int] = []
        for j in range(n_shared):
            end = min((j + 1) * self.block_size, len(prompt))
            blk = self._key_to_block[prompt[:end]]
            self._ref[blk] += 1
            self.shared_hits += 1
            table.append(blk)
        for j in range(n_shared, nb):
            blk = self._free.pop(0)
            self._ref[blk] = 1
            self.alloc_count += 1
            end = min((j + 1) * self.block_size, len(prompt))
            self._register(blk, prompt[:end])
            table.append(blk)
        self._tables[uid] = table
        self._shared[uid] = n_shared
        self.peak_used = max(self.peak_used, self.used_blocks)
        return list(table), n_shared

    def append_slot(self, uid: int, position: int) -> Optional[Tuple[str, int, int]]:
        """Make position ``position`` of ``uid`` safely writable.

        Called once per request per decode step, *before* the decode write.
        Returns one of::

            ("inplace", block, block)  write lands in an existing private block
            ("alloc",   block, block)  a fresh block was appended to the table
            ("cow",     src,   dst)    engine must copy pool[src] -> pool[dst]
            None                       pool exhausted — caller must preempt

        Any block this request is about to write into leaves the prefix
        registry (or is replaced by a private copy), keeping registry hits
        immutable.
        """
        table = self._tables[uid]
        logical = position // self.block_size
        if logical > len(table):
            raise ValueError(
                f"uid {uid}: position {position} skips past table of {len(table)}"
            )
        if logical == len(table):
            if not self._free:
                return None
            blk = self._free.pop(0)
            self._ref[blk] = 1
            self.alloc_count += 1
            table.append(blk)
            self.peak_used = max(self.peak_used, self.used_blocks)
            return ("alloc", blk, blk)
        blk = table[logical]
        if self._ref[blk] > 1:
            if not self._free:
                return None
            dst = self._free.pop(0)
            self._ref[blk] -= 1
            self._ref[dst] = 1
            self.alloc_count += 1
            self.cow_count += 1
            table[logical] = dst
            if self._shared.get(uid, 0) > logical:
                self._shared[uid] = logical
            self.peak_used = max(self.peak_used, self.used_blocks)
            return ("cow", blk, dst)
        self._unregister(blk)
        return ("inplace", blk, blk)

    def table(self, uid: int) -> List[int]:
        return list(self._tables[uid])

    def shared_prefix_blocks(self, uid: int) -> int:
        return self._shared.get(uid, 0)

    def has_table(self, uid: int) -> bool:
        return uid in self._tables

    def free(self, uid: int) -> None:
        """Release all of ``uid``'s blocks (refcount-aware)."""
        for blk in self._tables.pop(uid):
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                del self._ref[blk]
                self._unregister(blk)
                self._free.append(blk)
        self._free.sort()
        self._shared.pop(uid, None)

    def preempt(self, uid: int) -> None:
        """Evict ``uid``'s blocks under pressure (recompute-style preemption)."""
        self.free(uid)
        self.preemptions += 1

    def truncate(self, uid: int, n_tokens: int) -> int:
        """Shrink ``uid``'s table to cover exactly ``n_tokens`` tokens.

        Speculative rollback: verify writes KV for all k+1 candidate
        positions, so a rejection can leave granted blocks past the
        accepted frontier.  Releases every table entry beyond
        ``blocks_needed(n_tokens)`` (refcount-aware) and returns how many
        entries were dropped — the engine trash-redirects that many table
        tail slots on device.  The kept frontier block may hold stale
        rows past the frontier; they are unreadable (validity admits only
        held <= position) and are overwritten in order as the request
        advances.
        """
        table = self._tables[uid]
        keep = self.blocks_needed(n_tokens) if n_tokens > 0 else 0
        dropped = len(table) - keep
        if dropped <= 0:
            return 0
        for blk in table[keep:]:
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                del self._ref[blk]
                self._unregister(blk)
                self._free.append(blk)
        del table[keep:]
        self._free.sort()
        if self._shared.get(uid, 0) > keep:
            self._shared[uid] = keep
        return dropped

    # -- invariants / stats ----------------------------------------------

    def check_invariants(self) -> None:
        live = set(self._ref)
        free = set(self._free)
        if live & free:
            raise AssertionError(f"blocks both live and free: {live & free}")
        if live | free != set(range(self.num_blocks)):
            raise AssertionError("free + live blocks do not partition the pool")
        if len(free) != len(self._free):
            raise AssertionError("duplicate entries on the free list")
        counts: Dict[int, int] = {}
        for table in self._tables.values():
            for blk in table:
                counts[blk] = counts.get(blk, 0) + 1
        if counts != self._ref:
            raise AssertionError(f"refcounts {self._ref} != table counts {counts}")
        for key, blk in self._key_to_block.items():
            if self._block_to_key.get(blk) != key:
                raise AssertionError("prefix registry maps are out of sync")
            if blk not in self._ref:
                raise AssertionError(f"registered block {blk} is not live")

    def stats(self) -> dict:
        total = self.alloc_count + self.shared_hits
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "peak_blocks": self.peak_used,
            "shared_blocks": sum(1 for r in self._ref.values() if r > 1),
            "shared_hits": self.shared_hits,
            "shared_ratio": self.shared_hits / total if total else 0.0,
            "cow_count": self.cow_count,
            "preemptions": self.preemptions,
        }
