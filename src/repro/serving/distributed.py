"""Tensor-parallel quantized serving: the LUT weight tree sharded over a mesh.

SAIL's premise is that quantized LUT-GEMV makes commodity hardware serve
LLMs economically — but one device caps the model size and tokens/s this
reproduction can claim.  This module shards the *quantized* weight tree
itself over a ``make_mesh((1, M), ("data", "model"))`` mesh, Megatron
style, and runs the serving entry points (decode step, slot/paged
prefill) under ``shard_map`` so each shard executes the existing LUT-GEMV
kernels on its slice unchanged:

  * column-parallel (``wq/wk/wv/w_gate/w_up``): output dim on the model
    axis — each shard owns ``n_heads/M`` query heads, ``n_kv/M`` KV
    heads, and ``d_ff/M`` of the gate/up projection;
  * row-parallel (``wo``, ``w_down``): reduction dim on the model axis,
    partial sums combined by one ``psum`` per attention and one per MLP
    (the ``tp_all_reduce`` hooks in ``repro.models``);
  * quantized leaves: packed codes and group scales partition along the
    same logical axes as the matrix they encode (group quantization is
    per-group independent, so a contiguous K-slice carries exactly its
    own groups' codes and scales); codebooks and every 1-D param are
    replicated;
  * embeddings, ``lm_head``, and norms are replicated, so logits are
    computed redundantly on every shard and greedy decode is trivially
    shard-count invariant;
  * the KV pool shards on the kv-head axis (axis 3 of both the ring
    ``[L, B, S, n_kv, Dh]`` and paged ``[L, NB, BS, n_kv, Dh]`` layouts)
    — block tables, lengths, and all pool *accounting* stay logical and
    replicated, so the engine's scheduler/block manager never see TP.

``wire_bits=8`` sends int8+scale compressed partial sums through the
all-reduce (``dist/compress.py`` generalized from gradients to
activations, error feedback off — inference has no next iteration to
carry a residual into).  ``wire_bits=32`` is exact up to float summation
order; greedy token-identity vs ``tp=1`` is CI-gated in
``benchmarks/serve_bench.py --tp``.

Trace hygiene: the shard_map bodies call the *unjitted* ``lm`` functions
(``decode_step.__wrapped__`` / ``prefill`` + the raw scatter helpers)
inside ``repro.dist.sharding.tp_context``, so the collective hooks lower
exactly where this module traces them and no inner jit can cache a
collective-free trace against the same avals.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (_NAME_RE, _QUANT_FIELDS, _ROW_PARALLEL,
                                 _trim_spec, tp_context)
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QTensor, StackedQTensor
from repro.planning.cost import tp_allreduce_elems

__all__ = [
    "TPServing", "local_config", "localize_params", "serving_param_spec",
    "shard_alignment_error", "tp_allreduce_elems", "tp_supported",
]

# The seven block matrices TP shards; everything else is replicated so
# every shard holds the full LUT machinery and the full logits path.
_COLUMN_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up")


def tp_supported(cfg: ModelConfig, tp: int) -> Optional[str]:
    """Why this (config, shard count) cannot serve tensor-parallel —
    ``None`` when it can.

    TP serving covers the dense GQA attention family (the architectures
    the LUT-GEMV decode path itself serves); recurrent state, expert
    routing, and bias-after-reduce layouts are explicitly out of scope
    rather than silently wrong.
    """
    if tp <= 1:
        return None
    if cfg.family != "dense":
        return (f"family={cfg.family!r} is not tensor-parallel servable "
                "(dense attention only — recurrent/expert state does not "
                "shard on the model axis)")
    if cfg.attention_bias or cfg.mlp_bias:
        return ("attention/MLP biases are not supported under TP (bias "
                "addition must move after the partial-sum reduce)")
    if cfg.n_heads % tp:
        return f"n_heads={cfg.n_heads} not divisible by tp={tp}"
    if cfg.n_kv % tp:
        return f"n_kv={cfg.n_kv} not divisible by tp={tp}"
    if cfg.d_ff % tp:
        return f"d_ff={cfg.d_ff} not divisible by tp={tp}"
    return None


def local_config(cfg: ModelConfig, tp: int) -> ModelConfig:
    """The per-shard view of ``cfg``: each shard runs the unchanged model
    code over its own heads and FFN slice.  ``d_head`` is pinned because
    it defaults to ``d_model // n_heads`` and must not change when
    ``n_heads`` shrinks."""
    if tp <= 1:
        return cfg
    return dataclasses.replace(
        cfg, n_heads=cfg.n_heads // tp, n_kv=cfg.n_kv // tp,
        d_ff=cfg.d_ff // tp, d_head=cfg.head_dim)


def serving_param_spec(path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec of one serving parameter over the ("data", "model")
    mesh.

    Differs from the training rule (``dist.sharding.param_spec``) where
    serving correctness demands it: embeddings and ``lm_head`` are
    REPLICATED (every shard computes the full logits row, so argmax
    needs no gather), and only the seven block matrices shard.  Quantized
    leaves follow the matrix they encode; codebooks replicate.
    """
    nd = len(shape)
    quant_field = next((f for f in _QUANT_FIELDS if path.endswith(f)), None)
    if quant_field is not None:
        if quant_field == ".codebook":
            return P(*([None] * nd))
        return serving_param_spec(path[: -len(quant_field)], shape)
    names = _NAME_RE.findall(path)
    leaf = names[-1] if names else ""
    spec: list = [None] * nd
    if nd >= 2 and leaf in _ROW_PARALLEL:
        spec[-2] = "model"
    elif nd >= 2 and leaf in _COLUMN_PARALLEL:
        spec[-1] = "model"
    return P(*spec)


def _cache_spec(shape: Tuple[int, ...]) -> P:
    """KV pool arrays ([L, B|NB, S|BS, n_kv, Dh] and their scale
    companions) shard on the kv-head axis; ``length`` and any other
    bookkeeping replicate."""
    if len(shape) == 5:
        return P(None, None, None, "model", None)
    return P(*([None] * len(shape)))


def _is_qtensor(x) -> bool:
    return isinstance(x, (QTensor, StackedQTensor))


def localize_params(params, tp: int):
    """Fix up static QTensor metadata for the per-shard view.

    A row-parallel quantized leaf arrives inside the shard_map body with
    its arrays already sliced to the local K range, but ``k`` is static
    metadata carried by the treedef — still the global value.  Rewrite it
    to ``k // tp`` on ``wo``/``w_down`` leaves so ``unpack_grouped`` and
    the kernels see a self-consistent local tensor.  Column-parallel and
    replicated quantized leaves keep their full K and need no change.
    """
    if tp <= 1:
        return params

    def one(key_path, leaf):
        if not _is_qtensor(leaf):
            return leaf
        names = _NAME_RE.findall(jax.tree_util.keystr(key_path))
        if names and names[-1] in _ROW_PARALLEL:
            return dataclasses.replace(leaf, k=leaf.k // tp)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params, is_leaf=_is_qtensor)


def shard_alignment_error(params, tp: int) -> Optional[str]:
    """Why this quantized tree cannot shard ``tp`` ways — ``None`` when
    it can.  Row-parallel quantized leaves split their K dim, so the
    per-shard slice must cover whole quantization groups:
    ``(k // group_size) % tp == 0``."""
    if tp <= 1:
        return None
    problems = []

    def one(key_path, leaf):
        if not _is_qtensor(leaf):
            return
        names = _NAME_RE.findall(jax.tree_util.keystr(key_path))
        if names and names[-1] in _ROW_PARALLEL:
            groups = leaf.k // leaf.group_size
            if groups % tp:
                problems.append(
                    f"{names[-1]}: {groups} quant groups (k={leaf.k}, "
                    f"G={leaf.group_size}) not divisible by tp={tp}")

    jax.tree_util.tree_map_with_path(one, params, is_leaf=_is_qtensor)
    return "; ".join(problems) if problems else None


class TPServing:
    """Sharded drop-in for the ``lm`` serving entry points.

    Owns the ``(1, M)`` mesh, the placement rules, and a memoized family
    of jitted ``shard_map`` wrappers around ``lm.decode_step`` /
    ``lm.prefill`` (+ the pool scatter helpers).  The engine constructs
    one when ``tp > 1``, places its params/cache through
    :meth:`shard_params` / :meth:`shard_cache`, and routes every model
    call here; scheduling, sampling, and block accounting stay logical.
    """

    def __init__(self, cfg: ModelConfig, tp: int, wire_bits: int = 32):
        reason = tp_supported(cfg, tp)
        if reason is not None:
            raise ValueError(f"tensor-parallel serving unavailable: {reason}")
        if wire_bits not in (8, 32):
            raise ValueError(f"wire_bits must be 8 or 32, got {wire_bits}")
        if len(jax.devices()) < tp:
            raise ValueError(
                f"tp={tp} needs {tp} devices but only "
                f"{len(jax.devices())} are visible — on CPU set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "before importing jax")
        self.cfg = cfg
        self.tp = int(tp)
        self.wire_bits = int(wire_bits)
        self.mesh = make_mesh((1, self.tp), ("data", "model"))
        self.lcfg = local_config(cfg, self.tp)
        self._fns: Dict[Any, Any] = {}

    # -- placement ---------------------------------------------------------

    def _leaf_spec(self, key_path, leaf, kind: str) -> P:
        shape = tuple(getattr(leaf, "shape", ()))
        if kind == "params":
            spec = serving_param_spec(jax.tree_util.keystr(key_path), shape)
        elif kind == "cache":
            spec = _cache_spec(shape)
        else:
            spec = P(*([None] * len(shape)))
        return _trim_spec(spec, shape, self.mesh)

    def _spec_tree(self, tree, kind: str):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: self._leaf_spec(p, l, kind), tree)

    def _sharding_tree(self, tree, kind: str):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(self.mesh,
                                       self._leaf_spec(p, l, kind)), tree)

    def shard_params(self, params):
        """Place a (quantized or raw) parameter tree onto the mesh.

        Raises when a row-parallel quantized leaf's group count does not
        divide the shard count — ``_trim_spec`` would silently replicate
        it, and a replicated K-slice under a local-``k`` fixup is wrong,
        not slow."""
        err = shard_alignment_error(params, self.tp)
        if err is not None:
            raise ValueError(
                f"quantized tree cannot shard tp={self.tp} ways: {err} — "
                "use a group_size whose per-matrix group count divides "
                "the shard count")
        return jax.device_put(params, self._sharding_tree(params, "params"))

    def shard_cache(self, cache):
        """Place a KV pool (ring or paged) onto the mesh."""
        return jax.device_put(cache, self._sharding_tree(cache, "cache"))

    def allreduce_bytes_per_iter(self, batch: int) -> int:
        """All-reduce bytes one decode iteration moves per shard (the
        ring all-reduce's 2(M-1)/M factor applied to the payload)."""
        payload = batch * tp_allreduce_elems(self.cfg) * self.wire_bits // 8
        return int(payload * 2 * (self.tp - 1) / self.tp)

    # -- shard_map wrappers ------------------------------------------------

    def _kind_of(self, key_path) -> str:
        names = _NAME_RE.findall(jax.tree_util.keystr(key_path))
        return names[0] if names else ""

    def _build(self, kind: str, arrays: Dict[str, Any], body):
        in_spec = jax.tree_util.tree_map_with_path(
            lambda p, l: self._leaf_spec(
                p, l, {"params": "params", "cache": "cache"}.get(
                    self._kind_of(p), "other")), arrays)
        out_spec = (P(None, None), in_spec["cache"])
        return jax.jit(shard_map(body, mesh=self.mesh, in_specs=(in_spec,),
                                 out_specs=out_spec, check_rep=False))

    def _get(self, key, arrays, body):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build(key[0], arrays, body)
            self._fns[key] = fn
        return fn

    def decode_step(self, params, tokens, cache, quant_kv: bool = False,
                    active_mask=None, block_tables=None):
        """One TP decode iteration: (logits [B, V] replicated, sharded
        cache).  Mirrors ``lm.decode_step`` minus the tap capture path
        (taps are gated off under TP)."""
        arrays: Dict[str, Any] = {"params": params, "tokens": tokens,
                                  "cache": cache}
        if active_mask is not None:
            arrays["active_mask"] = active_mask
        if block_tables is not None:
            arrays["block_tables"] = block_tables
        lcfg, tp, wire = self.lcfg, self.tp, self.wire_bits

        def body(a):
            local = localize_params(a["params"], tp)
            with tp_context("model", wire):
                return lm.decode_step.__wrapped__(
                    local, a["tokens"], a["cache"], lcfg,
                    quant_kv=quant_kv,
                    active_mask=a.get("active_mask"),
                    block_tables=a.get("block_tables"))

        key = ("decode", bool(quant_kv), frozenset(arrays))
        return self._get(key, arrays, body)(arrays)

    def prefill_into_slot(self, params, tokens, cache, slots,
                          quant_kv: bool = False, lengths=None):
        """TP slot prefill: prefill under shard_map, scatter the fresh
        (sharded) cache rows into the pool with the raw scatter helper —
        the kv-head axis is untouched by the slot scatter, so the write
        stays shard-local."""
        arrays: Dict[str, Any] = {
            "params": params, "tokens": tokens, "cache": cache,
            "slots": jnp.atleast_1d(jnp.asarray(slots, jnp.int32))}
        if lengths is not None:
            arrays["lengths"] = lengths
        lcfg, tp, wire = self.lcfg, self.tp, self.wire_bits

        def body(a):
            local = localize_params(a["params"], tp)
            cache_len = a["cache"]["layers"]["k"].shape[2]
            with tp_context("model", wire):
                logits, fresh = lm.prefill(
                    local, a["tokens"], lcfg, cache_len=cache_len,
                    quant_kv=quant_kv, lengths=a.get("lengths"))
            return logits, lm._scatter_slots(a["cache"], fresh, a["slots"])

        key = ("prefill_slot", bool(quant_kv), frozenset(arrays))
        return self._get(key, arrays, body)(arrays)

    def prefill_into_blocks(self, params, tokens, cache, slots, phys, offs,
                            quant_kv: bool = False, lengths=None):
        """TP paged prefill: same shape as :meth:`prefill_into_slot`
        with the block-scatter helper; ``phys``/``offs`` destinations are
        logical (block, offset) pairs and replicate."""
        arrays: Dict[str, Any] = {
            "params": params, "tokens": tokens, "cache": cache,
            "slots": jnp.atleast_1d(jnp.asarray(slots, jnp.int32)),
            "phys": jnp.asarray(phys, jnp.int32),
            "offs": jnp.asarray(offs, jnp.int32)}
        if lengths is not None:
            arrays["lengths"] = lengths
        lcfg, tp, wire = self.lcfg, self.tp, self.wire_bits

        def body(a):
            local = localize_params(a["params"], tp)
            with tp_context("model", wire):
                logits, fresh = lm.prefill(
                    local, a["tokens"], lcfg,
                    cache_len=a["tokens"].shape[1],
                    quant_kv=quant_kv, lengths=a.get("lengths"))
            return logits, lm._scatter_blocks(a["cache"], fresh, a["slots"],
                                              a["phys"], a["offs"])

        key = ("prefill_blocks", bool(quant_kv), frozenset(arrays))
        return self._get(key, arrays, body)(arrays)
