"""Compressed gradient all-reduce with error feedback.

Data-parallel gradient exchange is the bandwidth hot spot at 512 chips;
the same int8 + per-tensor-scale format SAIL uses for the KV cache would
cut the all-reduce bytes 4x.  This module emulates that exchange's
*numerics* at the XLA level: each step quantizes ``grad + err`` to int8
codes + a per-tensor scale, reduces the dequantized values (``pmean``
over f32 — XLA picks the wire format, so the 4x byte cut is a property
of a backend that reduces the codes directly, not of this lowering),
and keeps the residual locally in a persistent error-feedback state
(1-bit-Adam style), so the *time-averaged* applied gradient is unbiased.
Use it to validate convergence under compression before committing to a
custom int8 collective.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh


def init_error_state(grads):
    """Zero residual matching the gradient pytree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(getattr(g, "shape", ()), jnp.float32), grads)


def _quantize_dequantize(x: jax.Array) -> jax.Array:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes.astype(jnp.float32) * scale


def make_compressed_allreduce(mesh: Mesh, axes: Sequence[str], specs):
    """Build ``fn(grads, err) -> (mean_grads, new_err)``.

    ``specs``: pytree of PartitionSpecs matching the gradient tree (how
    each per-device gradient shard is laid out).  The mean is taken over
    ``axes``; what crosses the interconnect is the int8-quantized
    ``grad + err``, and the residual stays on-device.
    """
    axes = tuple(axes)

    def shard_fn(grads, err):
        def one(g, e):
            x = g.astype(jnp.float32) + e
            y = _quantize_dequantize(x)
            mean = jax.lax.pmean(y, axes)
            return mean, x - y
        pairs = jax.tree_util.tree_map(one, grads, err)
        mean = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                      is_leaf=lambda p: isinstance(p, tuple))
        new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                         is_leaf=lambda p: isinstance(p, tuple))
        return mean, new_err

    fn = shard_map(shard_fn, mesh=mesh, in_specs=(specs, specs),
                   out_specs=(specs, specs))
    return jax.jit(fn)
