"""Distribution layer: sharding plans/rules and compressed collectives."""
