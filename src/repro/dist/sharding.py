"""Sharding plan + parameter placement rules for the production meshes.

One logical plan covers every launcher: a data axis (optionally split
``pod x data``) carries the batch, a model axis carries tensor-parallel
weight shards.  Rules are name-based over the parameter tree:

  * column-parallel (``wq/wk/wv/w_gate/w_up`` and other in->out
    projections): last dim on the model axis, second-to-last FSDP-sharded
    over the data axes when the plan enables FSDP;
  * row-parallel (``wo``, ``w_down``): model axis on the reduction dim,
    FSDP on the output dim;
  * embeddings: vocab (dim 0) on the model axis;
  * quantized QTensor leaves: packed codes and group scales partition
    along the same logical axes as the matrix they encode (the parent
    rule applied at the leaf's rank — group-quantization keeps both the
    K-derived dim at position -2 and the N dim at position -1, and
    stacked-layer leading dims align), codebooks replicated;
  * 1-D params (norm scales, biases) replicated.

``_trim_spec`` makes every rule safe: any mesh axis that is absent or
does not divide the concrete dim is dropped, so smoke configs with odd
head counts (or group counts that don't divide the shard count) lower
without GSPMD errors.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

AxisName = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class Plan:
    """Logical placement plan resolved against a concrete mesh."""
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"
    fsdp: bool = False

    @property
    def dp(self) -> AxisName:
        if not self.dp_axes:
            return None
        return self.dp_axes[0] if len(self.dp_axes) == 1 else self.dp_axes


def make_plan(mesh: Mesh, cfg: ModelConfig,
              fsdp: Optional[bool] = None) -> Plan:
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data", "batch") if a in names)
    tp = "model" if sizes.get("model", 1) > 1 else None
    if fsdp is None:
        fsdp = any(sizes.get(a, 1) > 1 for a in dp_axes)
    return Plan(dp_axes=dp_axes, tp_axis=tp, fsdp=bool(fsdp))


# ---------------------------------------------------------------------------
# constraint helper used inside model code (no-op outside a mesh context)
# ---------------------------------------------------------------------------

def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


_LOGICAL_DP = ("pod", "data")


def maybe_constrain(x: jax.Array, *logical: AxisName) -> jax.Array:
    """``with_sharding_constraint`` iff called under an active mesh.

    Logical axis names: ``"batch"`` maps onto the mesh's data axes,
    ``"model"`` onto the tensor-parallel axis; anything the mesh lacks
    (or that does not divide the dim) is silently dropped, so model code
    can annotate unconditionally.
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    spec_entries = []
    for ax in logical:
        if ax == "batch":
            dp = tuple(a for a in _LOGICAL_DP if a in names)
            spec_entries.append(dp if len(dp) > 1 else
                                (dp[0] if dp else None))
        else:
            spec_entries.append(ax)
    spec = _trim_spec(P(*spec_entries), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter placement rules
# ---------------------------------------------------------------------------

_ROW_PARALLEL = ("wo", "w_down")
_EMBED = ("embed", "pos_embed")
_QUANT_FIELDS = (".packed", ".scales", ".codebook")
_NAME_RE = re.compile(r"\['([^']+)'\]")


def param_spec(path: str, shape: Sequence[int], cfg: ModelConfig,
               plan: Plan) -> P:
    """PartitionSpec for one parameter, by tree path + shape.

    ``path`` is ``jax.tree_util.keystr`` form, e.g.
    ``"['blocks']['attn']['wq']"``.  Leading stacked-layer / expert dims
    are never sharded (they ride through ``lax.scan``).
    """
    nd = len(shape)
    if nd == 0:
        return P()
    quant_field = next((f for f in _QUANT_FIELDS if path.endswith(f)), None)
    if quant_field is not None:
        if quant_field == ".codebook":
            # LUT machinery is tiny and every shard needs the full table
            # (stacked codebooks [L, 2^bits] included)
            return P(*([None] * nd))
        # packed codes [(K//G)*wpg, N] and group scales [K//G, N] keep the
        # parent matrix's (K-derived, N) dim order, so the parent's rule
        # applies verbatim at this rank; _trim_spec drops the K-side axis
        # when the group count does not divide the shard count
        return param_spec(path[: -len(quant_field)], shape, cfg, plan)
    names = _NAME_RE.findall(path)
    leaf = names[-1] if names else ""
    if nd == 1:
        return P(None)
    spec: list = [None] * nd
    if any(e in leaf for e in _EMBED) or (not names and nd == 2):
        spec[0] = plan.tp_axis
    elif leaf in _ROW_PARALLEL:
        spec[-2] = plan.tp_axis
        if plan.fsdp:
            spec[-1] = plan.dp
    else:
        spec[-1] = plan.tp_axis
        if plan.fsdp:
            spec[-2] = plan.dp
    return P(*spec)


def _axis_size(mesh: Mesh, entry: AxisName) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([sizes.get(a, 1) for a in entry]))
    return sizes.get(entry, 1)


def _trim_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Fit a spec to a concrete shape: pad/truncate the rank and drop any
    axis that the mesh lacks or that does not divide the dim."""
    names = set(mesh.axis_names)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    out = []
    for dim, entry in zip(shape, entries):
        if isinstance(entry, (tuple, list)):
            entry = tuple(a for a in entry if a in names)
            entry = entry if entry else None
            if len(entry or ()) == 1:
                entry = entry[0]
        elif entry is not None and entry not in names:
            entry = None
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out) if out else P()


# ---------------------------------------------------------------------------
# tree-level sharding builders
# ---------------------------------------------------------------------------

def _shape_of(leaf) -> Tuple[int, ...]:
    return tuple(getattr(leaf, "shape", ()))


def param_shardings(mesh: Mesh, tree, cfg: ModelConfig, plan: Plan):
    """NamedSharding tree for a parameter (or optimizer-moment) pytree."""
    def one(path, leaf):
        shape = _shape_of(leaf)
        spec = param_spec(jax.tree_util.keystr(path), shape, cfg, plan)
        return NamedSharding(mesh, _trim_spec(spec, shape, mesh))
    return jax.tree_util.tree_map_with_path(one, tree)


def data_shardings(mesh: Mesh, tree, plan: Plan):
    """Batch-dim (dim 0) sharding over the data axes for input pytrees."""
    def one(leaf):
        shape = _shape_of(leaf)
        spec = _trim_spec(P(plan.dp), shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(one, tree)


# ---------------------------------------------------------------------------
# tensor-parallel trace context (serving decode under shard_map)
# ---------------------------------------------------------------------------
#
# Model code stays mesh-agnostic: row-parallel matmuls (wo / w_down) pass
# their partial sums through ``tp_all_reduce`` and activation quantization
# passes its per-token absmax through ``tp_axis_max``.  Outside a TP trace
# both are identity.  ``serving/distributed.py`` enters ``tp_context``
# around the shard_map body at trace time, which lowers them to
# collectives over the model axis.

_TP_STATE: list = []


@contextlib.contextmanager
def tp_context(axis: str = "model", wire_bits: int = 32):
    """Activate TP collectives for code traced inside this block."""
    _TP_STATE.append((axis, int(wire_bits)))
    try:
        yield
    finally:
        _TP_STATE.pop()


def tp_active() -> bool:
    return bool(_TP_STATE)


def tp_all_reduce(x: jax.Array) -> jax.Array:
    """Sum row-parallel partial results over the model axis (identity
    outside a TP trace).  ``wire_bits=8`` sends int8+scale compressed
    partials — ``dist/compress.py`` generalized from gradients to
    activations, error feedback off because inference has no next
    iteration to carry a residual into."""
    if not _TP_STATE:
        return x
    axis, wire = _TP_STATE[-1]
    if wire == 8:
        from repro.dist.compress import _quantize_dequantize

        x = _quantize_dequantize(x)
    return jax.lax.psum(x, axis)


def tp_axis_max(x: jax.Array) -> jax.Array:
    """Max over the model axis, so per-token activation-quantization
    scales on row-parallel inputs (each shard sees only its K-slice)
    match the unsharded computation bit-for-bit.  Identity outside a TP
    trace; a numeric no-op on replicated (column-parallel) inputs."""
    if not _TP_STATE:
        return x
    axis, _ = _TP_STATE[-1]
    return jax.lax.pmax(x, axis)


def cache_shardings(mesh: Mesh, tree, plan: Plan):
    """Decode-cache sharding: batch lives at dim 1 of the stacked
    per-layer arrays ([L, B, ...]) and at dim 0 of the ``length``
    vector; everything else replicated."""
    def one(leaf):
        shape = _shape_of(leaf)
        if len(shape) == 1:
            spec = P(plan.dp)
        elif len(shape) >= 2:
            spec = P(None, plan.dp)
        else:
            spec = P()
        return NamedSharding(mesh, _trim_spec(spec, shape, mesh))
    return jax.tree_util.tree_map(one, tree)
