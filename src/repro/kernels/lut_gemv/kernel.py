"""Pallas TPU kernel: LUT-dequant quantized matmul (SAIL LUT-GEMV on TPU).

TPU adaptation of the paper's C-SRAM LUT-GEMV (see DESIGN.md Sec. 2):

  * packed b-bit weight codes stream HBM -> VMEM tile by tile (the DRAM ->
    LLC ping-pong of Fig. 4 is Pallas' grid pipelining, which
    double-buffers the next weight block against current compute);
  * the 2**bits-entry dequant LUT (codebook) is VMEM-resident for the whole
    kernel — built once, reused across every tile, batch row, and K-group,
    which is the paper's central data-reuse property;
  * unpack + LUT gather + group-scale happen entirely in VMEM, feeding the
    MXU with an f32 tile — multiplications never touch the unquantized
    weight in HBM, so HBM bytes drop by ~(16/bits)x exactly as C-SRAM
    computing removes the LLC-external weight traffic.

Grid: (M/bm, N/bn, K/bk) with K innermost (accumulation).  The packed
operand is group-aligned (``pack_grouped``) so each K-block maps to an
integer number of packed rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import values_per_word, words_per_group


def _lut_matmul_kernel(x_ref, packed_ref, scales_ref, codebook_ref, o_ref,
                       acc_ref, *, bits: int, group_size: int, bk: int,
                       n_k: int, out_dtype):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vpw = values_per_word(bits)
    wpg = words_per_group(bits, group_size)
    groups = bk // group_size
    bn = packed_ref.shape[-1]

    # ---- unpack b-bit codes from the packed uint32 block ----------------
    words = packed_ref[...].reshape(groups, wpg, bn)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, None, :, None]
    mask = jnp.uint32((1 << bits) - 1)
    codes = (words[:, :, None, :] >> shifts) & mask      # [g, wpg, vpw, bn]
    codes = codes.reshape(groups, wpg * vpw, bn)[:, :group_size, :]

    # ---- LUT dequant: gather VMEM-resident codebook, apply group scale --
    lut = codebook_ref[...]                               # [2**bits]
    w = jnp.take(lut, codes.astype(jnp.int32), axis=0)    # [g, G, bn]
    w = w * scales_ref[...][:, None, :]                   # group-wise scale
    w = w.reshape(bk, bn)

    # ---- MXU matmul, f32 accumulation -----------------------------------
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "k", "bm", "bn", "bk", "out_dtype", "interpret"))
def lut_matmul_pallas(x, packed, scales, codebook, *, bits: int,
                      group_size: int, k: int, bm: int = 8, bn: int = 256,
                      bk: int = 512, out_dtype=jnp.float32,
                      interpret: bool = True):
    """y[M, N] = x[M, K] @ dequant(packed, scales, codebook).

    All of M % bm, N % bn, K % bk, bk % group_size must be 0 (ops.py pads).
    """
    m, kx = x.shape
    assert kx == k, (kx, k)
    n = packed.shape[-1]
    wpg = words_per_group(bits, group_size)
    pk_rows = (bk // group_size) * wpg
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(
        _lut_matmul_kernel, bits=bits, group_size=group_size, bk=bk,
        n_k=n_k, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((pk_rows, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1 << bits,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales, codebook)
