"""Pallas TPU kernel: LUT-dequant quantized matmul (SAIL LUT-GEMV on TPU).

TPU adaptation of the paper's C-SRAM LUT-GEMV (see DESIGN.md Sec. 2):

  * packed b-bit weight codes stream HBM -> VMEM tile by tile (the DRAM ->
    LLC ping-pong of Fig. 4 is Pallas' grid pipelining, which
    double-buffers the next weight block against current compute);
  * the 2**bits-entry dequant LUT (codebook) is VMEM-resident for the whole
    kernel — built once, reused across every tile, batch row, and K-group,
    which is the paper's central data-reuse property;
  * unpack + LUT gather + group-scale happen entirely in VMEM, feeding the
    MXU with an f32 tile — multiplications never touch the unquantized
    weight in HBM, so HBM bytes drop by ~(16/bits)x exactly as C-SRAM
    computing removes the LLC-external weight traffic.

Two activation flavours, matching the ``lutmm`` instruction's dual
precision fields (``ql`` for weights, abits for activations):

  * ``_lut_matmul_kernel``      — f32 activations (abits None);
  * ``_lut_matmul_int_kernel``  — int activation codes + per-token scales
    from ``quantize_activations``.  The codes are converted in-kernel with
    the paper's Algorithm-1 bitline typeconv (``int_to_f32_compute``) and
    the per-token scale is folded in at the accumulator store, so the
    executed datapath consumes exactly the ``abits`` integers the
    allocator priced — no fake-quant in the serve path.

Grid: (M/bm, N/bn, K/bk) with K innermost (accumulation).  The packed
operand is group-aligned (``pack_grouped``: ``ceil(bits*G/32)`` words per
group, bit-contiguous) so each K-block maps to an integer number of
packed rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import words_per_group
from repro.kernels.typeconv.kernel import int_to_f32_compute


def _unpack_codes(words, *, bits: int, group_size: int, groups: int, bn: int):
    """Decode the bit-contiguous packed block -> int32 codes [g, G, bn].

    Mirrors ``quant.unpack_grouped``: each group is a little-endian
    bitstream over ``wpg = ceil(bits*G/32)`` uint32 words; code ``v``
    occupies stream bits ``[v*bits, (v+1)*bits)``.  Pure shift/and/sum —
    no gathers — so it lowers on the TPU vector unit.
    """
    wpg = words_per_group(bits, group_size)
    words = words.reshape(groups, wpg, bn)
    wshifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :, None]
    stream = (words[:, :, None, :] >> wshifts) & jnp.uint32(1)
    stream = stream.reshape(groups, wpg * 32, bn)[:, :group_size * bits, :]
    stream = stream.reshape(groups, group_size, bits, bn)
    bshifts = jnp.arange(bits, dtype=jnp.uint32)[None, None, :, None]
    codes = jnp.sum(stream << bshifts, axis=2, dtype=jnp.uint32)
    return codes.astype(jnp.int32)


def _dequant_block(packed_ref, scales_ref, codebook_ref, *, bits: int,
                   group_size: int, bk: int):
    """LUT dequant of one packed (K-block, bn) tile -> f32 [bk, bn]."""
    bn = packed_ref.shape[-1]
    groups = bk // group_size
    codes = _unpack_codes(packed_ref[...], bits=bits, group_size=group_size,
                          groups=groups, bn=bn)
    lut = codebook_ref[...]                               # [2**bits]
    w = jnp.take(lut, codes, axis=0)                      # [g, G, bn]
    w = w * scales_ref[...][:, None, :]                   # group-wise scale
    return w.reshape(bk, bn)


def _lut_matmul_kernel(x_ref, packed_ref, scales_ref, codebook_ref, o_ref,
                       acc_ref, *, bits: int, group_size: int, bk: int,
                       n_k: int, out_dtype):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_block(packed_ref, scales_ref, codebook_ref, bits=bits,
                       group_size=group_size, bk=bk)

    # ---- MXU matmul, f32 accumulation -----------------------------------
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _lut_matmul_int_kernel(x_ref, xs_ref, packed_ref, scales_ref,
                           codebook_ref, o_ref, acc_ref, *, bits: int,
                           group_size: int, bk: int, n_k: int, abits: int,
                           out_dtype):
    """Int-activation tile: x_ref carries ``abits``-bit signed codes.

    The codes are widened to f32 with Algorithm-1 typeconv (exact for
    abits-bit ints) and the per-token scale ``xs`` is applied once at the
    final store, so y == (x_q @ dequant(W)) * xs bit-for-bit with the ref.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_block(packed_ref, scales_ref, codebook_ref, bits=bits,
                       group_size=group_size, bk=bk)

    xf = int_to_f32_compute(x_ref[...], n=abits)          # exact int -> f32
    acc_ref[...] += jnp.dot(xf, w, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * xs_ref[...]).astype(out_dtype)


def _common_specs(bits, group_size, bk, bm, bn):
    wpg = words_per_group(bits, group_size)
    pk_rows = (bk // group_size) * wpg
    return [
        pl.BlockSpec((pk_rows, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1 << bits,), lambda i, j, kk: (0,)),
    ]


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "k", "bm", "bn", "bk", "out_dtype", "interpret"))
def lut_matmul_pallas(x, packed, scales, codebook, *, bits: int,
                      group_size: int, k: int, bm: int = 8, bn: int = 256,
                      bk: int = 512, out_dtype=jnp.float32,
                      interpret: bool = False):
    """y[M, N] = x[M, K] @ dequant(packed, scales, codebook).

    All of M % bm, N % bn, K % bk, bk % group_size must be 0 (ops.py pads).
    Backend selection (compiled vs interpret) lives in ops.py: pass
    ``interpret=True`` only off-TPU — a real TPU run must never silently
    execute the interpreter.
    """
    m, kx = x.shape
    assert kx == k, (kx, k)
    n = packed.shape[-1]
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(
        _lut_matmul_kernel, bits=bits, group_size=group_size, bk=bk,
        n_k=n_k, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
        + _common_specs(bits, group_size, bk, bm, bn),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales, codebook)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "k", "abits", "bm", "bn", "bk", "out_dtype",
    "interpret"))
def lut_matmul_int_pallas(x_q, x_scale, packed, scales, codebook, *,
                          bits: int, group_size: int, k: int, abits: int,
                          bm: int = 8, bn: int = 256, bk: int = 512,
                          out_dtype=jnp.float32, interpret: bool = False):
    """y[M, N] = (x_q[M, K] @ dequant(...)) * x_scale[M, 1].

    x_q: int32 ``abits``-bit signed activation codes; x_scale: per-token
    f32 scales, both from ``quant.quantize_activations``.
    """
    m, kx = x_q.shape
    assert kx == k, (kx, k)
    n = packed.shape[-1]
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(
        _lut_matmul_int_kernel, bits=bits, group_size=group_size, bk=bk,
        n_k=n_k, abits=abits, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0))]
        + _common_specs(bits, group_size, bk, bm, bn),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_q, x_scale, packed, scales, codebook)
