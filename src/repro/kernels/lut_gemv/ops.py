"""Jitted public wrapper for the LUT-dequant matmul kernel.

Handles padding to block multiples, block-size selection (VMEM budgeting),
and the jnp fallback used on non-TPU backends / inside the 512-device
dry-run (same semantics as the kernel; the kernel itself is validated
against ``ref.lut_matmul_ref`` in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, words_per_group
from repro.kernels.lut_gemv.kernel import lut_matmul_pallas
from repro.kernels.lut_gemv.ref import lut_matmul_ref

VMEM_BUDGET = 64 * 2**20  # bytes; leave headroom below the 128MB v5e VMEM+


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_blocks(m: int, n: int, k: int, bits: int, group_size: int):
    """Choose (bm, bn, bk) hardware-aligned and within the VMEM budget.

    MXU wants multiples of (8, 128); the K block must cover whole quant
    groups.  Working set per grid step ~ x(bm,bk)4 + packed + scales +
    w_dequant(bk,bn)4 + acc(bm,bn)4, double-buffered (x2).
    """
    bm = min(_round_up(m, 8), 128)
    bn = min(_round_up(n, 128), 512)
    bk = min(_round_up(k, group_size), 2048)
    wpg = words_per_group(bits, group_size)

    def vmem(bm, bn, bk):
        x = bm * bk * 4
        pk = (bk // group_size) * wpg * bn * 4
        sc = (bk // group_size) * bn * 4
        w = bk * bn * 4
        acc = bm * bn * 4
        return 2 * (x + pk + sc) + w + acc

    while vmem(bm, bn, bk) > VMEM_BUDGET and bk > group_size:
        bk //= 2
        bk = _round_up(bk, group_size)
    while vmem(bm, bn, bk) > VMEM_BUDGET and bn > 128:
        bn //= 2
    return bm, bn, bk


def lut_matmul(x: jax.Array, qt: QTensor, out_dtype=jnp.float32,
               backend: str = "pallas", interpret: bool = True) -> jax.Array:
    """y[M, N] = x[M, K] @ dequant(qt), the SAIL serving matmul.

    backend: "pallas" (TPU kernel; interpret=True executes the kernel body
    on CPU for validation) or "jnp" (pure-jnp same-semantics fallback).
    """
    if backend == "jnp":
        return lut_matmul_ref(x, qt, out_dtype)
    m, k = x.shape
    n = qt.n
    bm, bn, bk = pick_blocks(m, n, k, qt.bits, qt.group_size)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    xx = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    packed, scales = qt.packed, qt.scales
    if kp != k:
        wpg = words_per_group(qt.bits, qt.group_size)
        extra_g = (kp - k) // qt.group_size
        packed = jnp.pad(packed, ((0, extra_g * wpg), (0, 0)))
        scales = jnp.pad(scales, ((0, extra_g), (0, 0)))
    if np_ != n:
        packed = jnp.pad(packed, ((0, 0), (0, np_ - n)))
        scales = jnp.pad(scales, ((0, 0), (0, np_ - n)),
                         constant_values=1.0)

    y = lut_matmul_pallas(xx, packed, scales, qt.codebook, bits=qt.bits,
                          group_size=qt.group_size, k=kp, bm=bm, bn=bn,
                          bk=bk, out_dtype=out_dtype, interpret=interpret)
    return y[:m, :n]
