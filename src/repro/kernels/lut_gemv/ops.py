"""Jitted public wrapper for the LUT-dequant matmul kernel.

Handles padding to block multiples, block-size selection (VMEM budgeting),
backend selection (compiled Pallas on TPU, interpret mode off-TPU), the
int-activation dispatch (real low-bit serve path whenever the QTensor
carries ``abits``), and the jnp fallback used inside the 512-device
dry-run (same semantics as the kernel; the kernel itself is validated
against ``ref.lut_matmul_ref`` / ``ref.lut_matmul_ref_int``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize_activations, words_per_group
from repro.kernels.lut_gemv.kernel import (lut_matmul_int_pallas,
                                           lut_matmul_pallas)
from repro.kernels.lut_gemv.ref import lut_matmul_ref, lut_matmul_ref_int

VMEM_BUDGET = 64 * 2**20  # bytes; leave headroom below the 128MB v5e VMEM+


def default_interpret() -> bool:
    """Interpret the kernel only when no TPU is attached.

    Backend selection lives here — not in the kernel defaults — so a real
    TPU run never silently executes the Pallas interpreter.
    """
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_blocks(m: int, n: int, k: int, bits: int, group_size: int):
    """Choose (bm, bn, bk) hardware-aligned and within the VMEM budget.

    MXU wants multiples of (8, 128); the K block must cover whole quant
    groups.  Working set per grid step ~ x(bm,bk)4 + packed + scales +
    w_dequant(bk,bn)4 + acc(bm,bn)4, double-buffered (x2).
    """
    bm = min(_round_up(m, 8), 128)
    bn = min(_round_up(n, 128), 512)
    bk = min(_round_up(k, group_size), 2048)
    wpg = words_per_group(bits, group_size)

    def vmem(bm, bn, bk):
        x = bm * bk * 4
        pk = (bk // group_size) * wpg * bn * 4
        sc = (bk // group_size) * bn * 4
        w = bk * bn * 4
        acc = bm * bn * 4
        return 2 * (x + pk + sc) + w + acc

    while vmem(bm, bn, bk) > VMEM_BUDGET and bk > group_size:
        bk //= 2
        bk = _round_up(bk, group_size)
    while vmem(bm, bn, bk) > VMEM_BUDGET and bn > 128:
        bn //= 2
    return bm, bn, bk


def _pad_weight(qt: QTensor, kp: int, np_: int):
    """Pad packed/scales to the padded (kp, np_) problem."""
    packed, scales = qt.packed, qt.scales
    if kp != qt.k:
        wpg = words_per_group(qt.bits, qt.group_size)
        extra_g = (kp - qt.k) // qt.group_size
        packed = jnp.pad(packed, ((0, extra_g * wpg), (0, 0)))
        scales = jnp.pad(scales, ((0, extra_g), (0, 0)))
    if np_ != qt.n:
        packed = jnp.pad(packed, ((0, 0), (0, np_ - qt.n)))
        scales = jnp.pad(scales, ((0, 0), (0, np_ - qt.n)),
                         constant_values=1.0)
    return packed, scales


def lut_matmul(x: jax.Array, qt: QTensor, out_dtype=jnp.float32,
               backend: str = "pallas",
               interpret: Optional[bool] = None) -> jax.Array:
    """y[M, N] = x[M, K] @ dequant(qt), the SAIL serving matmul.

    backend: "pallas" (TPU kernel; compiled on TPU, interpret mode
    elsewhere when ``interpret`` is None) or "jnp" (pure-jnp
    same-semantics fallback).

    When ``qt.abits`` is set and ``x`` is floating, activations are
    quantized per token (``quantize_activations``) and the integer
    LUT-GEMV path runs — the executed datapath matches the ``abits``
    semantics the allocator priced, with fake-quant surviving only as the
    calibration probe.
    """
    if qt.abits is not None and jnp.issubdtype(x.dtype, jnp.floating):
        x_q, x_scale = quantize_activations(x, qt.abits)
        return lut_matmul_quantized(x_q, x_scale, qt, out_dtype=out_dtype,
                                    backend=backend, interpret=interpret)
    if backend == "jnp":
        return lut_matmul_ref(x, qt, out_dtype)
    if interpret is None:
        interpret = default_interpret()
    m, k = x.shape
    n = qt.n
    bm, bn, bk = pick_blocks(m, n, k, qt.bits, qt.group_size)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    xx = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    packed, scales = _pad_weight(qt, kp, np_)

    y = lut_matmul_pallas(xx, packed, scales, qt.codebook, bits=qt.bits,
                          group_size=qt.group_size, k=kp, bm=bm, bn=bn,
                          bk=bk, out_dtype=out_dtype, interpret=interpret)
    return y[:m, :n]


def lut_matmul_quantized(x_q: jax.Array, x_scale: jax.Array, qt: QTensor,
                         out_dtype=jnp.float32, backend: str = "pallas",
                         interpret: Optional[bool] = None) -> jax.Array:
    """y[M, N] = (x_q @ dequant(qt)) * x_scale — the int-activation path.

    x_q int32 ``abits``-bit codes, x_scale f32 [M, 1], both straight from
    ``quant.quantize_activations``.  Padding uses zero codes (contribute
    exactly 0 to the dot) so padded and unpadded results agree bit-for-bit
    on the valid slice.
    """
    abits = qt.abits if qt.abits is not None else 8
    if backend == "jnp":
        return lut_matmul_ref_int(x_q, x_scale, qt, out_dtype)
    if interpret is None:
        interpret = default_interpret()
    m, k = x_q.shape
    n = qt.n
    bm, bn, bk = pick_blocks(m, n, k, qt.bits, qt.group_size)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    if (mp, kp) != (m, k):
        x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
        x_scale = jnp.pad(x_scale, ((0, mp - m), (0, 0)),
                          constant_values=1.0)
    packed, scales = _pad_weight(qt, kp, np_)

    y = lut_matmul_int_pallas(x_q, x_scale, packed, scales, qt.codebook,
                              bits=qt.bits, group_size=qt.group_size, k=kp,
                              abits=abits, bm=bm, bn=bn, bk=bk,
                              out_dtype=out_dtype, interpret=interpret)
    return y[:m, :n]
