"""Pure-jnp oracle for the LUT-dequant quantized matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, dequantize


def lut_matmul_ref(x: jax.Array, qt: QTensor,
                   out_dtype=jnp.float32) -> jax.Array:
    """y[M, N] = x[M, K] @ dequant(qt)[K, N] in f32 accumulation."""
    w = dequantize(qt)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)
