"""Pure-jnp oracle for the LUT-dequant quantized matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, dequantize


def lut_matmul_ref(x: jax.Array, qt: QTensor,
                   out_dtype=jnp.float32) -> jax.Array:
    """y[M, N] = x[M, K] @ dequant(qt)[K, N] in f32 accumulation."""
    w = dequantize(qt)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def lut_matmul_ref_int(x_q: jax.Array, x_scale: jax.Array, qt: QTensor,
                       out_dtype=jnp.float32) -> jax.Array:
    """Int-activation oracle: y = (x_q @ dequant(qt)) * x_scale.

    x_q int32 codes and x_scale f32 [M, 1] per-token scales from
    ``quant.quantize_activations``.  The scale is applied *after* the
    integer-code matmul — the serve-path semantics the kernel realizes —
    not folded into x beforehand (mathematically equal, not bitwise).
    """
    w = dequantize(qt)
    y = jnp.dot(x_q.astype(jnp.float32), w,
                preferred_element_type=jnp.float32)
    return (y * x_scale).astype(out_dtype)
