"""Pallas TPU kernel: in-memory parallel int->f32 (SAIL Algorithm 1).

Every VPU lane executes the paper's bitline algorithm in lockstep — the
direct analogue of 512 bitlines converting in parallel: cumulative-OR
leading-one detection, 5-bit ripple popcount for the exponent, bit-reversed
multiply for mantissa alignment, then sign/exponent/mantissa OR-assembled
and bitcast to float32.  No arithmetic float conversion instruction is used
inside the kernel body (only shifts / and / or / xor / integer mul), so the
kernel is faithful to what the C-SRAM performs.

Used fused at the tail of the serving path to keep dequantization off the
"CPU" (scalar) path — the paper's motivation for Algorithm 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def int_to_f32_compute(a: jax.Array, n: int) -> jax.Array:
    """Algorithm-1 body on an int32 array of n-bit signed values.

    Pure bit-ops (shift/and/or/xor/int-mul) + one bitcast — usable both as
    the typeconv kernel body and fused inside other Pallas kernels (the
    LUT-GEMV int-activation path converts its activation block with this,
    mirroring the paper's PIM typeconv feeding the GEMV datapath).
    """
    a = a.astype(jnp.int32)
    sign = (a >> 31) & 1
    mag = jnp.where(sign == 1, -a, a).astype(jnp.uint32)
    nm1 = n - 1

    # lines 2-4: cumulative-OR leading-one mask
    d = jnp.zeros_like(mag)
    c = jnp.zeros_like(mag)
    for i in range(nm1 - 1, -1, -1):
        ai = (mag >> i) & 1
        d = d | ai
        c = c | (d << i)

    # lines 5-11: 5-bit ripple popcount of C -> biased exponent
    s = [jnp.zeros_like(mag) for _ in range(5)]
    for i in range(nm1):
        carry = (c >> i) & 1
        for j in range(5):
            c1 = s[j] & carry
            s[j] = s[j] ^ carry
            carry = c1
    popc = s[0] | (s[1] << 1) | (s[2] << 2) | (s[3] << 3) | (s[4] << 4)
    biased = popc + jnp.uint32(126)

    # lines 16-17: n-bit reverse of C+1 = 2^k, k = leading zeros; align
    cp1 = c + 1
    rev = jnp.zeros_like(mag)
    for i in range(n):
        rev = rev | (((cp1 >> i) & 1) << (n - 1 - i))
    aligned = (mag * rev) & jnp.uint32((1 << nm1) - 1)

    r = (sign.astype(jnp.uint32) << 31) | (biased << 23)
    if nm1 >= 2:
        mant = aligned & jnp.uint32((1 << (nm1 - 1)) - 1)
        r = r | (mant << (23 - (nm1 - 1)))
    r = jnp.where(mag == 0, jnp.uint32(0), r)
    return jax.lax.bitcast_convert_type(r, jnp.float32)


def _typeconv_kernel(a_ref, o_ref, *, n: int):
    o_ref[...] = int_to_f32_compute(a_ref[...], n)


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def int_to_f32_pallas(a: jax.Array, n: int = 25, block: int = 512,
                      interpret: bool = True) -> jax.Array:
    """Vectorized Algorithm 1 over a 2D array [R, C] (R % 8 == 0 padded by
    ops.py; C % 128 == 0)."""
    r, c = a.shape
    grid = (r // 8, c // block) if c % block == 0 else (r // 8, 1)
    bc = block if c % block == 0 else c
    return pl.pallas_call(
        functools.partial(_typeconv_kernel, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((8, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )(a)
