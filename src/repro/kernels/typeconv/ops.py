"""Public wrapper for the Algorithm-1 conversion kernel (padding + fallback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.typeconv import int_to_f32 as _core_int_to_f32
from repro.kernels.typeconv.kernel import int_to_f32_pallas


def int_to_f32(a: jax.Array, n: int = 25, backend: str = "pallas",
               interpret: bool = True) -> jax.Array:
    """Convert int array (|a| < 2**(n-1), n <= 25) to f32, Algorithm 1.

    backend "jnp" uses the pure-JAX line-by-line implementation from
    repro.core.typeconv; "pallas" runs the TPU kernel (interpret on CPU).
    """
    if backend == "jnp":
        return _core_int_to_f32(a.reshape(-1), n).reshape(a.shape)
    shape = a.shape
    flat = a.reshape(-1)
    c = 128
    rows = -(-flat.size // c)
    rows_p = -(-rows // 8) * 8
    pad = rows_p * c - flat.size
    a2 = jnp.pad(flat, (0, pad)).reshape(rows_p, c)
    out = int_to_f32_pallas(a2, n=n, block=c, interpret=interpret)
    return out.reshape(-1)[:flat.size].reshape(shape)
