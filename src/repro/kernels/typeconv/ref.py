"""Oracle for the Algorithm-1 type conversion kernel."""
import jax
import jax.numpy as jnp


def int_to_f32_ref(a: jax.Array) -> jax.Array:
    """Native conversion — the ground truth Algorithm 1 must match."""
    return a.astype(jnp.float32)
