"""Pure-jnp oracle for the flash-decode attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array,
                         k_scale: Optional[jax.Array] = None,
                         v_scale: Optional[jax.Array] = None,
                         window: Optional[int] = None) -> jax.Array:
    """Single-token decode attention with a (possibly int8-quantized) KV
    cache.

    q        : [B, H, D]        query for the new token
    k, v     : [B, S, KV, D]    cache (f32/bf16, or int8 when scales given)
    lengths  : [B] int32        valid cache length per sequence
    k_scale  : [B, S, KV, 1]    dequant scales for int8 KV (optional)
    window   : sliding-window size (tokens attend to the last `window`
               positions only) — h2o-danube / mixtral SWA.
    Returns [B, H, D].
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    gsize = h // kv
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale
    if v_scale is not None:
        v = v.astype(jnp.float32) * v_scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    qg = q.reshape(b, kv, gsize, d).astype(jnp.float32)
    scores = jnp.einsum("bgid,bsgd->bgis", qg, k) / jnp.sqrt(d)
    pos = jnp.arange(s)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos >= (lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgis,bsgd->bgid", p, v)
    return out.reshape(b, h, d)
