"""Public wrapper for flash-decode attention (padding + jnp fallback)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.kernels.decode_attn.ref import decode_attention_ref


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     window: Optional[int] = None,
                     backend: str = "pallas", bs: int = 256,
                     interpret: bool = True) -> jax.Array:
    """Decode-step attention over a (possibly int8) KV cache.  See ref.py."""
    if backend == "jnp":
        return decode_attention_ref(q, k, v, lengths, k_scale, v_scale,
                                    window)
    b, h, d = q.shape
    s = k.shape[1]
    bs = min(bs, s)
    if s % bs:
        pad = bs - s % bs
        padkv = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k, v = padkv(k), padkv(v)
        if k_scale is not None:
            k_scale, v_scale = padkv(k_scale), padkv(v_scale)
    quantized = k_scale is not None
    if quantized:
        k = k.astype(jnp.int8) if k.dtype != jnp.int8 else k
        v = v.astype(jnp.int8) if v.dtype != jnp.int8 else v
    return decode_attention_pallas(
        q, k, v, lengths.astype(jnp.int32), k_scale, v_scale, bs=bs,
        window=window, quantized=quantized, interpret=interpret)
