"""Pallas TPU kernel: flash-decode attention over a quantized KV cache.

The decode-phase hot loop the paper targets (Sec. III-B: the KV path maps
column-wise across C-SRAM arrays so the Q x K_cache^T product streams
without rebuilding LUTs).  On TPU the analogous structure is a
flash-decoding kernel:

  * grid walks (batch, kv-head, S blocks); KV blocks stream HBM->VMEM and
    are consumed once (memory-bound, like the weight stream);
  * int8 KV dequant (per-position scale) happens in VMEM right before the
    MXU dot — KV HBM traffic drops 2x/4x vs bf16/f32, the same
    bytes-are-the-bottleneck reasoning as LUT-GEMV;
  * online softmax (running max / sum) keeps a single pass over the cache;
  * GQA: the H/KV query heads of one kv group ride in the same block.

Scratch: running (m, l, acc) in VMEM across the S-block grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, bs: int, n_s: int,
                        quantized: bool, window, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # [G, D] queries of group
    k = k_ref[0, 0].astype(jnp.float32)            # [bs, D]
    v = v_ref[0, 0].astype(jnp.float32)            # [bs, D]
    if quantized:
        k = k * ks_ref[0, 0]                       # [bs, 1] scales
        v = v * vs_ref[0, 0]

    length = len_ref[0]
    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < length
    if window is not None:
        valid &= pos >= (length - window)

    scores = jax.lax.dot_general(
        q.astype(jnp.float32), k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [G, bs]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                                  # [G, 1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                          # [G, bs]
    p = jnp.where(valid, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "window", "quantized",
                                             "interpret"))
def decode_attention_pallas(q, k, v, lengths, k_scale=None, v_scale=None,
                            *, bs: int = 256, window=None,
                            quantized: bool = False, interpret: bool = True):
    """q [B,H,D], k/v [B,S,KV,D] (+scales [B,S,KV,1]), lengths [B] -> [B,H,D].

    S must be a multiple of bs (ops.py pads); D, G should be TPU-aligned.
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    n_s = s // bs
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, kv, g, d)
    # layout KV as [B, KV, S, D] so the S-block stream is contiguous
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if quantized:
        kst = jnp.swapaxes(k_scale, 1, 2).reshape(b, kv, s, 1)
        vst = jnp.swapaxes(v_scale, 1, 2).reshape(b, kv, s, 1)
    else:  # dummies (same layout, zero-size blocks are not allowed)
        kst = jnp.zeros((b, kv, s, 1), jnp.float32)
        vst = jnp.zeros((b, kv, s, 1), jnp.float32)

    kernel = functools.partial(
        _decode_attn_kernel, bs=bs, n_s=n_s, quantized=quantized,
        window=window, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, bs, 1), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, bs, 1), lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, kt, vt, kst, vst)
    return out.reshape(b, h, d)
