"""Faithful batched LUT-based GEMV (SAIL Sec. II-C / III).

This is the paper's algorithm implemented with *exact integer semantics* in
pure JAX: lookup tables of weight subset-sums are built per NBW-sized group
of the reduction dimension, and activation bits are processed LSB->MSB,
each bit-plane's NBW-bit pattern indexing the LUT, with shift-and-add
accumulation (Fig. 2 of the paper).

The result is bit-exact equal to the integer matmul ``x_q @ w_q`` — this is
the oracle property the tests assert.  The TPU production kernel
(``repro.kernels.lut_gemv``) implements the hardware-adapted variant; this
module is the algorithmic reference and the workload generator for the SAIL
cost model.

Conventions (following Fig. 2):
  * A group holds ``nbw`` consecutive reduction-dim elements.
  * LUT has ``2**nbw`` entries; bit ``j`` (LSB=j=0) of the entry index
    selects weight ``nbw-1-j`` of the group, i.e. pattern ``0b001`` selects
    the *last* weight of the group (W2 in the paper's [W0, W1, W2] example).
  * Activations may be signed (two's complement): the MSB plane carries
    weight ``-2**(abits-1)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def build_luts(w_q: jax.Array, nbw: int) -> jax.Array:
    """Build weight subset-sum LUTs.

    w_q : int32 [K, N] quantized weights (signed codes).
    Returns LUTs int32 [K // nbw, 2**nbw, N] where
      lut[g, p, n] = sum_{j : bit_j(p) = 1} w_q[g * nbw + (nbw - 1 - j), n].
    """
    k, n = w_q.shape
    if k % nbw != 0:  # zero-pad: zero weights contribute nothing to sums
        pad = nbw - k % nbw
        w_q = jnp.concatenate([w_q, jnp.zeros((pad, n), w_q.dtype)], axis=0)
        k += pad
    groups = w_q.reshape(k // nbw, nbw, n)
    patterns = jnp.arange(1 << nbw, dtype=jnp.int32)
    # sel[p, i] = bit (nbw-1-i) of p  -> weight i of the group
    sel = (patterns[:, None] >> (nbw - 1 - jnp.arange(nbw))) & 1  # [2^nbw, nbw]
    # lut[g, p, n] = sum_i sel[p, i] * groups[g, i, n]
    return jnp.einsum("pi,gin->gpn", sel, groups,
                      preferred_element_type=jnp.int32)


def activation_patterns(x_q: jax.Array, nbw: int, abits: int) -> jax.Array:
    """Decompose activations into per-bit-plane LUT indices.

    x_q : int32 [B, K] (signed, two's complement within ``abits``).
    Returns patterns int32 [B, abits, K // nbw]: the NBW-bit index the DFM
    broadcasts for (batch b, bit-plane t, group g).
    """
    b, k = x_q.shape
    if k % nbw != 0:  # pad with zeros (pattern bits 0 -> LUT entry 0 term)
        pad = nbw - k % nbw
        x_q = jnp.concatenate([x_q, jnp.zeros((b, pad), x_q.dtype)], axis=1)
        k += pad
    ux = x_q.astype(jnp.uint32) & jnp.uint32((1 << abits) - 1)
    bits = (ux[:, None, :] >> jnp.arange(abits, dtype=jnp.uint32)[None, :, None]) & 1
    bits = bits.astype(jnp.int32)                                # [B, abits, K]
    bits = bits.reshape(b, abits, k // nbw, nbw)
    weights = (1 << (nbw - 1 - jnp.arange(nbw))).astype(jnp.int32)
    return jnp.einsum("btgi,i->btg", bits, weights)              # [B, abits, K/nbw]


@partial(jax.jit, static_argnames=("nbw", "abits", "signed"))
def lut_gemv(x_q: jax.Array, w_q: jax.Array, nbw: int, abits: int = 8,
             signed: bool = True) -> jax.Array:
    """Batched LUT-GEMV: exact int32 ``x_q @ w_q`` via LUT + shift-add.

    x_q : int32 [B, K] activations, |x| < 2**(abits-1) if signed.
    w_q : int32 [K, N] weights.
    Returns int32 [B, N].
    """
    luts = build_luts(w_q, nbw)                       # [G, 2^nbw, N]
    pats = activation_patterns(x_q, nbw, abits)       # [B, abits, G]
    g_idx = jnp.arange(luts.shape[0])
    # gather LUT entries: out[b, t, g, n] = luts[g, pats[b,t,g], n]
    fetched = luts[g_idx[None, None, :], pats]        # [B, abits, G, N]
    planes = fetched.sum(axis=2)                      # [B, abits, N]
    shifts = (1 << jnp.arange(abits, dtype=jnp.int32))
    if signed:
        # two's complement: MSB plane has weight -2^(abits-1)
        shifts = shifts.at[abits - 1].set(-(1 << (abits - 1)))
    return jnp.einsum("btn,t->bn", planes, shifts,
                      preferred_element_type=jnp.int32)


@partial(jax.jit, static_argnames=("nbw", "abits", "group_size"))
def lut_gemv_quantized(x: jax.Array, w_q: jax.Array, w_scales: jax.Array,
                       nbw: int, abits: int = 8,
                       group_size: int = 128) -> jax.Array:
    """End-to-end quantized GEMV: fp activations -> int LUT-GEMV -> dequant.

    Matches the SAIL dataflow: activations are quantized per token (CPU
    vector engine), the integer GEMV runs in C-SRAM via LUTs with per-group
    partial sums, and dequantization applies ``scale_x * scale_w[group]``
    per group before the final reduction (paper Fig. 3, step "CPU de-/quant").

    x        : f32 [B, K]
    w_q      : int32 [K, N] signed codes
    w_scales : f32 [K // group_size, N]
    Returns f32 [B, N] ~= x @ (w_q * scales-expanded).
    """
    from repro.core.quant import quantize_activations
    b, k = x.shape
    xq, xscale = quantize_activations(x, abits)
    # per-group integer partial sums so group-wise weight scales are exact
    luts = build_luts(w_q, nbw)                         # [G, 2^nbw, N]
    pats = activation_patterns(xq, nbw, abits)          # [B, abits, G]
    g_idx = jnp.arange(luts.shape[0])
    fetched = luts[g_idx[None, None, :], pats]          # [B, abits, G, N]
    shifts = (1 << jnp.arange(abits, dtype=jnp.int32))
    shifts = shifts.at[abits - 1].set(-(1 << (abits - 1)))
    psums = jnp.einsum("btgn,t->bgn", fetched, shifts,
                       preferred_element_type=jnp.int32)  # [B, G(K/nbw), N]
    # fold LUT groups into quant groups
    per_q = group_size // nbw
    gq = psums.shape[1] // per_q
    psums = psums.reshape(b, gq, per_q, -1).sum(axis=2)   # [B, K/gs, N]
    return jnp.einsum("bgn,gn->bn", psums.astype(jnp.float32), w_scales) * xscale


def reference_int_gemv(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Plain integer matmul oracle."""
    return jnp.einsum("bk,kn->bn", x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# Workload statistics consumed by the cost model (cycle accounting inputs)
# ---------------------------------------------------------------------------

def lut_gemv_op_counts(batch: int, k: int, n: int, nbw: int, abits: int = 8):
    """Count the abstract operations of one batched LUT-GEMV.

    Returns a dict the cost model converts to C-SRAM cycles:
      lut_builds   : number of (group) LUT constructions  = K/nbw per N-tile
      lut_entries  : entries per LUT                       = 2^nbw
      lookups      : total LUT reads = B * abits * K/nbw
      shift_adds   : accumulations   = lookups
    """
    groups = k // nbw
    return dict(
        lut_builds=groups,
        lut_entries=1 << nbw,
        lookups=batch * abits * groups,
        shift_adds=batch * abits * groups,
        n_cols=n,
    )
