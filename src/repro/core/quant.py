"""Group-wise low-bit quantization for SAIL.

The paper's LUT-GEMV consumes weights quantized at arbitrary precision
(2/3/4/5/6/8-bit, the ``ql`` field of the ``lutmm_1k`` instruction) with
group-wise scales.  This module provides:

  * ``quantize`` / ``dequantize``  — group-wise symmetric or asymmetric
    quantization along the reduction axis (rows of ``W[K, N]``).
  * ``pack_bits`` / ``unpack_bits`` — field packing of b-bit codes into
    uint32 words (``32 // b`` values per word; 3/5/6-bit waste 2 bits/word).
  * ``pack_grouped`` / ``unpack_grouped`` — bit-contiguous group packing
    (``ceil(b*G/32)`` words per group; codes may straddle word boundaries
    so packed bytes are strictly monotone in ``b``).
  * ``QTensor``                    — pytree carrying packed codes + scales +
    codebook, the storage format streamed HBM->VMEM by the Pallas kernel.
  * per-token activation quantization for the integer LUT-GEMV path.

Dequantization supports two modes, mirroring the two LUT flavours:
  * uniform  :  w = scale * (q - zero)            (affine; implicit LUT)
  * codebook :  w = scale * codebook[q]           (explicit 2^bits LUT,
                  the in-VMEM analogue of the paper's C-SRAM-resident LUT)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SUPPORTED_BITS = (2, 3, 4, 5, 6, 8)

# Precisions the storage/kernel layer handles.  1-bit (sign) weights are a
# kernel-level capability (the LUT formulation supports them for free) but
# stay out of SUPPORTED_BITS: the allocator's candidate set and the policy
# grammar keep the paper's 2..8-bit ``ql`` range.
KERNEL_BITS = (1,) + SUPPORTED_BITS

# Activation precisions the ``lutmm`` instruction parameterizes (the
# second precision field next to ``ql``).  ``None`` anywhere an abits is
# accepted means "serve f32 activations" (no activation quantization).
SUPPORTED_ABITS = (4, 6, 8)


def values_per_word(bits: int) -> int:
    """Number of b-bit codes fully contained per uint32 word."""
    if bits not in KERNEL_BITS:
        raise ValueError(f"bits must be one of {KERNEL_BITS}, got {bits}")
    return 32 // bits


# ---------------------------------------------------------------------------
# Bit packing (axis 0 is always the packed axis)
# ---------------------------------------------------------------------------

def pack_bits(codes: jax.Array, bits: int) -> jax.Array:
    """Pack unsigned b-bit integer ``codes`` along axis 0 into uint32 words.

    codes: integer array [K, ...] with values in [0, 2^bits).  K must be a
    multiple of ``values_per_word(bits)``.  Returns uint32 [K/vpw, ...].
    """
    vpw = values_per_word(bits)
    k = codes.shape[0]
    if k % vpw != 0:
        pad = vpw - k % vpw
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad,) + codes.shape[1:], codes.dtype)], axis=0)
        k = codes.shape[0]
    codes = codes.astype(jnp.uint32)
    grouped = codes.reshape((k // vpw, vpw) + codes.shape[1:])
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits).reshape(
        (1, vpw) + (1,) * (codes.ndim - 1))
    return jnp.bitwise_or.reduce(grouped << shifts, axis=1)


def unpack_bits(packed: jax.Array, bits: int, k: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`pack_bits`.  Returns int32 [K, ...]."""
    vpw = values_per_word(bits)
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits).reshape(
        (1, vpw) + (1,) * (packed.ndim - 1))
    codes = (packed[:, None] >> shifts) & mask
    out = codes.reshape((packed.shape[0] * vpw,) + packed.shape[1:])
    if k is not None:
        out = out[:k]
    return out.astype(jnp.int32)


def words_per_group(bits: int, group_size: int) -> int:
    """uint32 words holding one quantization group's codes.

    Packing is bit-contiguous within a group (codes may straddle word
    boundaries), so a group costs exactly ``ceil(bits * G / 32)`` words.
    This makes packed bytes strictly monotone in ``bits`` for every
    group size >= 32 — the old value-aligned layout collapsed 3/4-bit
    (and 5/6-bit) to identical sizes at group 32, flattening Pareto
    sweeps over the bit ladder.
    """
    if bits not in KERNEL_BITS:
        raise ValueError(f"bits must be one of {KERNEL_BITS}, got {bits}")
    return -(-(bits * group_size) // 32)  # ceil


def pack_grouped(codes: jax.Array, bits: int, group_size: int) -> jax.Array:
    """Group-aligned, bit-contiguous packing: each quantization group of
    ``group_size`` codes occupies ``ceil(bits*G/32)`` uint32 words, with
    the codes laid down as a little-endian bitstream (code ``v`` occupies
    stream bits ``[v*bits, (v+1)*bits)``; trailing stream bits zero).

    Groups stay word-aligned so a kernel block covering ``bk`` K-rows
    maps to exactly ``(bk // group_size) * wpg`` packed rows — the TPU
    analogue of SAIL keeping one group's LUT per C-SRAM residency.  When
    ``32 % bits == 0`` the layout coincides with plain value-aligned
    packing.  codes: [K, N] -> packed uint32 [(K//G)*wpg, N].
    """
    k = codes.shape[0]
    if k % group_size != 0:
        raise ValueError(f"K={k} not a multiple of group_size={group_size}")
    wpg = words_per_group(bits, group_size)
    g = k // group_size
    n_slots = wpg * 32  # stream bit positions per group
    grouped = codes.reshape((g, group_size) + codes.shape[1:])
    pad = -(-n_slots // bits) - group_size  # values covering every slot
    if pad:
        grouped = jnp.concatenate(
            [grouped, jnp.zeros((g, pad) + codes.shape[1:], codes.dtype)],
            axis=1)
    grouped = grouped.astype(jnp.uint32)
    t = np.arange(n_slots)
    src = jnp.asarray(t // bits, dtype=jnp.int32)
    sh = jnp.asarray(t % bits, dtype=jnp.uint32).reshape(
        (1, n_slots) + (1,) * (codes.ndim - 1))
    stream = (grouped[:, src] >> sh) & jnp.uint32(1)
    stream = stream.reshape((g, wpg, 32) + codes.shape[1:])
    wshifts = jnp.arange(32, dtype=jnp.uint32).reshape(
        (1, 1, 32) + (1,) * (codes.ndim - 1))
    words = jnp.sum(stream << wshifts, axis=2, dtype=jnp.uint32)
    return words.reshape((g * wpg,) + codes.shape[1:])


def unpack_grouped(packed: jax.Array, bits: int, group_size: int,
                   k: int) -> jax.Array:
    """Inverse of :func:`pack_grouped` -> int32 [K, ...]."""
    wpg = words_per_group(bits, group_size)
    g = k // group_size
    words = packed.reshape((g, wpg) + packed.shape[1:])
    wshifts = jnp.arange(32, dtype=jnp.uint32).reshape(
        (1, 1, 32) + (1,) * (packed.ndim - 1))
    stream = (words[:, :, None] >> wshifts) & jnp.uint32(1)
    stream = stream.reshape((g, wpg * 32) + packed.shape[1:])
    stream = stream[:, :group_size * bits].reshape(
        (g, group_size, bits) + packed.shape[1:])
    bshifts = jnp.arange(bits, dtype=jnp.uint32).reshape(
        (1, 1, bits) + (1,) * (packed.ndim - 1))
    codes = jnp.sum(stream << bshifts, axis=2, dtype=jnp.uint32)
    return codes.reshape((k,) + packed.shape[1:]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """SAIL-quantized weight tensor (the HBM storage format).

    Logical weight is ``W[K, N]`` (reduction dim first).  Fields:
      packed   : uint32 [(K//G)*wpg, N] group-aligned packed b-bit codes
      scales   : f32    [K // G, N]     per-group scales
      codebook : f32    [2**bits]       dequant LUT (uniform grid by default)
      bits, group_size, k: static metadata.
      abits    : activation precision this matmul serves at (the lutmm
                 instruction's second precision field); None keeps f32
                 activations.  When set, ``mm``/``einsum_q`` run the real
                 integer path: per-token ``abits`` codes enter the kernel
                 and the scale is applied to the output.
    """
    packed: jax.Array
    scales: jax.Array
    codebook: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    abits: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.packed.shape[-1]

    @property
    def shape(self):
        return (self.k, self.n)

    def nbytes(self) -> int:
        return (self.packed.size * 4 + self.scales.size * 4
                + self.codebook.size * 4)


def _uniform_codebook(bits: int) -> jnp.ndarray:
    """Symmetric uniform codebook: code q -> q - 2^(b-1) (signed grid)."""
    if bits == 1:
        # sign codebook: the signed grid degenerates to [-1, 0] at 1 bit
        return jnp.asarray([-1.0, 1.0], dtype=jnp.float32)
    qmax = (1 << (bits - 1)) - 1
    grid = jnp.arange(1 << bits, dtype=jnp.float32) - float(1 << (bits - 1))
    # normalise so max |entry| == 1; scale carries the magnitude
    return grid / float(max(qmax, 1))


def nf_codebook(bits: int) -> jnp.ndarray:
    """'NormalFloat'-style non-uniform codebook (beyond-paper option):

    quantiles of a standard normal, normalised to [-1, 1].  The explicit
    codebook LUT is exactly what the C-SRAM stores in SAIL, so non-uniform
    grids come for free in the LUT formulation.
    """
    levels = 1 << bits
    # evenly spaced probabilities avoiding 0/1
    p = (np.arange(levels) + 0.5) / levels
    # inverse normal CDF via numpy (Acklam approximation not needed: use
    # scipy-free erfinv through np)
    q = np.sqrt(2.0) * _erfinv(2 * p - 1)
    q = q / np.abs(q).max()
    return jnp.asarray(q, dtype=jnp.float32)


def _erfinv(x):
    """Vectorised inverse error function (Winitzki approximation, <2e-3)."""
    x = np.clip(x, -0.999999, 0.999999)
    a = 0.147
    ln1mx2 = np.log(1 - x * x)
    t1 = 2 / (np.pi * a) + ln1mx2 / 2
    return np.sign(x) * np.sqrt(np.sqrt(t1 * t1 - ln1mx2 / a) - t1)


def quantize(w: jax.Array, bits: int, group_size: int = 128,
             codebook: Optional[jax.Array] = None) -> QTensor:
    """Group-wise quantization of ``w[K, N]`` along K.

    For the uniform codebook this is classic symmetric round-to-nearest;
    for a general codebook it is nearest-codebook-entry assignment with a
    per-group absmax scale.
    """
    if w.ndim != 2:
        raise ValueError(f"expected W[K, N], got shape {w.shape}")
    k, n = w.shape
    if k % group_size != 0:
        raise ValueError(f"K={k} not a multiple of group_size={group_size}")
    if codebook is None:
        codebook = _uniform_codebook(bits)
    codebook = codebook.astype(jnp.float32)
    w = w.astype(jnp.float32)
    wg = w.reshape(k // group_size, group_size, n)
    scale = jnp.max(jnp.abs(wg), axis=1)  # [K/G, N]
    scale = jnp.where(scale == 0, 1.0, scale)
    normed = wg / scale[:, None, :]
    # nearest codebook entry: [KG, G, N, 1] vs [levels]
    dist = jnp.abs(normed[..., None] - codebook)
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint32)
    codes = codes.reshape(k, n)
    return QTensor(packed=pack_grouped(codes, bits, group_size), scales=scale,
                   codebook=codebook, bits=bits, group_size=group_size, k=k)


def dequantize(qt: QTensor) -> jax.Array:
    """Reconstruct f32 ``W[K, N]`` — the pure-jnp oracle for all kernels."""
    codes = unpack_grouped(qt.packed, qt.bits, qt.group_size, qt.k)  # [K, N]
    vals = qt.codebook[codes]                              # [K, N]
    vals = vals.reshape(qt.k // qt.group_size, qt.group_size, qt.n)
    return (vals * qt.scales[:, None, :]).reshape(qt.k, qt.n)


def quantize_int(w: jax.Array, bits: int, group_size: int = 128):
    """Integer-domain group-wise quantization used by the *faithful*
    bit-serial LUT-GEMV path (core/lut_gemv.py).

    Returns (w_q int32 [K,N] signed codes, scales f32 [K/G, N]) with
    w ~= scales[g] * w_q.
    """
    k, n = w.shape
    qmax = (1 << (bits - 1)) - 1
    wg = w.reshape(k // group_size, group_size, n)
    absmax = jnp.max(jnp.abs(wg), axis=1)
    absmax = jnp.where(absmax == 0, 1.0, absmax)
    scale = absmax / qmax
    wq = jnp.clip(jnp.round(wg / scale[:, None, :]), -qmax - 1, qmax)
    return wq.reshape(k, n).astype(jnp.int32), scale


def quantize_activations(x: jax.Array, bits: int = 8):
    """Per-token (row) symmetric activation quantization.

    x[B, K] -> (x_q int32 in [-2^(b-1)+1, 2^(b-1)-1], scale f32 [B, 1]).

    Under a tensor-parallel shard_map trace the absmax is maxed over the
    model axis: a row-parallel matmul's input is K-sharded, and only the
    global absmax reproduces the unsharded quantization bit-for-bit.
    """
    from repro.dist.sharding import tp_axis_max

    qmax = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    absmax = tp_axis_max(absmax)
    absmax = jnp.where(absmax == 0, 1.0, absmax)
    scale = absmax / qmax
    xq = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return xq, scale


def quantize_kv(x: jax.Array, axis: int = -1):
    """int8 symmetric quantization for the KV cache (per-head-dim absmax).

    Returns (int8 codes, f32 scales broadcastable against codes)."""
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    absmax = jnp.where(absmax == 0, 1.0, absmax)
    scale = absmax / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_kv(codes: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)
