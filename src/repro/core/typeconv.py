"""In-memory parallel type conversion (SAIL Algorithm 1).

Converts n-bit signed integers (n <= 25) to IEEE-754 single-precision floats
using only the logic operations available to bitline in-SRAM computing:
cumulative OR for leading-one detection, a 5-bit ripple popcount for the
exponent, and a bit-reversed multiply for mantissa alignment.  The JAX
implementation below follows the algorithm line-by-line (vectorised across
the array, the way 512 bitlines execute it in lockstep) and is bit-exact
against ``astype(float32)`` for all |A| < 2**24 — the paper excludes NaN /
subnormals (footnote 1) and we special-case zero, which the listing glosses.

Also exported: the paper's cycle/op-count formulas
    logic_ops(n)  = n^2 / 2 + 13 (n - 1)
    sram_cycles(n)= 3 n^2 / 2 + 39 (n - 1)
used by the cost model to price de-/quantization work done in C-SRAM instead
of the CPU vector units.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def logic_ops(n: int) -> float:
    """O(n^2/2 + 13(n-1)) logical operations (paper Sec. III-E)."""
    return n * n / 2.0 + 13.0 * (n - 1)


def sram_cycles(n: int) -> float:
    """(3n^2/2 + 39(n-1)) in-SRAM cycles (paper Sec. III-E)."""
    return 1.5 * n * n + 39.0 * (n - 1)


@partial(jax.jit, static_argnames=("n",))
def int_to_f32(a: jax.Array, n: int = 25) -> jax.Array:
    """Algorithm 1: n-bit signed int -> IEEE-754 float32, bitwise ops only.

    a : int32 array, values representable in n bits (|a| < 2**(n-1), n<=25).
    Returns float32 array bit-equal to ``a.astype(float32)``.
    """
    if not 2 <= n <= 25:
        raise ValueError("Algorithm 1 requires 2 <= n <= 25")
    a = a.astype(jnp.int32)
    sign = (a >> 31) & 1                              # a_{n-1} (sign bit)
    # work on the (n-1)-bit magnitude: the listing implicitly assumes
    # sign-magnitude form, so take |A| with logic-compatible ops
    mag = jnp.where(sign == 1, -a, a).astype(jnp.uint32)

    nm1 = n - 1  # number of magnitude bits
    # ---- lines 2-4: leading-one detection via cumulative OR -------------
    # C gets 1s from the leading-one position down to bit 0
    d = jnp.zeros_like(mag)
    c = jnp.zeros_like(mag)
    for i in range(nm1 - 1, -1, -1):
        ai = (mag >> i) & 1
        d = d | ai
        c = c | (d << i)

    # ---- lines 5-11: popcount(C) via 5-bit ripple counter ---------------
    s = [jnp.zeros_like(mag) for _ in range(5)]       # Sum bits s0..s4
    for i in range(nm1):
        carry = (c >> i) & 1
        for j in range(5):
            c1 = s[j] & carry
            s[j] = s[j] ^ carry
            carry = c1
    popc = sum(sj << j for j, sj in enumerate(s))     # = floor(log2 mag)+1
    biased_exp = popc + 126                           # line 11

    # ---- lines 16-17: mantissa alignment -------------------------------
    # C+1 = 2^(p+1); the listing's "BitReverse over (n-1) bits then <<1"
    # equals an n-bit reverse for p <= n-3 but is undefined when the leading
    # one sits at the top magnitude bit (C+1 overflows n-1 bits).  An n-bit
    # reverse is the exact equivalent covering that case too:
    #   rev_n(2^(p+1)) = 2^(n-2-p) = 2^k, k = leading zeros of the magnitude
    cp1 = c + 1                                       # up to 2^(n-1), fits n bits
    rev = jnp.zeros_like(mag)
    for i in range(n):
        rev = rev | (((cp1 >> i) & 1) << (n - 1 - i))
    mult = rev                                        # 2^k  (k = lead zeros)
    aligned = (mag * mult) & jnp.uint32((1 << nm1) - 1)  # A * 2^k (line 17)

    # ---- lines 12-15 / 18-20: assemble R --------------------------------
    r = sign.astype(jnp.uint32) << 31
    r = r | (biased_exp.astype(jnp.uint32) << 23)
    # mantissa: bits a_{n-3..0} of aligned map to r_{22 .. 22-(n-3)}
    if nm1 >= 2:
        mant = (aligned & jnp.uint32((1 << (nm1 - 1)) - 1))  # drop hidden 1
        mant_shift = 23 - (nm1 - 1)
        r = r | (mant << mant_shift)
    # zero is an exceptional case in the paper; handle explicitly
    r = jnp.where(mag == 0, jnp.uint32(0), r)
    return jax.lax.bitcast_convert_type(r, jnp.float32)


def f32_to_int(x: jax.Array, n: int = 25) -> jax.Array:
    """The 'straightforward other direction' (paper footnote): f32 -> intN.

    Round-to-nearest-even truncation matching jnp.rint + clip to n bits.
    """
    lim = (1 << (n - 1)) - 1
    return jnp.clip(jnp.rint(x), -lim - 1, lim).astype(jnp.int32)
