"""Pattern-Aware LUT optimization (SAIL Sec. III-D).

Each Data Feeding Module (DFM) holds a 32-entry fully-associative Pattern
Reuse Table (PRT) storing a hash of the NBW-bit input pattern (plus its
group/bit-plane context) and the previous LUT result; a hit bypasses the
C-SRAM read.  The paper reports ~17% of input activation patterns repeating
within computation batches, yielding a 13.8% computation-cycle reduction.

A content-addressable skip has no TPU analogue (SIMD lanes cannot
divergently skip work), so on TPU the optimization lives in the cost model:
this module measures the *actual* pattern-repeat statistics of activation
tensors under the DFM's access order and converts PRT hit rates into the
cycle discount used by ``repro.core.cost_model``.

Access-order assumption (the paper underspecifies): the DFM walks
bit-plane-major, then batch, then group — consecutive accesses for the same
group across the batch are adjacent, which is the order that makes the
"reuse within the batch" statement strongest.  Keys are (group, pattern):
a hit means the identical LUT entry was fetched recently and its value can
be served from the PRT.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.lut_gemv import activation_patterns

PRT_ENTRIES = 32
PAPER_REPEAT_RATE = 0.17
PAPER_CYCLE_REDUCTION = 0.138

# FreePDK-45nm synthesis numbers from the paper (per PRT incl. adder tree)
PRT_AREA_MM2 = 0.0012
PRT_POWER_MW = 0.25


@dataclasses.dataclass
class PRTStats:
    accesses: int
    hits: int
    unique_patterns: int

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)


def prt_simulate(patterns: np.ndarray, entries: int = PRT_ENTRIES) -> PRTStats:
    """Simulate one 32-entry fully-associative PRT with FIFO replacement.

    patterns: int array [B, abits, G] from ``activation_patterns`` — the
    stream order is (bit-plane, group, batch): for each bit-plane and group,
    the whole batch streams through, which is where cross-user pattern reuse
    (the paper's 17%) lives.
    """
    b, abits, g = patterns.shape
    # stream[(t, g), b] -> key (group, pattern)
    hits = 0
    accesses = 0
    uniq = set()
    table: list = []  # FIFO of keys
    lookup = set()
    for t in range(abits):
        for gi in range(g):
            for bi in range(b):
                key = (gi, int(patterns[bi, t, gi]))
                uniq.add(key)
                accesses += 1
                if key in lookup:
                    hits += 1
                else:
                    table.append(key)
                    lookup.add(key)
                    if len(table) > entries:
                        evicted = table.pop(0)
                        lookup.discard(evicted)
    return PRTStats(accesses=accesses, hits=hits, unique_patterns=len(uniq))


def measure_repeat_rate(x_q, nbw: int, abits: int = 8,
                        entries: int = PRT_ENTRIES) -> PRTStats:
    """Measure PRT hit statistics for a quantized activation batch.

    x_q: int32 [B, K] quantized activations.
    """
    pats = np.asarray(activation_patterns(x_q, nbw, abits))
    return prt_simulate(pats, entries=entries)


def vectorized_repeat_rate(x_q, nbw: int, abits: int = 8) -> float:
    """Fast upper-bound repeat estimate (no capacity misses): the fraction
    of (bit-plane, group) accesses whose pattern already appeared for an
    earlier batch element.  This is the paper's "~17% of input activation
    patterns repeat within computation batches" statistic.
    """
    pats = np.asarray(activation_patterns(x_q, nbw, abits))  # [B, T, G]
    b = pats.shape[0]
    if b < 2:
        return 0.0
    repeats = 0
    total = 0
    # within each (T, G) column, count duplicates across the batch
    flat = pats.reshape(b, -1)
    for col in range(flat.shape[1]):
        vals = flat[:, col]
        _, counts = np.unique(vals, return_counts=True)
        repeats += int((counts - 1).sum())
        total += b
    return repeats / max(total, 1)


def cycle_discount(hit_rate: float,
                   paper_rate: float = PAPER_REPEAT_RATE,
                   paper_discount: float = PAPER_CYCLE_REDUCTION) -> float:
    """Convert a PRT hit rate into a compute-cycle discount factor.

    The paper maps a 17% repeat rate to a 13.8% cycle reduction (hits skip
    the C-SRAM read but still traverse the DFM adder tree).  We scale that
    published ratio linearly in the measured hit rate and return the
    multiplicative factor to apply to lookup cycles.
    """
    eff = paper_discount / paper_rate  # cycles saved per unit hit-rate
    return max(0.0, 1.0 - eff * hit_rate)
