"""Pattern-Aware LUT optimization (SAIL Sec. III-D).

Each Data Feeding Module (DFM) holds a 32-entry fully-associative Pattern
Reuse Table (PRT) storing a hash of the NBW-bit input pattern (plus its
group/bit-plane context) and the previous LUT result; a hit bypasses the
C-SRAM read.  The paper reports ~17% of input activation patterns repeating
within computation batches, yielding a 13.8% computation-cycle reduction.

A content-addressable skip has no TPU analogue (SIMD lanes cannot
divergently skip work), so on TPU the optimization lives in the cost model:
this module measures the *actual* pattern-repeat statistics of activation
tensors under the DFM's access order and converts PRT hit rates into the
cycle discount used by ``repro.core.cost_model``.

Access-order assumption (the paper underspecifies): the DFM walks
bit-plane-major, then batch, then group — consecutive accesses for the same
group across the batch are adjacent, which is the order that makes the
"reuse within the batch" statement strongest.  Keys are (group, pattern):
a hit means the identical LUT entry was fetched recently and its value can
be served from the PRT.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.lut_gemv import activation_patterns

PRT_ENTRIES = 32
PAPER_REPEAT_RATE = 0.17
PAPER_CYCLE_REDUCTION = 0.138

# FreePDK-45nm synthesis numbers from the paper (per PRT incl. adder tree)
PRT_AREA_MM2 = 0.0012
PRT_POWER_MW = 0.25


@dataclasses.dataclass
class PRTStats:
    accesses: int
    hits: int
    unique_patterns: int

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)


def prt_simulate(patterns: np.ndarray, entries: int = PRT_ENTRIES) -> PRTStats:
    """Simulate one 32-entry fully-associative PRT with FIFO replacement.

    patterns: int array [B, abits, G] from ``activation_patterns`` — the
    stream order is (bit-plane, group, batch): for each bit-plane and group,
    the whole batch streams through, which is where cross-user pattern reuse
    (the paper's 17%) lives.
    """
    b, abits, g = patterns.shape
    # stream[(t, g), b] -> key (group, pattern)
    hits = 0
    accesses = 0
    uniq = set()
    table: list = []  # FIFO of keys
    lookup = set()
    for t in range(abits):
        for gi in range(g):
            for bi in range(b):
                key = (gi, int(patterns[bi, t, gi]))
                uniq.add(key)
                accesses += 1
                if key in lookup:
                    hits += 1
                else:
                    table.append(key)
                    lookup.add(key)
                    if len(table) > entries:
                        evicted = table.pop(0)
                        lookup.discard(evicted)
    return PRTStats(accesses=accesses, hits=hits, unique_patterns=len(uniq))


def measure_repeat_rate(x_q, nbw: int, abits: int = 8,
                        entries: int = PRT_ENTRIES) -> PRTStats:
    """Measure PRT hit statistics for a quantized activation batch.

    x_q: int32 [B, K] quantized activations.
    """
    pats = np.asarray(activation_patterns(x_q, nbw, abits))
    return prt_simulate(pats, entries=entries)


def vectorized_repeat_rate(x_q, nbw: int, abits: int = 8) -> float:
    """Fast upper-bound repeat estimate (no capacity misses): the fraction
    of (bit-plane, group) accesses whose pattern already appeared for an
    earlier batch element.  This is the paper's "~17% of input activation
    patterns repeat within computation batches" statistic.
    """
    pats = np.asarray(activation_patterns(x_q, nbw, abits))  # [B, T, G]
    b = pats.shape[0]
    if b < 2:
        return 0.0
    repeats = 0
    total = 0
    # within each (T, G) column, count duplicates across the batch
    flat = pats.reshape(b, -1)
    for col in range(flat.shape[1]):
        vals = flat[:, col]
        _, counts = np.unique(vals, return_counts=True)
        repeats += int((counts - 1).sum())
        total += b
    return repeats / max(total, 1)


def cycle_discount(hit_rate: float,
                   paper_rate: float = PAPER_REPEAT_RATE,
                   paper_discount: float = PAPER_CYCLE_REDUCTION) -> float:
    """Convert a PRT hit rate into a compute-cycle discount factor.

    The paper maps a 17% repeat rate to a 13.8% cycle reduction (hits skip
    the C-SRAM read but still traverse the DFM adder tree).  We scale that
    published ratio linearly in the measured hit rate and return the
    multiplicative factor to apply to lookup cycles.
    """
    eff = paper_discount / paper_rate  # cycles saved per unit hit-rate
    return max(0.0, 1.0 - eff * hit_rate)


# ---------------------------------------------------------------------------
# Measured per-precision discount (replaces the flat 13.8% constant when the
# cost model runs with ``prt="measured"``)
# ---------------------------------------------------------------------------

# The weight precision the paper's single published (17%, 13.8%) anchor was
# measured at; the per-hit cycle saving is calibrated there and rescaled to
# other ``ql`` by the lookup-cost ratio (a hit skips a fixed amount of
# C-SRAM work, so cheaper lookups see a LARGER fractional discount).
PAPER_ANCHOR_QL = 4

# Synthetic default calibration activations are capped at this many
# features: PRT hit statistics saturate long before real hidden sizes
# (the 32-entry table thrashes across groups either way) and the stream
# simulation is a Python loop.
_SYNTH_K_CAP = 2048

_HIT_RATE_CACHE: dict = {}
_SYNTH_CACHE: dict = {}
_BATCH_KEY_CACHE: dict = {}


def synthetic_activations(k: int, batch: int = 8,
                          seed: int = 0) -> np.ndarray:
    """Deterministic f32 [batch, k] stand-in activation batch for PRT
    calibration when no held-out activations are provided (matches the
    synthetic data used throughout the repro).  Memoized: the cost model
    resolves a discount per (unit, nbw, abits) and must not regenerate
    the batch thousands of times per calibration."""
    key = (int(k), int(batch), int(seed))
    got = _SYNTH_CACHE.get(key)
    if got is None:
        rng = np.random.default_rng((seed, k, batch))
        got = rng.standard_normal((batch, k)).astype(np.float32)
        got.setflags(write=False)
        _SYNTH_CACHE[key] = got
    return got


def canonical_calib(calib) -> "np.ndarray | dict | None":
    """Normalize a calibration batch to ONE f32 ndarray object.

    Callers that loop over precisions (the joint allocator's cost
    tables, ``mixed_decode_cycles(nbw="auto")``) should canonicalize
    once at their boundary: passing a JAX array or non-f32 ndarray
    straight through would re-materialize (and re-fingerprint) the batch
    on every discount lookup, defeating the identity-keyed memoization
    below.  A per-layer mapping ``{layer: batch}`` (see
    ``repro.planning.tap.ActivationTap.calib``) canonicalizes each
    value; resolve one layer's batch with :func:`calib_for_layer`."""
    if calib is None:
        return None
    if isinstance(calib, dict):
        return {k: np.asarray(v, dtype=np.float32) for k, v in calib.items()}
    return np.asarray(calib, dtype=np.float32)


def calib_for_layer(calib, layer):
    """Per-layer calibration mapping -> one batch: the layer's own
    captured activations when present, else the ``None``-keyed global
    fallback.  Plain arrays (and None) pass through."""
    if isinstance(calib, dict):
        got = calib.get(layer)
        return got if got is not None else calib.get(None)
    return calib


def _batch_key(arr: np.ndarray):
    """Content fingerprint of a calibration batch, cached per array
    object (identity-checked via weakref, so id() reuse cannot alias) —
    hashing the same default batch on every discount lookup would
    otherwise dominate the memoized path."""
    import hashlib
    import weakref
    hit = _BATCH_KEY_CACHE.get(id(arr))
    if hit is not None and hit[0]() is arr:
        return hit[1]
    key = (arr.shape, hashlib.sha1(arr.tobytes()).hexdigest()[:16])
    try:
        if len(_BATCH_KEY_CACHE) > 128:   # drop dead-weakref entries
            for k in [k for k, (ref, _) in _BATCH_KEY_CACHE.items()
                      if ref() is None]:
                del _BATCH_KEY_CACHE[k]
        _BATCH_KEY_CACHE[id(arr)] = (weakref.ref(arr), key)
    except TypeError:
        pass
    return key


def prt_hit_rate(nbw: int, abits: int, calib_batch=None,
                 entries: int = PRT_ENTRIES) -> float:
    """Measured PRT hit rate for one (NBW, abits) precision point.

    ``calib_batch``: f32 [B, K] activations (held-out data, or the
    synthetic default).  The batch is quantized per token at ``abits``
    and streamed through the PRT simulator — narrow activation codes
    repeat more often (2^``abits``-ish distinct bit-plane patterns), so
    the hit rate is genuinely per-precision rather than the paper's one
    global 17%.  Results are memoized on (nbw, abits, entries, batch).
    """
    if calib_batch is None:
        calib_batch = synthetic_activations(_SYNTH_K_CAP)
    arr = np.asarray(calib_batch, dtype=np.float32)
    if arr.ndim != 2:
        raise ValueError(f"calib_batch must be [B, K], got {arr.shape}")
    key = (int(nbw), int(abits), int(entries), _batch_key(arr))
    hit = _HIT_RATE_CACHE.get(key)
    if hit is None:
        from repro.core.quant import quantize_activations
        xq, _ = quantize_activations(arr, abits)
        stats = measure_repeat_rate(np.asarray(xq), nbw, abits, entries)
        hit = stats.hit_rate
        _HIT_RATE_CACHE[key] = hit
    return hit


def prt_discount(nbw: int, abits: int, ql: int, calib_batch=None,
                 entries: int = PRT_ENTRIES, machine=None) -> float:
    """Measured pattern-aware cycle discount for one (nbw, abits, ql).

    Two per-precision effects compose:

      * the HIT RATE is measured per (nbw, abits) from ``calib_batch``
        via :func:`prt_hit_rate` — narrower activations repeat more;
      * the PER-HIT SAVING is a fixed amount of skipped C-SRAM work,
        calibrated so the paper's anchor (ql=4, 17% hits -> 13.8% fewer
        cycles) is reproduced exactly, then rescaled by the lookup-cost
        ratio: at cheap (low ``ql``) lookups a hit saves a larger
        fraction, at expensive ones a smaller fraction.

    Returns the multiplicative factor applied to lookup cycles.
    """
    from repro.core import cost_model as _cm
    m = machine or _cm.SailMachine()
    hit = prt_hit_rate(nbw, abits, calib_batch, entries)
    saved_per_hit = (PAPER_CYCLE_REDUCTION / PAPER_REPEAT_RATE) * \
        _cm.lookup_cycles(m, PAPER_ANCHOR_QL)
    eff = saved_per_hit / _cm.lookup_cycles(m, ql)
    return max(0.0, 1.0 - eff * hit)
