"""Calibration of the SAIL analytic machine model against published anchors.

The paper hard-codes characterized NDP cycle counts into gem5 (Sec. V-A);
we recover the equivalent characterization by fitting the four dataflow
constants the microarchitecture description does not pin down:

  lookup cycles        L(wb) = a + b*wb      (DFM broadcast + SA read + add)
  rebuild control      ctrl * (2/nbw)^eta    (per-group residency swap)
  thread contention    tau                   (eff = 1/(1+tau*(T-1)))

against:
  * the three Fig. 6 anchor points (lutmm_1k tile, B=24):
      (nbw=4, 2-bit) 3.00M cycles, (nbw=4, 4-bit) 4.87M, (nbw=2, 2-bit) 11.45M
  * all 12 Table II SAIL cells at 1/16 threads (aggregate tokens/s,
    batch 8 — the batch the paper identifies as balancing the pipeline).

Run:  PYTHONPATH=src python -m repro.core.calibrate
Prints the best-fit constants (already baked into SailMachine defaults)
and the per-anchor residuals recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.core import cost_model as cm


def fit(verbose: bool = True):
    anchors_fig6 = cm.PAPER_FIG6_ANCHORS
    t2 = cm.PAPER_TABLE_II

    best = None
    # coarse -> fine grid search (cheap: model is closed-form)
    grids = [
        dict(a=np.linspace(5, 60, 9), b=np.linspace(2, 30, 9),
             ctrl=np.linspace(2e3, 3e4, 9), eta=np.linspace(1.2, 3.4, 8),
             tau=np.linspace(0.0, 0.05, 6)),
    ]
    for _ in range(3):
        g = grids[-1]
        for a, b, ctrl, eta, tau in itertools.product(
                g["a"], g["b"], g["ctrl"], g["eta"], g["tau"]):
            m = cm.SailMachine(lookup_base_cycles=float(a),
                               lookup_per_bit_cycles=float(b),
                               rebuild_ctrl_cycles=float(ctrl),
                               rebuild_nbw_exp=float(eta),
                               thread_scale_tau=float(tau))
            err = 0.0
            for (bsz, nbw, wb), target in anchors_fig6.items():
                got = cm.fig6_workload_cycles(bsz, nbw, wb, m)
                err += 3.0 * math.log(got / target) ** 2
            for (model_name, ql), cols in t2.items():
                model = cm.LLAMA2_7B if model_name == "7b" else cm.LLAMA2_13B
                for ti, threads in ((0, 1), (4, 16)):
                    target = cols["sail"][ti]
                    got = cm.sail_tokens_per_second(model, ql, threads,
                                                    batch=8, machine=m)
                    err += math.log(got / target) ** 2
            if best is None or err < best[0]:
                best = (err, dict(a=a, b=b, ctrl=ctrl, eta=eta, tau=tau))
        # refine around the best point
        c = best[1]
        grids.append(dict(
            a=np.linspace(max(1, c["a"] * 0.6), c["a"] * 1.5, 7),
            b=np.linspace(max(0.5, c["b"] * 0.6), c["b"] * 1.5, 7),
            ctrl=np.linspace(c["ctrl"] * 0.6, c["ctrl"] * 1.5, 7),
            eta=np.linspace(max(0.8, c["eta"] - 0.5), c["eta"] + 0.5, 7),
            tau=np.linspace(max(0.0, c["tau"] - 0.01), c["tau"] + 0.01, 5),
        ))

    err, c = best
    m = cm.SailMachine(lookup_base_cycles=c["a"],
                       lookup_per_bit_cycles=c["b"],
                       rebuild_ctrl_cycles=c["ctrl"],
                       rebuild_nbw_exp=c["eta"],
                       thread_scale_tau=c["tau"])
    if verbose:
        print(f"best-fit constants: {c}  (sum sq log-err {err:.4f})")
        print("\nFig. 6 anchors (model vs paper, Mcycles):")
        for (bsz, nbw, wb), target in anchors_fig6.items():
            got = cm.fig6_workload_cycles(bsz, nbw, wb, m)
            print(f"  B={bsz} NBW={nbw} Q{wb}: {got/1e6:6.2f} vs {target/1e6:5.2f}"
                  f"  ({got/target - 1:+.1%})")
        print("\nTable II SAIL (model vs paper, tokens/s, batch=8):")
        rows = []
        for (model_name, ql), cols in sorted(t2.items()):
            model = cm.LLAMA2_7B if model_name == "7b" else cm.LLAMA2_13B
            for ti, threads in ((0, 1), (4, 16)):
                target = cols["sail"][ti]
                got = cm.sail_tokens_per_second(model, ql, threads, 8,
                                                machine=m)
                rows.append(got / target)
                print(f"  {model_name}-Q{ql} {threads:2d}T: {got:7.2f} vs "
                      f"{target:7.2f}  ({got/target - 1:+.1%})")
        ratios = np.array(rows)
        print(f"\n  geomean model/paper = {np.exp(np.mean(np.log(ratios))):.3f}"
              f"  | mean abs err = {np.mean(np.abs(ratios - 1)):.1%}")
    return m, err


if __name__ == "__main__":
    fit()
