"""Tensor-level scheduling & ping-pong pipelining (SAIL Sec. III-A).

The paper's serving-side contribution: during batched inference, load each
layer's weight tensor into the LLC **once** and run every user's computation
against it before moving to the next layer (weight temporal locality), and
split the cache into two halves used as a ping-pong buffer so the DRAM->LLC
stream of layer L+1 overlaps the C-SRAM compute of layer L.

On TPU the same two ideas appear one level down the hierarchy (HBM->VMEM
double-buffering inside the Pallas kernel) and one level up (layer-at-a-time
weight residency in the serving engine, batch-iteration scheduling).  This
module provides the hardware-agnostic planner used by both:

  * ``TensorSchedule``  — the (layer, tensor) -> phase residency plan;
  * ``PipelineModel``   — analytic ping-pong timing (bubble-free condition,
    optimal batch — the paper finds batch ~= 8 balances the pipeline);
  * ``IterationScheduler`` — the iteration-level batcher used by
    ``repro.serving.engine`` (one model iteration serves every active user,
    the Orca/vLLM-style loop the paper assumes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TensorPlacement:
    name: str
    nbytes: int
    layer: int
    buffer: int           # 0/1 ping-pong half
    phase: int            # pipeline step in which it is resident


@dataclasses.dataclass
class TensorSchedule:
    """Layer-at-a-time residency plan over a two-half buffer of given size."""
    placements: List[TensorPlacement]
    buffer_bytes: int
    n_phases: int

    def residency(self, phase: int) -> List[TensorPlacement]:
        return [p for p in self.placements if p.phase == phase]


def plan_tensor_schedule(layer_tensors: Sequence[Sequence[Tuple[str, int]]],
                         buffer_bytes: int) -> TensorSchedule:
    """Assign each layer's tensors to alternating ping-pong halves.

    layer_tensors: per layer, a list of (tensor_name, nbytes).
    Each half must hold one layer's working set (the paper loads one layer's
    weights at a time); raises if a layer exceeds half the buffer — the
    caller must then split the layer into tiles (sc/loc fields of lutmm_1k).
    """
    half = buffer_bytes // 2
    placements: List[TensorPlacement] = []
    phase = 0
    for layer, tensors in enumerate(layer_tensors):
        total = sum(b for _, b in tensors)
        n_tiles = max(1, -(-total // half))   # ceil: split layer into tiles
        per_tile = [[] for _ in range(n_tiles)]
        acc = [0] * n_tiles
        for name, b in sorted(tensors, key=lambda t: -t[1]):
            i = min(range(n_tiles), key=lambda j: acc[j])
            if acc[i] + b > half and b <= half:
                i = next((j for j in range(n_tiles) if acc[j] + b <= half), i)
            per_tile[i].append((name, b))
            acc[i] += b
        for tile in per_tile:
            for name, b in tile:
                placements.append(TensorPlacement(
                    name=name, nbytes=b, layer=layer,
                    buffer=phase % 2, phase=phase))
            phase += 1
    return TensorSchedule(placements=placements, buffer_bytes=buffer_bytes,
                          n_phases=phase)


@dataclasses.dataclass
class PipelineModel:
    """Analytic ping-pong pipeline (paper Fig. 4).

    Per phase: one buffer half is written with the next weight tile
    (t_write = tile_bytes / stream_bw) while the other half feeds compute
    (t_compute).  The pipeline is bubble-free iff t_write <= t_compute; the
    paper finds batch ~= 8 balances the two for its machine.
    """
    stream_bw: float              # bytes/s into the buffer (DRAM->LLC)
    compute_rate: float           # effective bytes/s consumed by compute at B=1

    def phase_time(self, tile_bytes: int, batch: int) -> float:
        t_write = tile_bytes / self.stream_bw
        t_compute = batch * tile_bytes / self.compute_rate
        return max(t_write, t_compute)

    def iteration_time(self, tile_bytes_seq: Iterable[int],
                       batch: int) -> float:
        seq = list(tile_bytes_seq)
        if not seq:
            return 0.0
        # fill: first write is exposed; afterwards phases overlap
        fill = seq[0] / self.stream_bw
        return fill + sum(self.phase_time(b, batch) for b in seq)

    def bubble_free_batch(self, tile_bytes: int) -> int:
        """Smallest batch at which compute fully hides the write stream."""
        b = 1
        while (batch_compute := b * tile_bytes / self.compute_rate) < \
                tile_bytes / self.stream_bw and b < 1024:
            b += 1
        return b

    def optimal_batch(self, tile_bytes: int, max_batch: int = 64) -> int:
        """Batch maximising aggregate throughput = B / phase_time(B).

        Throughput rises until the pipeline balances, then plateaus (the
        paper's Fig. 6 'plateaus beyond about 7...8'); pick the knee."""
        best_b, best_rate = 1, 0.0
        for b in range(1, max_batch + 1):
            rate = b / self.phase_time(tile_bytes, b)
            if rate > best_rate * 1.02:      # 2% hysteresis finds the knee
                best_b, best_rate = b, rate
        return best_b


# ---------------------------------------------------------------------------
# Iteration-level batching (serving-side scheduler)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: int = 0
    done: bool = False


@dataclasses.dataclass
class IterationScheduler:
    """Iteration-based scheduler: each model iteration serves every active
    user once (paper Sec. III-A: 'inference serving systems operate on an
    iteration-based principle when serving multiple users').

    Admission keeps the running batch at ``target_batch`` (the pipeline's
    optimal), back-filling finished slots from the waiting queue — the
    iteration-granular variant of continuous batching, which the paper
    treats as orthogonal.
    """
    target_batch: int = 8
    max_batch: int = 32
    waiting: List[Request] = dataclasses.field(default_factory=list)
    running: List[Request] = dataclasses.field(default_factory=list)
    finished: List[Request] = dataclasses.field(default_factory=list)

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def admit(self) -> List[Request]:
        """Fill the running batch up to target from the FIFO queue."""
        while self.waiting and len(self.running) < self.target_batch:
            self.running.append(self.waiting.pop(0))
        return list(self.running)

    def step_complete(self, finished_uids: Sequence[int]) -> None:
        done = set(finished_uids)
        still = []
        for r in self.running:
            r.generated += 1
            if r.uid in done or r.generated >= r.max_new_tokens:
                r.done = True
                self.finished.append(r)
            else:
                still.append(r)
        self.running = still

    @property
    def active(self) -> int:
        return len(self.running)

    def idle(self) -> bool:
        return not self.waiting and not self.running
