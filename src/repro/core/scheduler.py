"""Tensor-level scheduling & ping-pong pipelining (SAIL Sec. III-A).

The paper's serving-side contribution: during batched inference, load each
layer's weight tensor into the LLC **once** and run every user's computation
against it before moving to the next layer (weight temporal locality), and
split the cache into two halves used as a ping-pong buffer so the DRAM->LLC
stream of layer L+1 overlaps the C-SRAM compute of layer L.

On TPU the same two ideas appear one level down the hierarchy (HBM->VMEM
double-buffering inside the Pallas kernel) and one level up (layer-at-a-time
weight residency in the serving engine, batch-iteration scheduling).  This
module provides the hardware-agnostic planner used by both:

  * ``TensorSchedule``  — the (layer, tensor) -> phase residency plan;
  * ``PipelineModel``   — analytic ping-pong timing (bubble-free condition,
    optimal batch — the paper finds batch ~= 8 balances the pipeline);
  * ``IterationScheduler`` — the slot-based continuous-batching scheduler
    driving ``repro.serving.engine``: one model iteration serves every
    active user (the Orca/vLLM-style loop the paper assumes), requests
    occupy fixed KV-pool slots from admission to retirement, and freed
    slots are back-filled from the FIFO queue at iteration granularity
    under a Sarathi-style per-iteration prefill-token budget.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TensorPlacement:
    name: str
    nbytes: int
    layer: int
    buffer: int           # 0/1 ping-pong half
    phase: int            # pipeline step in which it is resident


@dataclasses.dataclass
class TensorSchedule:
    """Layer-at-a-time residency plan over a two-half buffer of given size."""
    placements: List[TensorPlacement]
    buffer_bytes: int
    n_phases: int

    def residency(self, phase: int) -> List[TensorPlacement]:
        return [p for p in self.placements if p.phase == phase]


def plan_tensor_schedule(layer_tensors: Sequence[Sequence[Tuple[str, int]]],
                         buffer_bytes: int) -> TensorSchedule:
    """Assign each layer's tensors to alternating ping-pong halves.

    layer_tensors: per layer, a list of (tensor_name, nbytes).
    Each half must hold one layer's working set (the paper loads one layer's
    weights at a time); raises if a layer exceeds half the buffer — the
    caller must then split the layer into tiles (sc/loc fields of lutmm_1k).
    """
    half = buffer_bytes // 2
    placements: List[TensorPlacement] = []
    phase = 0
    for layer, tensors in enumerate(layer_tensors):
        total = sum(b for _, b in tensors)
        n_tiles = max(1, -(-total // half))   # ceil: split layer into tiles
        per_tile = [[] for _ in range(n_tiles)]
        acc = [0] * n_tiles
        for name, b in sorted(tensors, key=lambda t: -t[1]):
            i = min(range(n_tiles), key=lambda j: acc[j])
            if acc[i] + b > half and b <= half:
                i = next((j for j in range(n_tiles) if acc[j] + b <= half), i)
            per_tile[i].append((name, b))
            acc[i] += b
        for tile in per_tile:
            for name, b in tile:
                placements.append(TensorPlacement(
                    name=name, nbytes=b, layer=layer,
                    buffer=phase % 2, phase=phase))
            phase += 1
    return TensorSchedule(placements=placements, buffer_bytes=buffer_bytes,
                          n_phases=phase)


@dataclasses.dataclass
class PipelineModel:
    """Analytic ping-pong pipeline (paper Fig. 4).

    Per phase: one buffer half is written with the next weight tile
    (t_write = tile_bytes / stream_bw) while the other half feeds compute
    (t_compute).  The pipeline is bubble-free iff t_write <= t_compute; the
    paper finds batch ~= 8 balances the two for its machine.
    """
    stream_bw: float              # bytes/s into the buffer (DRAM->LLC)
    compute_rate: float           # effective bytes/s consumed by compute at B=1

    def phase_time(self, tile_bytes: int, batch: int) -> float:
        t_write = tile_bytes / self.stream_bw
        t_compute = batch * tile_bytes / self.compute_rate
        return max(t_write, t_compute)

    def iteration_time(self, tile_bytes_seq: Iterable[int],
                       batch: int) -> float:
        seq = list(tile_bytes_seq)
        if not seq:
            return 0.0
        # fill: first write is exposed; afterwards phases overlap
        fill = seq[0] / self.stream_bw
        return fill + sum(self.phase_time(b, batch) for b in seq)

    def bubble_free_batch(self, tile_bytes: int) -> int:
        """Smallest batch at which compute fully hides the write stream."""
        b = 1
        while (batch_compute := b * tile_bytes / self.compute_rate) < \
                tile_bytes / self.stream_bw and b < 1024:
            b += 1
        return b

    def optimal_batch(self, tile_bytes: int, max_batch: int = 64) -> int:
        """Batch maximising aggregate throughput = B / phase_time(B).

        Throughput rises until the pipeline balances, then plateaus (the
        paper's Fig. 6 'plateaus beyond about 7...8'); pick the knee."""
        best_b, best_rate = 1, 0.0
        for b in range(1, max_batch + 1):
            rate = b / self.phase_time(tile_bytes, b)
            if rate > best_rate * 1.02:      # 2% hysteresis finds the knee
                best_b, best_rate = b, rate
        return best_b


# ---------------------------------------------------------------------------
# Iteration-level batching (serving-side scheduler)
# ---------------------------------------------------------------------------

# Request lifecycle: WAITING -> PREFILL (slot assigned, prompt being
# processed) -> DECODE (one token per model iteration) -> DONE.
WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"


@dataclasses.dataclass
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: int = 0
    done: bool = False
    state: str = WAITING
    slot: int = -1                # KV-pool row while PREFILL/DECODE


@dataclasses.dataclass
class IterationScheduler:
    """Iteration-based scheduler over a fixed pool of KV-cache slots.

    Each model iteration serves every active user once (paper Sec. III-A:
    'inference serving systems operate on an iteration-based principle
    when serving multiple users'), so each layer's weights are streamed
    once and reused batch-wide.  ``schedule()`` implements the
    iteration-granular (Orca-style) continuous-batching admission the
    engine runs: arrival-order FIFO, one pool slot per admitted request,
    and a Sarathi-style per-iteration cap on newly admitted prefill
    tokens (``prefill_budget``) so a burst of long prompts cannot stall
    the decode cohort.  ``release()`` returns a finished request's slot
    to the free list at iteration granularity — a request arriving
    mid-decode joins the very next iteration instead of waiting for the
    cohort to drain.

    ``admit()``/``step_complete()`` remain as the coarse batch-mode
    interface (run-to-completion serving, kept for A/B comparison).
    """
    target_batch: int = 8
    max_batch: int = 32
    prefill_budget: Optional[int] = None   # new prefill tokens / iteration
    waiting: List[Request] = dataclasses.field(default_factory=list)
    running: List[Request] = dataclasses.field(default_factory=list)
    finished: List[Request] = dataclasses.field(default_factory=list)
    free_slots: List[int] = dataclasses.field(default_factory=list)
    _slots_init: bool = False

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    # --- continuous (slot) interface ------------------------------------

    def _ensure_slots(self) -> None:
        if not self._slots_init:
            self.free_slots = list(range(self.max_batch))
            self._slots_init = True

    def schedule(self, max_active: Optional[int] = None,
                 can_admit=None) -> List[Request]:
        """Admit waiting requests into free slots; return the newly
        admitted ones (state PREFILL, ``slot`` assigned).

        FIFO in arrival order; total prompt tokens admitted per call are
        capped at ``prefill_budget`` (the first admitted request is
        exempt so an over-budget prompt cannot starve).  ``max_active``
        caps total occupancy below the pool size — the SLO controller's
        shrink/shed lever (deferred requests stay queued in FIFO order).

        ``can_admit``: optional callback ``Request -> bool`` consulted
        last, immediately before a request would be admitted — the paged
        engine's block-availability gate (which may allocate blocks as a
        side effect, hence "consulted last": it only fires for requests
        that are otherwise certain to be admitted).  A False answer stops
        admission for this call, preserving FIFO order.
        """
        self._ensure_slots()
        admitted: List[Request] = []
        used = 0
        while self.waiting and self.free_slots:
            if max_active is not None and len(self.running) >= max_active:
                break
            nxt = self.waiting[0]
            if (admitted and self.prefill_budget is not None
                    and used + nxt.prompt_len > self.prefill_budget):
                break
            if can_admit is not None and not can_admit(nxt):
                break
            req = self.waiting.pop(0)
            req.slot = self.free_slots.pop(0)
            req.state = PREFILL
            used += req.prompt_len
            self.running.append(req)
            admitted.append(req)
        return admitted

    def preempt(self, uid: int) -> Request:
        """Evict a running request back to the FRONT of the waiting queue.

        Recompute-style preemption under memory pressure: the slot is
        freed, state returns to WAITING, and the request is requeued ahead
        of everyone else so it is the first to resume once blocks free up.
        The caller (engine) is responsible for releasing its KV blocks and
        adjusting ``prompt_len`` to cover already-committed tokens.
        """
        for r in self.running:
            if r.uid == uid:
                self.running.remove(r)
                if r.slot >= 0:
                    self.free_slots.append(r.slot)
                    self.free_slots.sort()
                    r.slot = -1
                r.state = WAITING
                self.waiting.insert(0, r)
                return r
        raise KeyError(f"uid {uid} not running")

    def release(self, uid: int) -> Request:
        """Retire a finished request; its slot returns to the free pool."""
        for r in self.running:
            if r.uid == uid:
                self.running.remove(r)
                r.done = True
                r.state = DONE
                if r.slot >= 0:
                    self.free_slots.append(r.slot)
                    self.free_slots.sort()
                    r.slot = -1
                self.finished.append(r)
                return r
        raise KeyError(f"uid {uid} not running")

    # --- batch-mode (run-to-completion) interface ------------------------

    def admit(self) -> List[Request]:
        """Fill the running batch up to target from the FIFO queue."""
        while self.waiting and len(self.running) < self.target_batch:
            req = self.waiting.pop(0)
            req.state = DECODE
            self.running.append(req)
        return list(self.running)

    def step_complete(self, finished_uids: Sequence[int]) -> None:
        done = set(finished_uids)
        still = []
        for r in self.running:
            r.generated += 1
            if r.uid in done or r.generated >= r.max_new_tokens:
                r.done = True
                r.state = DONE
                self.finished.append(r)
            else:
                still.append(r)
        self.running = still

    @property
    def active(self) -> int:
        return len(self.running)

    def idle(self) -> bool:
        return not self.waiting and not self.running
