"""Sensitivity-calibrated mixed-precision bit allocation.

SAIL's first stated challenge is that "optimal bit precision varies across
models and layers" (Sec. I); its LUT-GEMV supports arbitrary ``ql`` per
matmul at minimal overhead.  This module turns that capability into a
serving feature:

  * ``output_sensitivity`` — from a small calibration batch, score each
    weight matrix (per layer, per matrix: attn qkv/o vs mlp up/down vs
    lm_head) by the quantization-induced end-to-end output error: quantize
    ONE matrix (or one layer slice of a scan stack) at each candidate
    precision, run the model, and measure the mean squared logit deviation
    against the f32 reference.
  * ``weight_sensitivity`` — the calibration-free proxy (squared weight
    reconstruction error), for when no forward passes are affordable.
  * ``allocate_bits`` — greedy solver for "minimize total predicted error
    subject to a byte budget" over ``SUPPORTED_BITS``, using the exact
    QTensor byte accounting (packed words + group scales + codebook).
  * ``calibrate_policy`` — end-to-end: score, solve, and return a
    ``QuantPolicy`` whose ``allocation`` carries per-path (and per-layer)
    bits; ``quantize_params`` then emits a mixed tree.
  * ``parse_bit_policy`` / ``resolve_bit_policy`` — the serving-facing
    spec surface (``EngineConfig.bit_policy``, ``--bit-policy``):
    ``"uniform:<b>"``, ``"rules:<regex>=<b>,..."``, ``"auto:q<b>"``
    (byte budget matched to uniform b-bit), ``"auto:<f>bpw"``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import SUPPORTED_BITS

# A unit key: (keystr path, layer index or None for non-stacked leaves).
UnitKey = Tuple[str, Optional[int]]


@dataclasses.dataclass(frozen=True)
class Unit:
    """One independently allocatable weight: a 2-D leaf or one layer slice
    of a scan-stacked leaf.  ``copies`` folds extra leading dims (MoE
    experts) into the byte accounting."""
    path: str
    layer: Optional[int]
    k: int
    n: int
    copies: int
    errors: Mapping[int, float]    # bits -> predicted output error

    @property
    def key(self) -> UnitKey:
        return (self.path, self.layer)


@dataclasses.dataclass(frozen=True)
class AllocationReport:
    """Solver diagnostics (the bench's Pareto bookkeeping)."""
    bits_by_unit: Dict[UnitKey, int]
    bytes_total: int
    budget_bytes: int
    predicted_error: float
    feasible: bool                 # min-bits config fit inside the budget


def unit_bytes(k: int, n: int, bits: int, group_size: int,
               copies: int = 1) -> int:
    """QTensor storage bytes for one [K, N] weight (x ``copies``): packed
    words + group scales.  The 2^bits-entry codebook is shared per tensor
    (and tiny), so it is excluded — allocator accounting must price a
    per-layer unit and a whole-leaf unit consistently."""
    from repro.core.cost_model import qtensor_bytes
    return qtensor_bytes(k, n, bits, group_size, copies)


def fake_quant(w: jax.Array, bits: int, group_size: int,
               codebook: Optional[jax.Array] = None) -> jax.Array:
    """Quantize->dequantize roundtrip of ``w[..., K, N]`` (vmapped over
    leading dims) — the error a SAIL-served matmul would see."""
    if w.ndim == 2:
        return quant.dequantize(quant.quantize(w, bits, group_size,
                                               codebook))
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda a: quant.dequantize(
        quant.quantize(a, bits, group_size, codebook)))(flat)
    return out.reshape(lead + out.shape[-2:])


def calibration_tokens(vocab: int, batch: int = 4, seq: int = 32,
                       seed: int = 0) -> jax.Array:
    """Deterministic synthetic calibration batch (matches the synthetic
    data pipeline used everywhere else in this repro)."""
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, seq), 0,
                              vocab)


def quantizable_units(params, policy) -> List[Tuple[str, Any, bool]]:
    """(path, leaf, stacked?) for every leaf ``policy`` would quantize."""
    from repro.models.sail_linear import (_should_quantize,
                                          _should_quantize_stacked)
    out = []
    for path, w in jax.tree_util.tree_flatten_with_path(params)[0]:
        pstr = jax.tree_util.keystr(path)
        if _should_quantize(pstr, w, policy):
            out.append((pstr, w, False))
        elif _should_quantize_stacked(pstr, w, policy):
            out.append((pstr, w, True))
    return out


def uniform_bytes(params, policy, bits: int) -> int:
    """Total QTensor bytes of quantizing every eligible leaf at ``bits``
    (the byte budget 'uniform b-bit' occupies)."""
    total = 0
    for _, w, stacked in quantizable_units(params, policy):
        k, n = w.shape[-2:]
        copies = 1
        for d in w.shape[:-2]:
            copies *= d
        total += unit_bytes(k, n, bits, policy.group_size, copies)
    return total


# ---------------------------------------------------------------------------
# sensitivity scoring
# ---------------------------------------------------------------------------

def weight_sensitivity(params, policy,
                       bits_candidates: Sequence[int] = SUPPORTED_BITS,
                       per_layer: bool = True) -> Dict[UnitKey, Dict[int, float]]:
    """Calibration-free proxy: sum of squared weight reconstruction error
    per unit and candidate precision."""
    scores: Dict[UnitKey, Dict[int, float]] = {}
    for pstr, w, stacked in quantizable_units(params, policy):
        if stacked and per_layer:
            slices = [(layer, w[layer]) for layer in range(w.shape[0])]
        else:
            slices = [(None if not stacked else -1, w)]
        for layer, ws in slices:
            key = (pstr, None) if layer in (None, -1) else (pstr, layer)
            errs = {}
            for b in bits_candidates:
                dq = fake_quant(ws, b, policy.group_size,
                                policy.codebook_for(b))
                errs[b] = float(jnp.sum((dq - ws) ** 2))
            scores[key] = errs
    return scores


def output_sensitivity(params, cfg, tokens, policy,
                       bits_candidates: Sequence[int] = SUPPORTED_BITS,
                       per_layer: bool = True) -> Dict[UnitKey, Dict[int, float]]:
    """Calibrated scores, centered at the uniform-``policy.bits`` model.

    Independent per-matrix probes against the f32 model mispredict the
    fully quantized operating point (quantization errors interact), so
    each score is instead the TRUE end-to-end logit MSE (vs the f32
    reference) of the model with every eligible weight at the uniform
    baseline precision and ONLY the probed unit moved to the candidate
    precision.  An allocation differing from uniform in few units is then
    predicted to second order in the number of moved units.

    The forward is jitted once (probe trees share the structure), so the
    cost is |units| x (|bits_candidates| - 1) reruns of one compiled step.
    """
    from repro.models import lm
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)

    eligible = {pstr: stacked
                for pstr, _, stacked in quantizable_units(params, policy)}
    base_bits = policy.bits
    base_cb = policy.codebook_for(base_bits)
    base_leaves = []
    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        base_leaves.append(fake_quant(w, base_bits, policy.group_size,
                                      base_cb)
                           if pstr in eligible else w)

    def probe(idx: int, new_leaf) -> float:
        swapped = list(base_leaves)
        swapped[idx] = new_leaf
        logits = fwd(jax.tree_util.tree_unflatten(treedef, swapped))
        return float(jnp.mean((logits - ref) ** 2))

    err_base = float(jnp.mean(
        (fwd(jax.tree_util.tree_unflatten(treedef, base_leaves)) - ref)
        ** 2))

    scores: Dict[UnitKey, Dict[int, float]] = {}
    for idx, (path, w) in enumerate(flat):
        pstr = jax.tree_util.keystr(path)
        if pstr not in eligible:
            continue
        stacked = eligible[pstr]
        if stacked and per_layer:
            for layer in range(w.shape[0]):
                errs = {}
                for b in bits_candidates:
                    if b == base_bits:
                        errs[b] = err_base
                        continue
                    dq = fake_quant(w[layer], b, policy.group_size,
                                    policy.codebook_for(b))
                    errs[b] = probe(idx, base_leaves[idx].at[layer].set(dq))
                scores[(pstr, layer)] = errs
        else:
            errs = {}
            for b in bits_candidates:
                if b == base_bits:
                    errs[b] = err_base
                    continue
                dq = fake_quant(w, b, policy.group_size,
                                policy.codebook_for(b))
                errs[b] = probe(idx, dq)
            scores[(pstr, None)] = errs
    return scores


# ---------------------------------------------------------------------------
# greedy budgeted allocation
# ---------------------------------------------------------------------------

def allocate_bits(units: Sequence[Unit], budget_bytes: int,
                  group_size: int,
                  bits_candidates: Sequence[int] = SUPPORTED_BITS,
                  pinned: Optional[Mapping[UnitKey, int]] = None
                  ) -> AllocationReport:
    """Greedy knapsack: start every free unit at the narrowest candidate,
    then repeatedly apply the upgrade with the best error-reduction per
    extra byte that still fits the budget.  Upgrades may jump several
    precisions at once, so locally non-monotone error ladders (a 3-bit
    grid occasionally reconstructs worse than 2-bit) cannot wedge the
    solver."""
    cand = sorted(set(int(b) for b in bits_candidates))
    pinned = dict(pinned or {})
    free = [u for u in units if u.key not in pinned]

    def bytes_at(u: Unit, b: int) -> int:
        return unit_bytes(u.k, u.n, b, group_size, u.copies)

    def climb(start_bits: int):
        """Greedy upgrades from every free unit at ``start_bits``.
        Returns (bits_by_unit, total_bytes, predicted_error) or None if
        the start itself exceeds the budget."""
        current: Dict[UnitKey, int] = {}
        total = 0
        for u in units:
            b = pinned.get(u.key, start_bits)
            current[u.key] = b
            total += bytes_at(u, b)
        if total > budget_bytes:
            return None
        while True:
            best = None  # (ratio, delta_err, key_tiebreak, new_bits)
            for u in free:
                cur = current[u.key]
                err_cur = u.errors[cur]
                for b in cand:
                    if b <= cur:
                        continue
                    db = bytes_at(u, b) - bytes_at(u, cur)
                    if db <= 0 or total + db > budget_bytes:
                        continue
                    de = err_cur - u.errors[b]
                    if de <= 0:
                        continue
                    pick = (de / db, de, u.key, b)
                    if best is None or pick > best:
                        best = pick
            if best is None:
                break
            _, _, key, b = best
            u = next(x for x in free if x.key == key)
            total += bytes_at(u, b) - bytes_at(u, current[key])
            current[key] = b
        total = swap_refine(current, total)
        predicted = sum(u.errors[current[u.key]] for u in units)
        return current, total, predicted

    def swap_refine(current: Dict[UnitKey, int], total: int) -> int:
        """Pairwise trades: downgrade one unit to fund upgrading another.
        A monotone climb cannot cross a tight budget (e.g. start =
        uniform-4 at the uniform-4 budget leaves zero headroom); profitable
        down+up swaps are how mixed precision beats uniform there."""
        while True:
            best = None  # (net_err_delta, key_down, bits_down, key_up, bits_up)
            for ud in free:
                cur_d = current[ud.key]
                for bd in cand:
                    if bd >= cur_d:
                        continue
                    saved = bytes_at(ud, cur_d) - bytes_at(ud, bd)
                    loss = ud.errors[bd] - ud.errors[cur_d]
                    for uu in free:
                        if uu.key == ud.key:
                            continue
                        cur_u = current[uu.key]
                        for bu in cand:
                            if bu <= cur_u:
                                continue
                            cost = bytes_at(uu, bu) - bytes_at(uu, cur_u)
                            if total - saved + cost > budget_bytes:
                                continue
                            net = loss + uu.errors[bu] - uu.errors[cur_u]
                            pick = (net, ud.key, bd, uu.key, bu)
                            if net < 0 and (best is None or pick < best):
                                best = pick
            if best is None:
                return total
            _, kd, bd, ku, bu = best
            ud = next(x for x in free if x.key == kd)
            uu = next(x for x in free if x.key == ku)
            total += (bytes_at(ud, bd) - bytes_at(ud, current[kd])
                      + bytes_at(uu, bu) - bytes_at(uu, current[ku]))
            current[kd] = bd
            current[ku] = bu

    # Multi-start: all-narrowest plus every feasible uniform level — the
    # result is never predicted-worse than the best uniform config the
    # budget admits (greedy alone can wedge when a cheap early upgrade
    # starves a crucial later one).
    solutions = [s for s in (climb(b) for b in cand) if s is not None]
    if not solutions:
        # infeasible even at min bits: report the min-bits config
        current = {u.key: pinned.get(u.key, cand[0]) for u in units}
        total = sum(bytes_at(u, current[u.key]) for u in units)
        predicted = sum(u.errors[current[u.key]] for u in units)
        return AllocationReport(bits_by_unit=current, bytes_total=total,
                                budget_bytes=int(budget_bytes),
                                predicted_error=predicted, feasible=False)
    current, total, predicted = min(solutions, key=lambda s: (s[2], s[1]))
    return AllocationReport(bits_by_unit=current, bytes_total=total,
                            budget_bytes=int(budget_bytes),
                            predicted_error=predicted, feasible=True)


def _allocation_from_units(bits_by_unit: Mapping[UnitKey, int]):
    """{(path, layer): bits} -> BitAllocation (tuples for stacked paths)."""
    from repro.models.sail_linear import BitAllocation
    per_path: Dict[str, Any] = {}
    layered: Dict[str, Dict[int, int]] = {}
    for (path, layer), b in bits_by_unit.items():
        if layer is None:
            per_path[path] = int(b)
        else:
            layered.setdefault(path, {})[layer] = int(b)
    for path, by_layer in layered.items():
        n_layers = max(by_layer) + 1
        if set(by_layer) != set(range(n_layers)):
            raise ValueError(f"allocation for {path} misses layers: "
                             f"{sorted(by_layer)}")
        per_path[path] = tuple(by_layer[i] for i in range(n_layers))
    return BitAllocation(per_path=per_path)


def calibrate_policy(params, cfg, policy=None, budget_bytes=None,
                     match_uniform: Optional[int] = None,
                     budget_bpw: Optional[float] = None,
                     tokens=None, mode: str = "output",
                     bits_candidates: Sequence[int] = SUPPORTED_BITS,
                     per_layer: bool = True, calib_batch: int = 4,
                     calib_seq: int = 32, scores=None):
    """Score sensitivities and solve the budgeted allocation.

    Budget, one of: ``budget_bytes`` (absolute), ``match_uniform=b``
    (bytes of uniform b-bit), ``budget_bpw`` (bits per quantizable
    weight).  Paths matched by ``policy.rules`` are pinned to their rule
    bits and charged against the budget.  Returns ``(policy_with_
    allocation, AllocationReport)``.
    ``scores`` (an ``output_sensitivity``/``weight_sensitivity`` result)
    short-circuits the probing — budget sweeps score once, solve many.
    """
    from repro.models.sail_linear import QuantPolicy
    policy = policy or QuantPolicy()
    if scores is not None:
        pass
    elif mode == "output":
        if tokens is None:
            tokens = calibration_tokens(cfg.vocab, calib_batch, calib_seq)
        scores = output_sensitivity(params, cfg, tokens, policy,
                                    bits_candidates, per_layer)
    elif mode == "weight":
        scores = weight_sensitivity(params, policy, bits_candidates,
                                    per_layer)
    else:
        raise ValueError(f"mode must be 'output' or 'weight', got {mode}")

    units: List[Unit] = []
    pinned: Dict[UnitKey, int] = {}
    total_weights = 0
    for pstr, w, stacked in quantizable_units(params, policy):
        k, n = w.shape[-2:]
        per_slice_copies = 1
        for d in w.shape[1:-2]:
            per_slice_copies *= d
        total_weights += w.size
        keys = ([(pstr, layer) for layer in range(w.shape[0])]
                if stacked and per_layer else [(pstr, None)])
        copies = (per_slice_copies if stacked and per_layer
                  else per_slice_copies * (w.shape[0] if stacked else 1))
        rule_bits = None
        for pat, b in policy.rules:
            if re.search(pat, pstr):
                rule_bits = int(b)
                if rule_bits not in bits_candidates:
                    raise ValueError(
                        f"rule ({pat!r}, {b}) pins {pstr} outside the "
                        f"scored candidates {tuple(bits_candidates)}")
                break
        for key in keys:
            units.append(Unit(path=pstr, layer=key[1], k=k, n=n,
                              copies=copies, errors=scores[key]))
            if rule_bits is not None:
                pinned[key] = rule_bits

    if budget_bytes is None:
        if match_uniform is not None:
            budget_bytes = uniform_bytes(params, policy, match_uniform)
        elif budget_bpw is not None:
            budget_bytes = int(budget_bpw * total_weights / 8)
        else:
            budget_bytes = uniform_bytes(params, policy, policy.bits)
    report = allocate_bits(units, budget_bytes, policy.group_size,
                           bits_candidates, pinned)
    allocation = _allocation_from_units(report.bits_by_unit)
    return dataclasses.replace(policy, allocation=allocation), report


# ---------------------------------------------------------------------------
# serving-facing spec surface
# ---------------------------------------------------------------------------

def parse_bit_policy(spec: str) -> Dict[str, Any]:
    """``--bit-policy`` / ``EngineConfig.bit_policy`` string grammar.

      uniform:<b>                         one precision everywhere
      rules:<regex>=<b>[,<regex>=<b>...]  explicit per-path overrides
      auto:q<b>                           allocate within uniform-b bytes
      auto:<f>bpw                         allocate within f bits/weight
    """
    kind, _, rest = spec.partition(":")
    if kind == "uniform":
        return {"mode": "uniform", "bits": int(rest)}
    if kind == "rules":
        rules = []
        default = None
        for part in filter(None, rest.split(",")):
            pat, _, b = part.rpartition("=")
            if not pat:
                raise ValueError(f"bad rule {part!r} in {spec!r}")
            if pat in ("default", "*"):
                default = int(b)
            else:
                rules.append((pat, int(b)))
        out: Dict[str, Any] = {"mode": "rules", "rules": rules}
        if default is not None:
            out["bits"] = default
        return out
    if kind == "auto":
        rest = rest.strip()
        if rest.startswith("q"):
            return {"mode": "auto", "match_uniform": int(rest[1:])}
        if rest.endswith("bpw"):
            return {"mode": "auto", "budget_bpw": float(rest[:-3])}
        raise ValueError(f"auto budget must be q<b> or <f>bpw, got {rest!r}")
    raise ValueError(f"unknown bit policy {spec!r} "
                     "(expected uniform:/rules:/auto:)")


def resolve_bit_policy(bit_policy, params, cfg, base):
    """EngineConfig.bit_policy (None | str | dict | QuantPolicy) -> the
    QuantPolicy to quantize with.  ``base`` carries the engine's
    group_size/min_size/default bits; auto mode runs the calibration."""
    from repro.models.sail_linear import QuantPolicy
    if bit_policy is None:
        return base
    if isinstance(bit_policy, QuantPolicy):
        return bit_policy
    if isinstance(bit_policy, str):
        bit_policy = parse_bit_policy(bit_policy)
    if not isinstance(bit_policy, Mapping):
        raise TypeError(f"bit_policy must be None/str/dict/QuantPolicy, "
                        f"got {type(bit_policy)!r}")
    spec = dict(bit_policy)
    mode = spec.pop("mode", "spec")
    if mode == "uniform":
        return dataclasses.replace(base, bits=int(spec["bits"]))
    if mode == "rules":
        return dataclasses.replace(
            base, bits=int(spec.get("bits", base.bits)),
            rules=tuple((p, int(b)) for p, b in spec.get("rules", ())))
    if mode == "auto":
        policy, _ = calibrate_policy(params, cfg, base, **spec)
        return policy
    if mode == "spec":
        merged = QuantPolicy.from_spec({
            "bits": base.bits, "group_size": base.group_size,
            "min_size": base.min_size, "skip_embed": base.skip_embed,
            **spec})
        return merged
    raise ValueError(f"unknown bit_policy mode {mode!r}")
