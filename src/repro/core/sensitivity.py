"""Sensitivity-calibrated mixed-precision bit allocation.

SAIL's first stated challenge is that "optimal bit precision varies across
models and layers" (Sec. I); its LUT-GEMV supports arbitrary ``ql`` per
matmul at minimal overhead.  This module turns that capability into a
serving feature:

  * ``output_sensitivity`` — from a small calibration batch, score each
    weight matrix (per layer, per matrix: attn qkv/o vs mlp up/down vs
    lm_head) by the quantization-induced end-to-end output error: quantize
    ONE matrix (or one layer slice of a scan stack) at each candidate
    precision, run the model, and measure the mean squared logit deviation
    against the f32 reference.
  * ``weight_sensitivity`` — the calibration-free proxy (squared weight
    reconstruction error), for when no forward passes are affordable.
  * ``allocate_bits`` — greedy solver for "minimize total predicted error
    subject to a byte budget" over ``SUPPORTED_BITS``, using the exact
    QTensor byte accounting (packed words + group scales + codebook).
  * ``activation_sensitivity`` — the activation-precision twin: probe
    ONE unit's matmul inputs at each candidate ``abits`` (gate-masked
    ``ActQuantWeight`` wrapper, one compiled forward per path) against
    the same exact center.
  * ``kv_sensitivity`` — the KV-cache twin: prefill an f32 cache, then
    per layer quantize->dequantize that layer's cached K/V (the exact
    int8 transform ``quant_kv`` serving applies) and measure one decode
    step's logit MSE vs the f32-cache reference.  ``Planner`` resolves
    ``PlanSpec.kv_bits="auto"`` against the normalized total.
  * ``calibrate_policy`` — end-to-end: score, solve, and return a
    ``QuantPolicy`` whose ``allocation`` carries per-path (and per-layer)
    bits; ``quantize_params`` then emits a mixed tree.  With
    ``abits_candidates`` it allocates ``(wbits, abits)`` JOINTLY under a
    projected-cycles budget (``allocate_bits_joint``), accepts held-out
    ``calib_batches``, and caps scan segmentation via ``max_segments``.
  * ``parse_bit_policy`` / ``resolve_bit_policy`` — DEPRECATED shims
    over ``repro.planning``: the serving-facing surface is now a typed
    ``PlanSpec`` (``EngineConfig.plan``, ``--plan``), and the legacy
    string grammar (``"uniform:<b>[a<ab>]"``, ``"rules:..."``,
    ``"auto:q<b>[a<ab>][,prt=...][,maxseg=<n>][,slo=<tps>]"``,
    ``"auto:<f>bpw"``) enters only via ``PlanSpec.parse``.

Invariants the probes guarantee: every score is measured against an
exact center (f32 reference logits from the SAME jitted forward), probes
are deterministic for a given (params, tokens) — calibration batches are
seeded — and probing never mutates ``params`` (tree surgery happens on
copies of the flattened leaf list).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.quant import SUPPORTED_ABITS, SUPPORTED_BITS

# A unit key: (keystr path, layer index or None for non-stacked leaves).
UnitKey = Tuple[str, Optional[int]]


@dataclasses.dataclass(frozen=True)
class Unit:
    """One independently allocatable weight: a 2-D leaf or one layer slice
    of a scan-stacked leaf.  ``copies`` folds extra leading dims (MoE
    experts) into the byte accounting.  ``aerrors`` (activation-precision
    -> predicted output error, from ``activation_sensitivity``) is only
    present for joint (wbits, abits) allocation."""
    path: str
    layer: Optional[int]
    k: int
    n: int
    copies: int
    errors: Mapping[int, float]    # wbits -> predicted output error
    aerrors: Optional[Mapping[Optional[int], float]] = None

    @property
    def key(self) -> UnitKey:
        return (self.path, self.layer)


@dataclasses.dataclass(frozen=True)
class AllocationReport:
    """Solver diagnostics (the bench's Pareto bookkeeping)."""
    bits_by_unit: Dict[UnitKey, int]
    bytes_total: int
    budget_bytes: int
    predicted_error: float
    feasible: bool                 # min-bits config fit inside the budget


@dataclasses.dataclass(frozen=True)
class JointAllocationReport:
    """Joint (wbits, abits) solver diagnostics."""
    bits_by_unit: Dict[UnitKey, Tuple[int, int]]   # key -> (wbits, abits)
    bytes_total: int
    cycles_total: float
    byte_budget: Optional[int]
    cycle_budget: float
    predicted_error: float
    feasible: bool


def unit_bytes(k: int, n: int, bits: int, group_size: int,
               copies: int = 1) -> int:
    """QTensor storage bytes for one [K, N] weight (x ``copies``): packed
    words + group scales.  The 2^bits-entry codebook is shared per tensor
    (and tiny), so it is excluded — allocator accounting must price a
    per-layer unit and a whole-leaf unit consistently."""
    from repro.core.cost_model import qtensor_bytes
    return qtensor_bytes(k, n, bits, group_size, copies)


def fake_quant(w: jax.Array, bits: int, group_size: int,
               codebook: Optional[jax.Array] = None) -> jax.Array:
    """Quantize->dequantize roundtrip of ``w[..., K, N]`` (vmapped over
    leading dims) — the error a SAIL-served matmul would see."""
    if w.ndim == 2:
        return quant.dequantize(quant.quantize(w, bits, group_size,
                                               codebook))
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda a: quant.dequantize(
        quant.quantize(a, bits, group_size, codebook)))(flat)
    return out.reshape(lead + out.shape[-2:])


def calibration_tokens(vocab: int, batch: int = 4, seq: int = 32,
                       seed: int = 0) -> jax.Array:
    """Deterministic synthetic calibration batch (matches the synthetic
    data pipeline used everywhere else in this repro)."""
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, seq), 0,
                              vocab)


def quantizable_units(params, policy) -> List[Tuple[str, Any, bool]]:
    """(path, leaf, stacked?) for every leaf ``policy`` would quantize."""
    from repro.models.sail_linear import (_should_quantize,
                                          _should_quantize_stacked)
    out = []
    for path, w in jax.tree_util.tree_flatten_with_path(params)[0]:
        pstr = jax.tree_util.keystr(path)
        if _should_quantize(pstr, w, policy):
            out.append((pstr, w, False))
        elif _should_quantize_stacked(pstr, w, policy):
            out.append((pstr, w, True))
    return out


def uniform_bytes(params, policy, bits: int) -> int:
    """Total QTensor bytes of quantizing every eligible leaf at ``bits``
    (the byte budget 'uniform b-bit' occupies)."""
    total = 0
    for _, w, stacked in quantizable_units(params, policy):
        k, n = w.shape[-2:]
        copies = 1
        for d in w.shape[:-2]:
            copies *= d
        total += unit_bytes(k, n, bits, policy.group_size, copies)
    return total


# ---------------------------------------------------------------------------
# sensitivity scoring
# ---------------------------------------------------------------------------

def weight_sensitivity(params, policy,
                       bits_candidates: Sequence[int] = SUPPORTED_BITS,
                       per_layer: bool = True) -> Dict[UnitKey, Dict[int, float]]:
    """Calibration-free proxy: sum of squared weight reconstruction error
    per unit and candidate precision."""
    scores: Dict[UnitKey, Dict[int, float]] = {}
    for pstr, w, stacked in quantizable_units(params, policy):
        if stacked and per_layer:
            slices = [(layer, w[layer]) for layer in range(w.shape[0])]
        else:
            slices = [(None if not stacked else -1, w)]
        for layer, ws in slices:
            key = (pstr, None) if layer in (None, -1) else (pstr, layer)
            errs = {}
            for b in bits_candidates:
                dq = fake_quant(ws, b, policy.group_size,
                                policy.codebook_for(b))
                errs[b] = float(jnp.sum((dq - ws) ** 2))
            scores[key] = errs
    return scores


def output_sensitivity(params, cfg, tokens, policy,
                       bits_candidates: Sequence[int] = SUPPORTED_BITS,
                       per_layer: bool = True) -> Dict[UnitKey, Dict[int, float]]:
    """Calibrated scores, centered at the uniform-``policy.bits`` model.

    Independent per-matrix probes against the f32 model mispredict the
    fully quantized operating point (quantization errors interact), so
    each score is instead the TRUE end-to-end logit MSE (vs the f32
    reference) of the model with every eligible weight at the uniform
    baseline precision and ONLY the probed unit moved to the candidate
    precision.  An allocation differing from uniform in few units is then
    predicted to second order in the number of moved units.

    The forward is jitted once (probe trees share the structure), so the
    cost is |units| x (|bits_candidates| - 1) reruns of one compiled step.
    """
    from repro.models import lm
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)

    eligible = {pstr: stacked
                for pstr, _, stacked in quantizable_units(params, policy)}
    base_bits = policy.bits
    base_cb = policy.codebook_for(base_bits)
    base_leaves = []
    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        base_leaves.append(fake_quant(w, base_bits, policy.group_size,
                                      base_cb)
                           if pstr in eligible else w)

    def probe(idx: int, new_leaf) -> float:
        swapped = list(base_leaves)
        swapped[idx] = new_leaf
        logits = fwd(jax.tree_util.tree_unflatten(treedef, swapped))
        return float(jnp.mean((logits - ref) ** 2))

    err_base = float(jnp.mean(
        (fwd(jax.tree_util.tree_unflatten(treedef, base_leaves)) - ref)
        ** 2))

    scores: Dict[UnitKey, Dict[int, float]] = {}
    for idx, (path, w) in enumerate(flat):
        pstr = jax.tree_util.keystr(path)
        if pstr not in eligible:
            continue
        stacked = eligible[pstr]
        if stacked and per_layer:
            for layer in range(w.shape[0]):
                errs = {}
                for b in bits_candidates:
                    if b == base_bits:
                        errs[b] = err_base
                        continue
                    dq = fake_quant(w[layer], b, policy.group_size,
                                    policy.codebook_for(b))
                    errs[b] = probe(idx, base_leaves[idx].at[layer].set(dq))
                scores[(pstr, layer)] = errs
        else:
            errs = {}
            for b in bits_candidates:
                if b == base_bits:
                    errs[b] = err_base
                    continue
                dq = fake_quant(w, b, policy.group_size,
                                policy.codebook_for(b))
                errs[b] = probe(idx, dq)
            scores[(pstr, None)] = errs
    return scores


def kv_sensitivity(params, cfg, tokens, bits: int = 8) -> Dict[str, Any]:
    """Per-layer decode-logit error from quantizing ONE layer's KV cache.

    The probe mirrors the weight probes' exact-centering: prefill the
    calibration batch with an f32 cache, take one reference decode step,
    then for each layer quantize->dequantize that layer's cached K and V
    (int8 per-head-dim absmax — the exact transform ``quant_kv`` serving
    applies) and re-run the same decode step.  Scores are logit MSE vs
    the reference; ``relative`` normalizes the summed error by the
    reference logit power — the number ``Planner`` compares against
    ``kv_tolerance`` when resolving ``kv_bits="auto"``.

    Attention families only (recurrent state has no KV to quantize).
    """
    from repro.core.quant import dequantize_kv, quantize_kv
    from repro.models import lm
    if bits != 8:
        raise ValueError(f"only int8 KV is served; got bits={bits}")
    if cfg.family == "ssm":
        raise ValueError("kv_sensitivity needs an attention family "
                         f"(family={cfg.family!r} has no KV cache)")
    b, t = tokens.shape
    logits, cache = lm.prefill(params, tokens, cfg, cache_len=t + 1,
                               quant_kv=False)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    ref, _ = lm.decode_step(params, tok, cache, cfg)
    ref = ref.astype(jnp.float32)
    denom = float(jnp.mean(ref ** 2))
    layers = cache["layers"]
    n_layers = int(layers["k"].shape[0])
    per_layer = []
    for i in range(n_layers):
        kd = dequantize_kv(*quantize_kv(layers["k"][i]))
        vd = dequantize_kv(*quantize_kv(layers["v"][i]))
        probed = dict(layers)
        probed["k"] = layers["k"].at[i].set(kd)
        probed["v"] = layers["v"].at[i].set(vd)
        lg, _ = lm.decode_step(params, tok,
                               {"length": cache["length"],
                                "layers": probed}, cfg)
        per_layer.append(float(jnp.mean((lg.astype(jnp.float32) - ref) ** 2)))
    total = float(sum(per_layer))
    return {"bits": int(bits), "per_layer": per_layer, "total": total,
            "relative": total / max(denom, 1e-30)}


def activation_sensitivity(params, cfg, tokens, policy,
                           abits_candidates: Sequence[int] = SUPPORTED_ABITS,
                           per_layer: bool = True
                           ) -> Dict[UnitKey, Dict[Optional[int], float]]:
    """Activation-precision scores, exact-centered like the weight probes.

    Each score is the TRUE end-to-end logit MSE (vs the f32 reference) of
    the model with every eligible weight at the uniform baseline precision
    and ONLY the probed unit's *matmul inputs* quantized to the candidate
    ``abits`` (via the ``ActQuantWeight`` wrapper, whose per-layer gate
    lets one compiled forward probe every layer of a scan stack).  The
    ``None`` entry (f32 activations — the center) is the baseline error
    itself, so a joint allocation moving few units stays second-order
    accurate exactly like the weight side.
    """
    from repro.models import lm
    from repro.models.sail_linear import ActQuantWeight
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    fwd = jax.jit(lambda p: lm.forward(p, tokens, cfg)[0])
    ref = fwd(params)

    eligible = {pstr: stacked
                for pstr, _, stacked in quantizable_units(params, policy)}
    base_bits = policy.bits
    base_cb = policy.codebook_for(base_bits)
    base_leaves = []
    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        base_leaves.append(fake_quant(w, base_bits, policy.group_size,
                                      base_cb)
                           if pstr in eligible else w)

    err_base = float(jnp.mean(
        (fwd(jax.tree_util.tree_unflatten(treedef, base_leaves)) - ref)
        ** 2))

    def probe(idx: int, gate, abits: int) -> float:
        swapped = list(base_leaves)
        swapped[idx] = ActQuantWeight(w=base_leaves[idx],
                                      gate=jnp.asarray(gate, jnp.float32),
                                      abits=int(abits))
        logits = fwd(jax.tree_util.tree_unflatten(treedef, swapped))
        return float(jnp.mean((logits - ref) ** 2))

    scores: Dict[UnitKey, Dict[Optional[int], float]] = {}
    for idx, (path, w) in enumerate(flat):
        pstr = jax.tree_util.keystr(path)
        if pstr not in eligible:
            continue
        stacked = eligible[pstr]
        if stacked and per_layer:
            n_layers = w.shape[0]
            for layer in range(n_layers):
                errs: Dict[Optional[int], float] = {None: err_base}
                gate = np.zeros((n_layers,), np.float32)
                gate[layer] = 1.0
                for ab in abits_candidates:
                    errs[int(ab)] = probe(idx, gate, ab)
                scores[(pstr, layer)] = errs
        else:
            errs = {None: err_base}
            gate = (np.ones((w.shape[0],), np.float32)
                    if stacked else np.float32(1.0))
            for ab in abits_candidates:
                errs[int(ab)] = probe(idx, gate, ab)
            scores[(pstr, None)] = errs
    return scores


# ---------------------------------------------------------------------------
# greedy budgeted allocation
# ---------------------------------------------------------------------------

def allocate_bits(units: Sequence[Unit], budget_bytes: int,
                  group_size: int,
                  bits_candidates: Sequence[int] = SUPPORTED_BITS,
                  pinned: Optional[Mapping[UnitKey, int]] = None
                  ) -> AllocationReport:
    """Greedy knapsack: start every free unit at the narrowest candidate,
    then repeatedly apply the upgrade with the best error-reduction per
    extra byte that still fits the budget.  Upgrades may jump several
    precisions at once, so locally non-monotone error ladders (a 3-bit
    grid occasionally reconstructs worse than 2-bit) cannot wedge the
    solver."""
    cand = sorted(set(int(b) for b in bits_candidates))
    pinned = dict(pinned or {})
    free = [u for u in units if u.key not in pinned]

    def bytes_at(u: Unit, b: int) -> int:
        return unit_bytes(u.k, u.n, b, group_size, u.copies)

    def climb(start_bits: int):
        """Greedy upgrades from every free unit at ``start_bits``.
        Returns (bits_by_unit, total_bytes, predicted_error) or None if
        the start itself exceeds the budget."""
        current: Dict[UnitKey, int] = {}
        total = 0
        for u in units:
            b = pinned.get(u.key, start_bits)
            current[u.key] = b
            total += bytes_at(u, b)
        if total > budget_bytes:
            return None
        while True:
            best = None  # (ratio, delta_err, key_tiebreak, new_bits)
            for u in free:
                cur = current[u.key]
                err_cur = u.errors[cur]
                for b in cand:
                    if b <= cur:
                        continue
                    db = bytes_at(u, b) - bytes_at(u, cur)
                    if db <= 0 or total + db > budget_bytes:
                        continue
                    de = err_cur - u.errors[b]
                    if de <= 0:
                        continue
                    pick = (de / db, de, u.key, b)
                    if best is None or pick > best:
                        best = pick
            if best is None:
                break
            _, _, key, b = best
            u = next(x for x in free if x.key == key)
            total += bytes_at(u, b) - bytes_at(u, current[key])
            current[key] = b
        total = swap_refine(current, total)
        predicted = sum(u.errors[current[u.key]] for u in units)
        return current, total, predicted

    def swap_refine(current: Dict[UnitKey, int], total: int) -> int:
        """Pairwise trades: downgrade one unit to fund upgrading another.
        A monotone climb cannot cross a tight budget (e.g. start =
        uniform-4 at the uniform-4 budget leaves zero headroom); profitable
        down+up swaps are how mixed precision beats uniform there."""
        while True:
            best = None  # (net_err_delta, key_down, bits_down, key_up, bits_up)
            for ud in free:
                cur_d = current[ud.key]
                for bd in cand:
                    if bd >= cur_d:
                        continue
                    saved = bytes_at(ud, cur_d) - bytes_at(ud, bd)
                    loss = ud.errors[bd] - ud.errors[cur_d]
                    for uu in free:
                        if uu.key == ud.key:
                            continue
                        cur_u = current[uu.key]
                        for bu in cand:
                            if bu <= cur_u:
                                continue
                            cost = bytes_at(uu, bu) - bytes_at(uu, cur_u)
                            if total - saved + cost > budget_bytes:
                                continue
                            net = loss + uu.errors[bu] - uu.errors[cur_u]
                            pick = (net, ud.key, bd, uu.key, bu)
                            if net < 0 and (best is None or pick < best):
                                best = pick
            if best is None:
                return total
            _, kd, bd, ku, bu = best
            ud = next(x for x in free if x.key == kd)
            uu = next(x for x in free if x.key == ku)
            total += (bytes_at(ud, bd) - bytes_at(ud, current[kd])
                      + bytes_at(uu, bu) - bytes_at(uu, current[ku]))
            current[kd] = bd
            current[ku] = bu

    # Multi-start: all-narrowest plus every feasible uniform level — the
    # result is never predicted-worse than the best uniform config the
    # budget admits (greedy alone can wedge when a cheap early upgrade
    # starves a crucial later one).
    solutions = [s for s in (climb(b) for b in cand) if s is not None]
    if not solutions:
        # infeasible even at min bits: report the min-bits config
        current = {u.key: pinned.get(u.key, cand[0]) for u in units}
        total = sum(bytes_at(u, current[u.key]) for u in units)
        predicted = sum(u.errors[current[u.key]] for u in units)
        return AllocationReport(bits_by_unit=current, bytes_total=total,
                                budget_bytes=int(budget_bytes),
                                predicted_error=predicted, feasible=False)
    current, total, predicted = min(solutions, key=lambda s: (s[2], s[1]))
    return AllocationReport(bits_by_unit=current, bytes_total=total,
                            budget_bytes=int(budget_bytes),
                            predicted_error=predicted, feasible=True)


def pareto_state_filter(states, err_of, cyc_of, byte_of=None):
    """Drop states strictly dominated in (error, cycles[, bytes]).

    A state another state beats-or-ties on every objective (and beats on
    at least one) can never be part of a better allocation, so the joint
    solver's climb and swap-refinement loops — O(|units|^2 x |states|^2)
    per accepted swap — need not consider it.  Real probe ladders
    saturate (several precisions reach the same error at different
    cost), so the surviving frontier is typically a fraction of the
    product grid; see the scaling regression in tests/test_planning.py.
    """
    scored = [
        (s, err_of(s), cyc_of(s), byte_of(s) if byte_of is not None else 0)
        for s in states
    ]
    kept = []
    for s, e, c, b in scored:
        dominated = False
        for t, e2, c2, b2 in scored:
            if t == s:
                continue
            if e2 <= e and c2 <= c and b2 <= b and (e2 < e or c2 < c or b2 < b):
                dominated = True
                break
        if not dominated:
            kept.append(s)
    return kept


def allocate_bits_joint(units: Sequence[Unit], cycle_budget: float,
                        group_size: int,
                        byte_budget: Optional[int] = None,
                        bits_candidates: Sequence[int] = SUPPORTED_BITS,
                        abits_candidates: Sequence[int] = SUPPORTED_ABITS,
                        pinned: Optional[Mapping[UnitKey, int]] = None,
                        pinned_act: Optional[Mapping[UnitKey, int]] = None,
                        batch: int = 8, threads: int = 16,
                        machine=None, prt="paper", calib=None,
                        prune_states: bool = True
                        ) -> JointAllocationReport:
    """Joint (wbits, abits) allocation under a projected-cycles budget.

    SAIL's lutmm takes BOTH precisions per call, so the allocator searches
    the product grid: minimize total predicted error (weight probe +
    activation probe, both exact-centered) subject to
    ``mixed_decode_cycles <= cycle_budget`` and optionally
    ``bytes <= byte_budget``.  Every unit is priced at its own
    cycle-optimal NBW (``best_nbw_for_unit``) and, under
    ``prt="measured"``, its own simulated PRT hit rate — this is what
    lets the solver trade activation width (pure cycles) against weight
    width (cycles + bytes) where each actually pays.

    Same solver shape as :func:`allocate_bits`: multi-start greedy climbs
    (best error reduction per normalized budget use) followed by pairwise
    down/up swap refinement, so tight budgets where a monotone climb
    cannot move still reach mixed assignments.  ``prune_states`` (on by
    default) restricts every per-unit move list to its (error, cycles[,
    bytes]) Pareto frontier — dominated states cannot improve any
    allocation, and dropping them bounds the swap-refinement candidate
    count at calibration scale (the ROADMAP's joint-solver scaling item).
    ``calib`` may be a per-layer mapping (``ActivationTap.calib()``):
    each unit is then priced with its own layer's measured PRT hit rate.
    """
    from repro.core import cost_model as cm
    from repro.core import pattern as _pattern
    m = machine or cm.SailMachine()
    calib = _pattern.canonical_calib(calib)
    wcand = sorted(set(int(b) for b in bits_candidates))
    acand = sorted(set(int(b) for b in abits_candidates))
    states = [(wb, ab) for wb in wcand for ab in acand]
    pinned = dict(pinned or {})
    pinned_act = dict(pinned_act or {})

    for u in units:
        if u.aerrors is None:
            raise ValueError(f"unit {u.key} has no activation scores "
                             "(aerrors) — run activation_sensitivity")

    bytes_tab: Dict[Tuple[UnitKey, int], int] = {}
    cyc_tab: Dict[Tuple[UnitKey, Tuple[int, int]], float] = {}
    for u in units:
        ucalib = _pattern.calib_for_layer(calib, u.layer)
        for wb in wcand:
            bytes_tab[(u.key, wb)] = unit_bytes(u.k, u.n, wb, group_size,
                                                u.copies)
        for s in states:
            wb, ab = s
            _, cyc = cm._best_nbw_and_cycles(u.k, u.n, wb, ab, batch,
                                             threads, m, prt, ucalib)
            cyc_tab[(u.key, s)] = u.copies * cyc

    def err(u: Unit, s: Tuple[int, int]) -> float:
        return u.errors[s[0]] + u.aerrors[s[1]]

    _states_cache: Dict[UnitKey, list] = {}

    def unit_states(u: Unit):
        got = _states_cache.get(u.key)
        if got is not None:
            return got
        wfix = pinned.get(u.key)
        afix = pinned_act.get(u.key)
        opts = [(wb, ab) for wb, ab in states
                if (wfix is None or wb == wfix)
                and (afix is None or ab == afix)]
        if prune_states and len(opts) > 2:
            opts = pareto_state_filter(
                opts, lambda s: err(u, s), lambda s: cyc_tab[(u.key, s)],
                (lambda s: bytes_tab[(u.key, s[0])])
                if byte_budget is not None else None)
        _states_cache[u.key] = opts
        return opts

    free = [u for u in units
            if len(unit_states(u)) > 1]

    def totals(current):
        by = sum(bytes_tab[(k, s[0])] for k, s in current.items())
        cy = sum(cyc_tab[(k, s)] for k, s in current.items())
        return by, cy

    def fits(by, cy):
        return (cy <= cycle_budget
                and (byte_budget is None or by <= byte_budget))

    def norm_cost(key, s) -> float:
        c = cyc_tab[(key, s)] / max(cycle_budget, 1e-9)
        if byte_budget is not None:
            c += bytes_tab[(key, s[0])] / max(byte_budget, 1)
        return c

    def min_state(u: Unit):
        return min(unit_states(u), key=lambda s: (norm_cost(u.key, s),
                                                  err(u, s)))

    def climb(start: Tuple[int, int]):
        current: Dict[UnitKey, Tuple[int, int]] = {}
        for u in units:
            opts = unit_states(u)
            current[u.key] = start if start in opts else min_state(u)
        by, cy = totals(current)
        if not fits(by, cy):
            return None
        while True:
            best = None  # (ratio, de, key, state)
            for u in free:
                cur = current[u.key]
                e_cur = err(u, cur)
                c_cur = norm_cost(u.key, cur)
                for s in unit_states(u):
                    if s == cur:
                        continue
                    de = e_cur - err(u, s)
                    if de <= 0:
                        continue
                    nby = by + bytes_tab[(u.key, s[0])] - \
                        bytes_tab[(u.key, cur[0])]
                    ncy = cy + cyc_tab[(u.key, s)] - cyc_tab[(u.key, cur)]
                    if not fits(nby, ncy):
                        continue
                    dc = norm_cost(u.key, s) - c_cur
                    ratio = de / dc if dc > 1e-12 else float("inf")
                    pick = (ratio, de, u.key, s)
                    if best is None or pick > best:
                        best = pick
            if best is None:
                break
            _, _, key, s = best
            by += bytes_tab[(key, s[0])] - bytes_tab[(key, current[key][0])]
            cy += cyc_tab[(key, s)] - cyc_tab[(key, current[key])]
            current[key] = s
        by, cy = swap_refine(current, by, cy)
        predicted = sum(err(u, current[u.key]) for u in units)
        return current, by, cy, predicted

    def swap_refine(current, by, cy):
        """Pairwise trades: move one unit to a cheaper state to fund a
        more accurate state elsewhere (e.g. drop one layer's abits to
        afford another layer's extra weight bit at a tight cycle
        budget)."""
        while True:
            best = None  # (net_err_delta, key_d, s_d, key_u, s_u)
            for ud in free:
                cur_d = current[ud.key]
                for sd in unit_states(ud):
                    d_by = bytes_tab[(ud.key, sd[0])] - \
                        bytes_tab[(ud.key, cur_d[0])]
                    d_cy = cyc_tab[(ud.key, sd)] - cyc_tab[(ud.key, cur_d)]
                    if d_cy >= 0 and d_by >= 0:
                        continue   # not a funding move
                    loss = err(ud, sd) - err(ud, cur_d)
                    for uu in free:
                        if uu.key == ud.key:
                            continue
                        cur_u = current[uu.key]
                        for su in unit_states(uu):
                            gain = err(uu, cur_u) - err(uu, su)
                            if gain <= 0:
                                continue
                            nby = by + d_by + \
                                bytes_tab[(uu.key, su[0])] - \
                                bytes_tab[(uu.key, cur_u[0])]
                            ncy = cy + d_cy + \
                                cyc_tab[(uu.key, su)] - \
                                cyc_tab[(uu.key, cur_u)]
                            if not fits(nby, ncy):
                                continue
                            net = loss - gain
                            pick = (net, ud.key, sd, uu.key, su)
                            if net < -1e-15 and (best is None
                                                 or pick < best):
                                best = pick
            if best is None:
                return by, cy
            _, kd, sd, ku, su = best
            by += (bytes_tab[(kd, sd[0])] - bytes_tab[(kd, current[kd][0])]
                   + bytes_tab[(ku, su[0])]
                   - bytes_tab[(ku, current[ku][0])])
            cy += (cyc_tab[(kd, sd)] - cyc_tab[(kd, current[kd])]
                   + cyc_tab[(ku, su)] - cyc_tab[(ku, current[ku])])
            current[kd] = sd
            current[ku] = su

    solutions = [s for s in (climb(st) for st in states) if s is not None]
    if not solutions:
        current = {u.key: min_state(u) for u in units}
        by, cy = totals(current)
        predicted = sum(err(u, current[u.key]) for u in units)
        return JointAllocationReport(
            bits_by_unit=current, bytes_total=by, cycles_total=cy,
            byte_budget=byte_budget, cycle_budget=float(cycle_budget),
            predicted_error=predicted, feasible=False)
    current, by, cy, predicted = min(solutions,
                                     key=lambda s: (s[3], s[2], s[1]))
    return JointAllocationReport(
        bits_by_unit=current, bytes_total=by, cycles_total=cy,
        byte_budget=byte_budget, cycle_budget=float(cycle_budget),
        predicted_error=predicted, feasible=True)


def _spec_map_from_units(assign: Mapping[UnitKey, int]) -> Dict[str, Any]:
    """{(path, layer): bits} -> {path: bits | per-layer tuple}."""
    per_path: Dict[str, Any] = {}
    layered: Dict[str, Dict[int, int]] = {}
    for (path, layer), b in assign.items():
        if layer is None:
            per_path[path] = int(b)
        else:
            layered.setdefault(path, {})[layer] = int(b)
    for path, by_layer in layered.items():
        n_layers = max(by_layer) + 1
        if set(by_layer) != set(range(n_layers)):
            raise ValueError(f"allocation for {path} misses layers: "
                             f"{sorted(by_layer)}")
        per_path[path] = tuple(by_layer[i] for i in range(n_layers))
    return per_path


def _allocation_from_units(bits_by_unit: Mapping[UnitKey, Any]):
    """Unit assignment -> BitAllocation.

    Values are scalar wbits (weight-only solve) or (wbits, abits) pairs
    (joint solve, which also fills ``act_per_path``)."""
    from repro.models.sail_linear import BitAllocation
    joint = any(isinstance(b, (tuple, list))
                for b in bits_by_unit.values())
    if not joint:
        return BitAllocation(per_path=_spec_map_from_units(bits_by_unit))
    return BitAllocation(
        per_path=_spec_map_from_units(
            {k: s[0] for k, s in bits_by_unit.items()}),
        act_per_path=_spec_map_from_units(
            {k: s[1] for k, s in bits_by_unit.items()}))


def _segment_cuts(assign: Mapping[UnitKey, Any], paths, n_layers
                  ) -> List[int]:
    """Layer cut points of an assignment: a cut wherever ANY stacked
    path's state differs between adjacent layers (the same rule
    ``sail_linear._segment_bounds`` applies to the emitted policy, so
    the allocator's cap and the actual scan segmentation agree).
    Equal-adjacent layers never produce a cut — the lossless merge."""
    cuts = [0]
    for layer in range(1, n_layers):
        if any(assign.get((p, layer)) != assign.get((p, layer - 1))
               for p in paths):
            cuts.append(layer)
    cuts.append(n_layers)
    return cuts


def segment_count(assign: Mapping[UnitKey, Any]) -> int:
    """Number of uniform-precision scan segments an assignment implies.

    Adjacent layers whose joint assignment matches across every stacked
    path share a segment; non-stacked units don't segment anything."""
    layers = sorted({k[1] for k in assign if k[1] is not None})
    if not layers:
        return 1
    paths = sorted({k[0] for k in assign if k[1] is not None})
    return len(_segment_cuts(assign, paths, max(layers) + 1)) - 1


def enforce_max_segments(units: Sequence[Unit],
                         assign: Dict[UnitKey, Any],
                         max_segments: int,
                         err_of=None,
                         bytes_of=None) -> Dict[UnitKey, Any]:
    """Cap the number of scan segments by merging adjacent segments.

    Each uniform-bits segment compiles its own scan body, so an
    unconstrained per-layer allocation can multiply trace/compile cost.
    While over the cap, the adjacent segment pair whose merge costs the
    least predicted error is coalesced: per stacked path the merged range
    adopts whichever side's assignment raises the summed unit error
    least.  Adjacent segments that already agree merge for free (the
    lossless case); equal-adjacent layers never count as separate
    segments in the first place (see :func:`segment_count`).

    With a ``bytes_of(unit, state)`` hook, a direction that grows the
    byte footprint is taken only when no byte-neutral direction exists:
    merging must spend error, not the byte budget the assignment was
    solved under (one side of every disagreeing pair adopts the
    narrower state, so a non-growing direction always exists for
    weight bits).  Joint (wbits, abits) merges can still leave the
    *cycle* budget — ``calibrate_policy`` re-derives the report's
    ``feasible`` flag after capping for exactly this reason.
    """
    if max_segments < 1:
        raise ValueError(f"max_segments must be >= 1, got {max_segments}")
    if err_of is None:
        def err_of(u, s):
            if isinstance(s, (tuple, list)):
                return u.errors[s[0]] + u.aerrors[s[1]]
            return u.errors[s]
    assign = dict(assign)
    by_key = {u.key: u for u in units}
    paths = sorted({k[0] for k in assign if k[1] is not None})
    layers = sorted({k[1] for k in assign if k[1] is not None})
    if not layers:
        return assign
    n_layers = max(layers) + 1

    while True:
        cuts = _segment_cuts(assign, paths, n_layers)
        if len(cuts) - 1 <= max_segments:
            return assign
        best = None   # (err_delta, cut_index, {(path, layer): state})
        for i in range(1, len(cuts) - 1):
            a, b, c = cuts[i - 1], cuts[i], cuts[i + 1]
            delta = 0.0
            moves: Dict[UnitKey, Any] = {}
            for p in paths:
                lv, rv = assign[(p, a)], assign[(p, b)]
                if lv == rv:
                    continue
                # adopt the left value over [b, c) or the right over [a, b)
                d_left = sum(err_of(by_key[(p, layer)], lv)
                             - err_of(by_key[(p, layer)],
                                      assign[(p, layer)])
                             for layer in range(b, c))
                d_right = sum(err_of(by_key[(p, layer)], rv)
                              - err_of(by_key[(p, layer)],
                                       assign[(p, layer)])
                              for layer in range(a, b))
                take_left = d_left <= d_right
                if bytes_of is not None:
                    b_left = sum(bytes_of(by_key[(p, layer)], lv)
                                 - bytes_of(by_key[(p, layer)],
                                            assign[(p, layer)])
                                 for layer in range(b, c))
                    b_right = sum(bytes_of(by_key[(p, layer)], rv)
                                  - bytes_of(by_key[(p, layer)],
                                             assign[(p, layer)])
                                  for layer in range(a, b))
                    if b_left > 0 and b_right <= 0:
                        take_left = False
                    elif b_right > 0 and b_left <= 0:
                        take_left = True
                if take_left:
                    delta += d_left
                    for layer in range(b, c):
                        moves[(p, layer)] = lv
                else:
                    delta += d_right
                    for layer in range(a, b):
                        moves[(p, layer)] = rv
            if best is None or (delta, i) < best[:2]:
                best = (delta, i, moves)
        assign.update(best[2])


def _tokens_from_calib_batches(calib_batches) -> jax.Array:
    """Held-out token batches -> one [B, T] calibration array.

    Accepts a single [B, T] array or a sequence of [b_i, T] arrays (e.g.
    batches drawn from an eval data pipeline), concatenated along batch.
    """
    if isinstance(calib_batches, (list, tuple)):
        arrs = [np.asarray(b) for b in calib_batches]
        widths = {a.shape[-1] for a in arrs}
        if len(widths) != 1:
            raise ValueError(
                f"calib_batches have mixed sequence lengths {widths}")
        arr = np.concatenate([a.reshape(-1, a.shape[-1]) for a in arrs], 0)
    else:
        arr = np.asarray(calib_batches)
        if arr.ndim == 1:
            arr = arr[None]
    return jnp.asarray(arr, jnp.int32)


def calibrate_policy(params, cfg, policy=None, budget_bytes=None,
                     match_uniform: Optional[int] = None,
                     budget_bpw: Optional[float] = None,
                     tokens=None, mode: str = "output",
                     bits_candidates: Sequence[int] = SUPPORTED_BITS,
                     per_layer: bool = True, calib_batch: int = 4,
                     calib_seq: int = 32, scores=None,
                     calib_batches=None,
                     abits_candidates: Optional[Sequence[int]] = None,
                     act_scores=None, cycle_budget: Optional[float] = None,
                     match_uniform_abits: int = 8,
                     prt="paper", prt_calib=None, cost_batch: int = 8,
                     cost_threads: int = 16, machine=None,
                     max_segments: Optional[int] = None):
    """Score sensitivities and solve the budgeted allocation.

    Weight-only (default): minimize total predicted error subject to
    ``bytes <= budget``, where the budget is one of ``budget_bytes``
    (absolute), ``match_uniform=b`` (bytes of uniform b-bit),
    ``budget_bpw`` (bits per quantizable weight).

    Joint mode (``abits_candidates`` given): additionally allocate the
    activation precision per unit under a projected-cycles budget —
    ``cycle_budget`` (absolute C-SRAM cycles per decode iteration), or by
    default the projected cycles of the uniform reference
    ``(match_uniform or policy.bits, match_uniform_abits)`` — the joint
    answer then Pareto-improves the weight-only one at equal projected
    speed.  ``prt`` selects the pattern-discount model ("paper" flat
    13.8% or "measured" per-precision hit rates); the byte budget is only
    enforced in joint mode when ``budget_bytes`` is explicit (cycles are
    what bound decode speed; weight bytes only bound DRAM residency).

    Calibration data: ``tokens`` (explicit array), or ``calib_batches``
    (held-out token batches from a real eval pipeline — single [B, T]
    array or list of same-T arrays), else the synthetic default.  Under
    ``prt="measured"`` the PRT hit rates are simulated on ``prt_calib``
    (f32 [B, K] activations) — when omitted, the calibration tokens'
    embedding vectors stand in for real hidden activations (capped at
    ``cost_batch`` rows), falling back to the synthetic normal batch.

    Paths matched by ``policy.rules`` / ``policy.act_rules`` are pinned
    to their rule bits and charged against the budgets.  ``scores`` /
    ``act_scores`` short-circuit the probing — budget sweeps score once,
    solve many.  ``max_segments`` caps the scan-segment count of the
    resulting per-layer allocation (merging adjacent segments at least
    predicted-error cost; see :func:`enforce_max_segments`).

    Returns ``(policy_with_allocation, AllocationReport |
    JointAllocationReport)``.
    """
    from repro.models.sail_linear import QuantPolicy
    policy = policy or QuantPolicy()
    joint = abits_candidates is not None
    if not joint and prt not in ("paper", True):
        raise ValueError(
            f"prt={prt!r} only affects the joint (wbits, abits) cycle "
            "budget — a weight-only allocation is priced in bytes, so "
            "the option would be silently ignored; add a<ab> to the "
            "spec (abits_candidates=) to enable joint mode")
    if calib_batches is not None and tokens is None:
        tokens = _tokens_from_calib_batches(calib_batches)
    if scores is not None:
        pass
    elif mode == "output":
        if tokens is None:
            tokens = calibration_tokens(cfg.vocab, calib_batch, calib_seq)
        scores = output_sensitivity(params, cfg, tokens, policy,
                                    bits_candidates, per_layer)
    elif mode == "weight":
        if joint:
            raise ValueError(
                "joint (wbits, abits) allocation requires mode='output': "
                "weight_sensitivity scores are weight-space SSE while "
                "activation probes are logit MSE — summing them would let "
                "the larger scale silently dominate the trade-off")
        scores = weight_sensitivity(params, policy, bits_candidates,
                                    per_layer)
    else:
        raise ValueError(f"mode must be 'output' or 'weight', got {mode}")
    if joint and act_scores is None:
        if tokens is None:
            tokens = calibration_tokens(cfg.vocab, calib_batch, calib_seq)
        act_scores = activation_sensitivity(params, cfg, tokens, policy,
                                            abits_candidates, per_layer)

    units: List[Unit] = []
    pinned: Dict[UnitKey, int] = {}
    pinned_act: Dict[UnitKey, int] = {}
    total_weights = 0
    for pstr, w, stacked in quantizable_units(params, policy):
        k, n = w.shape[-2:]
        per_slice_copies = 1
        for d in w.shape[1:-2]:
            per_slice_copies *= d
        total_weights += w.size
        keys = ([(pstr, layer) for layer in range(w.shape[0])]
                if stacked and per_layer else [(pstr, None)])
        copies = (per_slice_copies if stacked and per_layer
                  else per_slice_copies * (w.shape[0] if stacked else 1))
        rule_bits = None
        for pat, b in policy.rules:
            if re.search(pat, pstr):
                rule_bits = int(b)
                if rule_bits not in bits_candidates:
                    raise ValueError(
                        f"rule ({pat!r}, {b}) pins {pstr} outside the "
                        f"scored candidates {tuple(bits_candidates)}")
                break
        act_rule_bits = None
        if joint:
            for pat, b in policy.act_rules:
                if re.search(pat, pstr):
                    act_rule_bits = int(b)
                    if act_rule_bits not in abits_candidates:
                        raise ValueError(
                            f"act rule ({pat!r}, {b}) pins {pstr} outside "
                            f"the scored candidates "
                            f"{tuple(abits_candidates)}")
                    break
        for key in keys:
            units.append(Unit(path=pstr, layer=key[1], k=k, n=n,
                              copies=copies, errors=scores[key],
                              aerrors=(act_scores[key] if joint
                                       else None)))
            if rule_bits is not None:
                pinned[key] = rule_bits
            if act_rule_bits is not None:
                pinned_act[key] = act_rule_bits

    # a bpw request is an explicit byte budget too — joint mode must not
    # silently drop it just because it arrives in different units
    explicit_bytes = budget_bytes is not None or budget_bpw is not None
    if budget_bytes is None:
        if match_uniform is not None:
            budget_bytes = uniform_bytes(params, policy, match_uniform)
        elif budget_bpw is not None:
            budget_bytes = int(budget_bpw * total_weights / 8)
        else:
            budget_bytes = uniform_bytes(params, policy, policy.bits)

    if joint:
        from repro.core import cost_model as cm
        if prt == "measured" and prt_calib is None and tokens is not None \
                and isinstance(params, dict) and "embed" in params:
            # real-data stand-in for hidden activations: the calibration
            # tokens' embedding vectors (one PRT compute-batch worth)
            emb = np.asarray(jnp.take(params["embed"],
                                      jnp.asarray(tokens), axis=0),
                             np.float32)
            prt_calib = emb.reshape(-1, emb.shape[-1])[:cost_batch]
        if cycle_budget is None:
            ref_wb = match_uniform if match_uniform is not None \
                else policy.bits
            cycle_budget = cm.mixed_decode_cycles(
                [(u.k, u.n, ref_wb, match_uniform_abits, u.copies)
                 for u in units],
                machine=machine or cm.SailMachine(), batch=cost_batch,
                nbw="auto", threads=cost_threads, prt=prt,
                calib=prt_calib)
        report = allocate_bits_joint(
            units, cycle_budget, policy.group_size,
            byte_budget=budget_bytes if explicit_bytes else None,
            bits_candidates=bits_candidates,
            abits_candidates=abits_candidates,
            pinned=pinned, pinned_act=pinned_act, batch=cost_batch,
            threads=cost_threads, machine=machine, prt=prt,
            calib=prt_calib)
    else:
        report = allocate_bits(units, budget_bytes, policy.group_size,
                               bits_candidates, pinned)
    assign = dict(report.bits_by_unit)
    if max_segments is not None:
        def seg_bytes(u, s):
            return unit_bytes(u.k, u.n, s[0] if joint else s,
                              policy.group_size, u.copies)

        capped = enforce_max_segments(units, assign, max_segments,
                                      bytes_of=seg_bytes)
        if capped != assign:
            assign = capped
            nbytes = sum(unit_bytes(
                u.k, u.n,
                assign[u.key][0] if joint else assign[u.key],
                policy.group_size, u.copies) for u in units)
            err = sum(
                (u.errors[assign[u.key][0]] + u.aerrors[assign[u.key][1]])
                if joint else u.errors[assign[u.key]]
                for u in units)
            # merging adopts a neighbor's (wider or narrower) state, so
            # the capped assignment can leave the budgets — re-derive
            # feasible so callers are never told a violating allocation
            # fits
            if joint:
                cycles = cm.mixed_decode_cycles(
                    [(u.k, u.n, assign[u.key][0], assign[u.key][1],
                      u.copies) for u in units],
                    machine=machine or cm.SailMachine(), batch=cost_batch,
                    nbw="auto", threads=cost_threads, prt=prt,
                    calib=prt_calib)
                ok = (cycles <= report.cycle_budget * (1 + 1e-9)
                      and (report.byte_budget is None
                           or nbytes <= report.byte_budget))
                report = dataclasses.replace(
                    report, bits_by_unit=assign, bytes_total=nbytes,
                    cycles_total=cycles, predicted_error=err,
                    feasible=report.feasible and ok)
            else:
                report = dataclasses.replace(
                    report, bits_by_unit=assign, bytes_total=nbytes,
                    predicted_error=err,
                    feasible=(report.feasible
                              and nbytes <= report.budget_bytes))
    allocation = _allocation_from_units(assign)
    return dataclasses.replace(policy, allocation=allocation), report


# ---------------------------------------------------------------------------
# serving-facing spec surface (deprecated shims over repro.planning)
# ---------------------------------------------------------------------------

# public alias: the planner emits solved PlanSpecs from solver reports
spec_map_from_units = _spec_map_from_units


def parse_bit_policy(spec: str) -> Dict[str, Any]:
    """DEPRECATED: use ``repro.planning.PlanSpec.parse``.

    Kept as a thin shim for callers of the legacy string grammar
    (``uniform:<b>[a<ab>]``, ``rules:<regex>=<b>[a<ab>],...``,
    ``auto:q<b>[a<ab>][,prt=...][,maxseg=...]``, ``auto:<f>bpw``) —
    parsing now happens in ``PlanSpec.parse`` and this function merely
    re-emits its legacy dict form.
    """
    import warnings

    from repro.planning import PlanSpec
    warnings.warn(
        "parse_bit_policy is deprecated; use repro.planning."
        "PlanSpec.parse (the dict form it returns is the legacy "
        "EngineConfig.bit_policy surface)", DeprecationWarning,
        stacklevel=2)
    return PlanSpec.parse(spec).to_legacy_dict()


def resolve_bit_policy(bit_policy, params, cfg, base):
    """DEPRECATED: use ``repro.planning.resolve_plan``.

    EngineConfig.bit_policy (None | str | dict | QuantPolicy) -> the
    QuantPolicy to quantize with.  ``base`` carries the engine's
    group_size/min_size/default bits; auto mode runs the calibration.
    Strings and legacy mode-dicts route through ``PlanSpec``; explicit
    QuantPolicy objects and raw ``QuantPolicy.from_spec`` dicts resolve
    as before.
    """
    import warnings

    warnings.warn(
        "resolve_bit_policy is deprecated; use repro.planning."
        "resolve_plan (EngineConfig.plan)", DeprecationWarning,
        stacklevel=2)
    return _resolve_policy_like(bit_policy, params, cfg, base)


def _resolve_policy_like(bit_policy, params, cfg, base):
    """Shared resolution for the legacy ``bit_policy`` surface (no
    deprecation warning — ``Engine`` calls this for compat configs after
    warning once itself)."""
    from repro import planning
    from repro.models.sail_linear import QuantPolicy
    if bit_policy is None:
        return base
    if isinstance(bit_policy, QuantPolicy):
        return bit_policy
    if isinstance(bit_policy, str):
        return planning.resolve_plan(
            planning.PlanSpec.parse(bit_policy), params, cfg,
            base=base).policy
    if not isinstance(bit_policy, Mapping):
        raise TypeError(f"bit_policy must be None/str/dict/QuantPolicy, "
                        f"got {type(bit_policy)!r}")
    mode = bit_policy.get("mode", "spec")
    if mode in ("uniform", "rules", "auto"):
        try:
            plan = planning.PlanSpec.from_legacy_dict(bit_policy)
        except ValueError:
            if mode != "auto":
                raise
            # full backward compat: auto dicts could carry arbitrary
            # calibrate_policy kwargs (calib_batch, budget_bytes, ...)
            # that have no PlanSpec field — forward them like the old
            # resolve_bit_policy did
            spec = dict(bit_policy)
            spec.pop("mode")
            abits = spec.pop("abits", None)
            if abits is not None:
                spec.setdefault("abits_candidates", SUPPORTED_ABITS)
                spec.setdefault("match_uniform_abits", int(abits))
            policy, _ = calibrate_policy(params, cfg, base, **spec)
            return policy
        return planning.resolve_plan(plan, params, cfg, base=base).policy
    if mode == "spec":
        spec = {k: v for k, v in bit_policy.items() if k != "mode"}
        return QuantPolicy.from_spec({
            "bits": base.bits, "group_size": base.group_size,
            "min_size": base.min_size, "skip_embed": base.skip_embed,
            **spec})
    raise ValueError(f"unknown bit_policy mode {mode!r}")
