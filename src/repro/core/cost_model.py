"""Analytic SAIL machine model (paper Secs. III-C, IV, V).

The paper evaluates SAIL with gem5 plus an NDP model whose cycle counts for
LUT-GEMV / batched inference / in-memory type conversion are "characterized
... and hardcoded into the NDP model" (Sec. V-A).  This module is that
characterization, reconstructed from the published microarchitecture:

  * C-SRAM array: 256 x 512 bits @ 3 GHz; n-bit add = n+1 cycles,
    n-bit multiply = n^2 + 5n - 2 cycles (Sec. IV-B(d));
  * type conversion: 3n^2/2 + 39(n-1) cycles (Sec. III-E);
  * 2 C-SRAM arrays per thread (32 KB / thread, Sec. V-I), up to 16 threads
    = 32 arrays (matching the 32 NDPs of Sec. V-A);
  * 8-channel DDR4-3200 DRAM = 204.8 GB/s; 32 MB / 32-slice LLC; NoC
    32 B/cycle @ 2 GHz (Table I);
  * ping-pong LLC halves overlap DRAM->LLC transfer with C-SRAM compute
    (Sec. III-A), so a decode iteration costs max(t_dram, t_compute) plus
    the un-overlapped de-/quant tail;
  * the PRT discount (Sec. III-D) scales lookup cycles by the measured
    pattern hit rate (13.8% at the paper's 17% repeat rate).

Three efficiency constants that gem5 would capture microarchitecturally
(DFM streaming efficiency, LUT-rebuild dataflow overhead, CPU-side GEMV
efficiency of the baselines) are calibrated against the paper's published
anchors (Fig. 6 cycle counts, Table II throughput) — see ``calibrate`` and
EXPERIMENTS.md for the fit quality.  Everything else is first-principles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import typeconv
from repro.core.pattern import PAPER_CYCLE_REDUCTION


# ---------------------------------------------------------------------------
# Machine description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SailMachine:
    freq_hz: float = 3.0e9                 # C-SRAM runs at system clock
    arrays_per_thread: int = 2             # 2 x (256x512) per thread
    array_rows: int = 256
    array_cols: int = 512                  # bitline lanes (N-parallelism)
    dram_bw: float = 204.8e9               # 8ch DDR4-3200
    llc_bytes: int = 32 * 2**20
    llc_slices: int = 32
    noc_bytes_per_cycle: float = 32.0
    noc_freq_hz: float = 2.0e9
    # calibrated dataflow constants (fit by repro.core.calibrate against the
    # paper's Fig. 6 anchors + Table II SAIL columns; see EXPERIMENTS.md):
    lookup_base_cycles: float = 30.7125    # DFM broadcast+row select+SA read
    lookup_per_bit_cycles: float = 5.94    # accumulate slope per weight bit
    rebuild_ctrl_cycles: float = 9900.0    # per-group residency swap / ctrl
    rebuild_nbw_exp: float = 4.4           # dataflow penalty ~ (2/nbw)^exp
    build_overhead: float = 1.0            # fitted multiplier on adds+load
    thread_scale_tau: float = 0.0          # SAIL multi-thread contention
    dram_efficiency: float = 0.92          # achieved fraction of peak BW

    def add_cycles(self, n: int) -> int:
        return n + 1

    def mult_cycles(self, n: int) -> int:
        return n * n + 5 * n - 2


@dataclasses.dataclass(frozen=True)
class CpuMachine:
    """ARM Neoverse-N1-like baseline (Table I)."""
    freq_hz: float = 3.0e9
    simd_bits: int = 128                   # NEON
    fma_per_cycle: int = 2                 # 2 FP/SIMD pipes
    dram_bw: float = 204.8e9
    # calibrated:
    dequant_ops_per_weight: float = 4.0    # unpack+sub+mul+fma at sub-8-bit
    mem_efficiency: float = 0.55           # achieved stream BW fraction
    thread_scale_tau: float = 0.045        # contention: eff = 1/(1+tau*(T-1))


# bits-per-weight including group scale overhead (llama.cpp-style Q*_0/K
# formats: b bits + fp16 scale per 32-group; Q3/Q5/Q6 carry extra metadata)
BPW: Dict[int, float] = {2: 2.63, 3: 3.44, 4: 4.50, 5: 5.50, 6: 6.56, 8: 8.50}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    params: float                          # weight count
    d_model: int
    n_layers: int
    ffn_dim: int

    @property
    def gemv_macs_per_token(self) -> float:
        # dense decode: ~2 * params MAC -> params multiply-accumulates
        return self.params


LLAMA2_7B = ModelSpec("llama-2-7b", 6.74e9, 4096, 32, 11008)
LLAMA2_13B = ModelSpec("llama-2-13b", 13.0e9, 5120, 40, 13824)
TINYMISTRAL = ModelSpec("tinymistral-248m", 2.48e8, 1024, 12, 4096)


# ---------------------------------------------------------------------------
# LUT-GEMV cycle model (Fig. 6 reproduction)
# ---------------------------------------------------------------------------

def lut_build_cycles(m: SailMachine, nbw: int, wbits: int) -> float:
    """Cycles to build one group's LUT inside a C-SRAM array.

    2^nbw - nbw - 1 incremental subset-sum adds of (wbits + ceil(log2 nbw))
    wide entries, plus loading/transposing the nbw weight rows, plus the
    calibrated per-group residency/control overhead which the paper's Fig. 6
    attributes to "LUT rebuild" (dominant at small NBW).
    """
    entry_bits = wbits + max(1, math.ceil(math.log2(max(nbw, 2))))
    n_adds = max((1 << nbw) - nbw - 1, 0)
    adds = n_adds * m.add_cycles(entry_bits)
    load = nbw * 2.0  # stream nbw rows through the transposer (512b/row)
    ctrl = m.rebuild_ctrl_cycles * (2.0 / nbw) ** m.rebuild_nbw_exp
    return (adds + load) * m.build_overhead + ctrl


def lookup_cycles(m: SailMachine, wbits: int, kernel_level: bool = False) -> float:
    """One DFM pattern broadcast + LUT row read + shift-add accumulate.

    ``kernel_level=True`` prices the raw in-array operation (SA read + 16-bit
    accumulate), used for kernel-scope comparisons (Fig. 1 / Fig. 12).  The
    default system-level constants are calibrated against Table II / Fig. 6
    and additionally absorb DFM/NoC orchestration, the way the paper's gem5
    NDP characterization does.
    """
    if kernel_level:
        return 2.0 + 17.0 + 0.5 * wbits   # read + adder tree + shift slope
    return m.lookup_base_cycles + m.lookup_per_bit_cycles * wbits


def lut_gemv_cycles(m: SailMachine, batch: int, k: int, n: int, nbw: int,
                    wbits: int, abits: int = 8, threads: int = 1,
                    prt_discount: float = 1.0,
                    kernel_level: bool = False) -> float:
    """Total C-SRAM cycles of a batched [B,K]x[K,N] LUT-GEMV on `threads`
    threads (2 arrays each, 512 N-lanes per array).

    Per N-tile of 512 columns, per K-group of nbw rows: build the LUT once,
    then stream B*abits pattern lookups through it (reused across the whole
    batch and all bit-planes — the paper's central data-reuse claim).
    """
    arrays = threads * m.arrays_per_thread
    eff = 1.0 / (1.0 + m.thread_scale_tau * (threads - 1))
    n_tiles = math.ceil(n / m.array_cols)
    groups = k / nbw
    per_group = (lut_build_cycles(m, nbw, wbits)
                 + batch * abits * lookup_cycles(m, wbits, kernel_level)
                 * prt_discount)
    total_tile_cycles = n_tiles * groups * per_group
    return total_tile_cycles / (arrays * eff)


def lut_build_fraction(m: SailMachine, batch: int, nbw: int, wbits: int,
                       abits: int = 8, kernel_level: bool = False) -> float:
    """Fraction of GEMV cycles spent constructing LUTs (paper: 3%..12%).

    ``kernel_level`` selects the same lookup pricing ``lut_gemv_cycles``
    uses, so the fraction is consistent with the cycle total it describes
    (kernel-level lookups are cheaper, so the build fraction is larger).
    """
    b = lut_build_cycles(m, nbw, wbits)
    l = batch * abits * lookup_cycles(m, wbits, kernel_level)
    return b / (b + l)


def bitserial_gemv_cycles(m: SailMachine, batch: int, k: int, n: int,
                          wbits: int, abits: int = 8,
                          threads: int = 1) -> float:
    """Neural-Cache-style bit-serial GEMV (no LUTs): every MAC is an
    in-SRAM bit-serial multiply + accumulate (Sec. V-A 'Neural Cache')."""
    arrays = threads * m.arrays_per_thread
    n_tiles = math.ceil(n / m.array_cols)
    per_mac = m.mult_cycles(max(wbits, abits)) + m.add_cycles(24)
    return n_tiles * k * batch * per_mac / arrays


# ---------------------------------------------------------------------------
# End-to-end decode throughput (Table II / III reproduction)
# ---------------------------------------------------------------------------

def model_weight_bytes(model: ModelSpec, ql: int) -> float:
    return model.params * BPW[ql] / 8.0


def qtensor_bytes(k: int, n: int, bits: int, group_size: int = 128,
                  copies: int = 1) -> int:
    """Exact bytes of one SAIL-quantized [K, N] weight in the repo's
    QTensor storage: bit-contiguous packed uint32 words + f32 group scales
    (``copies`` folds stacked layers / MoE experts).  This is the byte
    accounting the mixed-precision allocator budgets against — strictly
    monotone in ``bits`` for every group size >= 32 (matches
    ``quant.words_per_group``)."""
    wpg = -(-(bits * group_size) // 32)          # ceil: words per group
    groups = k // group_size
    return copies * (groups * wpg * n * 4 + groups * n * 4)


def resolve_prt_discount(prt, nbw: int, wbits: int, abits: int,
                         calib=None,
                         machine: SailMachine = SailMachine()) -> float:
    """Resolve the ``prt=`` switch into a lookup-cycle discount factor.

      False/None   no PRT (factor 1.0)
      True/"paper" the paper's flat 13.8% reduction
      "measured"   per-precision discount from simulated PRT hit rates on
                   ``calib`` activations (``repro.core.pattern.prt_discount``
                   — synthetic batch when ``calib`` is None)
    """
    if prt in (False, None, "off"):
        return 1.0
    if prt is True or prt == "paper":
        return 1.0 - PAPER_CYCLE_REDUCTION
    if prt == "measured":
        from repro.core import pattern
        return pattern.prt_discount(nbw, abits, wbits, calib,
                                    machine=machine)
    raise ValueError(f"prt must be bool, 'paper' or 'measured', got {prt!r}")


def _best_nbw_and_cycles(k: int, n: int, wbits: int, abits: int,
                         batch: int, threads: int, machine: SailMachine,
                         prt, calib) -> tuple:
    best, best_c = 2, float("inf")
    for nbw in (1, 2, 3, 4):
        disc = resolve_prt_discount(prt, nbw, wbits, abits, calib, machine)
        c = lut_gemv_cycles(machine, batch, k, n, nbw, wbits, abits,
                            threads, disc)
        if c < best_c:
            best, best_c = nbw, c
    return best, best_c


def best_nbw_for_unit(k: int, n: int, wbits: int, abits: int = 8,
                      batch: int = 8, threads: int = 16,
                      machine: SailMachine = SailMachine(),
                      prt=True, calib=None) -> int:
    """Cycle-optimal NBW for ONE [K, N] matrix at its allocated precision.

    A mixed allocation should not inherit the model-global ``best_nbw``:
    the build/lookup trade-off shifts with both the matrix shape (K sets
    the group count the build cost amortizes over) and the (wbits, abits)
    pair — and under ``prt="measured"`` the hit rate itself depends on
    NBW.  Small per-call cost, exhaustive over the 4 NBW values.
    """
    return _best_nbw_and_cycles(k, n, wbits, abits, batch, threads,
                                machine, prt, calib)[0]


def mixed_decode_cycles(units, machine: SailMachine = SailMachine(),
                        batch: int = 8, nbw=4, abits: int = 8,
                        threads: int = 16, prt=True, calib=None) -> float:
    """Projected C-SRAM cycles of one decode iteration under a mixed
    per-matrix bit allocation: each matrix runs LUT-GEMV at its own
    ``(ql, abits)`` (the lutmm instruction's per-call precision fields —
    uniformity is a policy choice, never a hardware requirement).

    ``units``: iterable of (k, n, wbits), (k, n, wbits, copies), or
    (k, n, wbits, abits, copies) — a None abits (f32-activation serving)
    is priced at the global ``abits`` default.
    ``nbw``: a fixed NBW, or "auto" to pick :func:`best_nbw_for_unit`
    per matrix.
    ``prt``: see :func:`resolve_prt_discount`; "measured" replaces the
    flat 13.8% constant with per-(nbw, abits, ql) simulated hit rates on
    ``calib`` activations.
    """
    if prt == "measured":
        from repro.core import pattern
        # per-layer calib mappings collapse to their global fallback here:
        # these units carry no layer identity (the planning facade prices
        # per-layer; see repro.planning.cost.DecodeCostModel)
        calib = pattern.calib_for_layer(pattern.canonical_calib(calib), None)
    total = 0.0
    for u in units:
        k, n, wbits = u[0], u[1], u[2]
        if len(u) >= 5:
            ab = u[3] if u[3] is not None else abits
            copies = u[4]
        else:
            ab = abits
            copies = u[3] if len(u) > 3 else 1
        if nbw == "auto":
            _, unit_cycles = _best_nbw_and_cycles(
                k, n, wbits, ab, batch, threads, machine, prt, calib)
        else:
            disc = resolve_prt_discount(prt, nbw, wbits, ab, calib,
                                        machine)
            unit_cycles = lut_gemv_cycles(machine, batch, k, n, nbw,
                                          wbits, ab, threads, disc)
        total += copies * unit_cycles
    return total


def sail_tokens_per_second(model: ModelSpec, ql: int, threads: int = 16,
                           batch: int = 1, nbw: Optional[int] = None,
                           abits: int = 8, machine: SailMachine = SailMachine(),
                           prt=True, inmem_typeconv: bool = True,
                           use_lut: bool = True, calib=None) -> float:
    """Aggregate decode throughput (tokens/s summed over the batch).

    Tensor-level scheduling loads each layer's weights once per iteration
    and serves the whole batch against them (Sec. III-A), so the DRAM
    stream cost is paid once per iteration while compute scales with B.
    The ping-pong pipeline overlaps the two: t_iter = max(t_dram, t_comp)
    + un-overlapped de-/quant tail.

    ``prt``: True/"paper" applies the published flat 13.8% reduction;
    "measured" simulates the PRT hit rate at this (nbw, abits, ql) on
    ``calib`` activations (see :func:`resolve_prt_discount`).
    """
    m = machine
    if nbw is None:
        nbw = best_nbw(model, ql, threads, batch, abits, m, prt, calib)
    prt_discount = resolve_prt_discount(prt, nbw, ql, abits, calib, m)

    t_dram = model_weight_bytes(model, ql) / (m.dram_bw * m.dram_efficiency)

    # GEMV compute across all layers ~ params MACs; expressed as one big
    # [B, K] x [K, N] with K*N = params and K ~ d_model
    k = model.d_model
    n_total = model.params / k
    if use_lut:
        cycles = lut_gemv_cycles(m, batch, k, n_total, nbw, ql, abits,
                                 threads, prt_discount)
    else:
        cycles = bitserial_gemv_cycles(m, batch, k, n_total, ql, abits,
                                       threads)
    t_comp = cycles / m.freq_hz

    # de-/quantization of activations & outputs: one f32<->int pass per
    # activation element per layer boundary
    act_elems = batch * (model.d_model * 4 + model.ffn_dim) * model.n_layers
    if inmem_typeconv:
        arrays = threads * m.arrays_per_thread
        tc_cycles = act_elems * typeconv.sram_cycles(abits + 9) / (
            arrays * m.array_cols)
        # in-memory conversion also pipelines behind the GEMV
        t_tc_exposed = 0.25 * tc_cycles / m.freq_hz
    else:
        # CPU vector engine: ~8 ops/elem on 128-bit NEON lanes
        cpu = CpuMachine()
        lanes = cpu.simd_bits // 32
        t_tc_exposed = act_elems * 8.0 / (lanes * cpu.fma_per_cycle *
                                          cpu.freq_hz * threads)

    t_iter = max(t_dram, t_comp) + t_tc_exposed
    return batch / t_iter


def best_nbw(model: ModelSpec, ql: int, threads: int, batch: int,
             abits: int = 8, machine: SailMachine = SailMachine(),
             prt=True, calib=None) -> int:
    """SAIL jointly optimizes (NBW, bit-width, batch) (Sec. III-C).

    ``prt``/``calib`` select the pricing mode the candidates are ranked
    under — a measured-mode caller must not have its NBW picked by the
    flat paper discount (the hit rate itself depends on NBW)."""
    best, best_t = 2, -1.0
    for nbw in (1, 2, 3, 4):
        t = sail_tokens_per_second(model, ql, threads, batch, nbw, abits,
                                   machine, prt=prt, calib=calib)
        if t > best_t:
            best, best_t = nbw, t
    return best


# Per-ql effective MAC rates (MAC/s per thread), anchored on the paper's own
# measured llama.cpp 7B single-thread baselines (Table II ARM/AMX 1T columns
# x 6.74e9 params): this is the "calibrated against real inference latency"
# step the paper performs for its gem5 CPU model (Sec. V-A).  The per-ql
# variation IS the sub-8-bit NEON/AMX dequant inefficiency SAIL targets.
ARM_MAC_RATE = {2: 0.68 * 6.74e9, 3: 0.70 * 6.74e9, 4: 0.70 * 6.74e9,
                5: 0.60 * 6.74e9, 6: 0.79 * 6.74e9, 8: 0.66 * 6.74e9}
AMX_MAC_RATE = {2: 2.06 * 6.74e9, 3: 2.02 * 6.74e9, 4: 3.45 * 6.74e9,
                5: 1.30 * 6.74e9, 6: 1.20 * 6.74e9, 8: 2.30 * 6.74e9}
ARM_EFF_BW = 40.0e9     # saturated stream BW implied by 7B-Q8 16T (Table II)
AMX_EFF_BW = 132.0e9    # implied by AMX 7B-Q8 16T
ARM_TAU = 0.0113        # 16T = 85.5% of linear (7B-Q2 column)
AMX_TAU = 0.0214


def arm_tokens_per_second(model: ModelSpec, ql: int, threads: int = 16,
                          batch: int = 1) -> float:
    """ARM Neoverse-N1 + llama.cpp decode model.

    Compute rate per thread is anchored on the paper's measured 1-thread
    baselines (per-ql, capturing NEON sub-byte dequant inefficiency).
    Batching does NOT amortize the weight stream on the CPU baseline:
    "CPU-based platforms show minimal benefit from batching due to memory
    bandwidth saturation" (paper Sec. V-D) — throughput is capped at the
    per-token stream bound regardless of batch.
    """
    eff = 1.0 / (1.0 + ARM_TAU * (threads - 1))
    t_comp = batch * model.gemv_macs_per_token / (
        ARM_MAC_RATE[ql] * threads * eff)
    mem_cap = ARM_EFF_BW / model_weight_bytes(model, ql)  # tokens/s
    return min(batch / t_comp, mem_cap)


def amx_tokens_per_second(model: ModelSpec, ql: int, threads: int = 16,
                          batch: int = 1) -> float:
    """Intel AMX (Emerald Rapids) llama.cpp decode model, anchored the same
    way.  AMX's native int8 tiles show up as the higher Q4/Q8 rates; sub-4-bit
    still pays vector-side dequant (Sec. V-E).  Same batch-saturation
    behaviour as ARM (Sec. V-D)."""
    eff = 1.0 / (1.0 + AMX_TAU * (threads - 1))
    t_comp = batch * model.gemv_macs_per_token / (
        AMX_MAC_RATE[ql] * threads * eff)
    mem_cap = AMX_EFF_BW / model_weight_bytes(model, ql)
    return min(batch / t_comp, mem_cap)


# ---------------------------------------------------------------------------
# Breakdown (Fig. 12) and TPD (Fig. 13 / Table IV)
# ---------------------------------------------------------------------------

# CPU-side exposure when PIM GEMV results round-trip through the cache for
# vector-unit type conversion (the "up to 90% waiting on data movement"
# problem of in-cache PIM [9] that Algorithm 1 removes), per element.
CPU_TC_NS_PER_ELEM = 3.0
# Fig. 12's Baseline is "a real ARM machine" (not the gem5 Neoverse-N1);
# its per-thread GEMV rate is calibrated so full SAIL lands at the
# published 3.81x end-to-end kernel speedup.
FIG12_BASELINE_MAC_RATE = 18.35e9


def gemv_breakdown(k: int = 4096, n: int = 4096, batch: int = 8,
                   ql: int = 4, nbw: int = 4, threads: int = 16,
                   machine: SailMachine = SailMachine()) -> Dict[str, float]:
    """Latency of one Q4 GEMV kernel under the four configurations of
    Fig. 12: Baseline (real ARM CPU), NC (bit-serial in-SRAM), LUT (SAIL
    without in-memory type conversion), LUT+TC (full SAIL).  Returns
    seconds; kernel-level cycle accounting (see ``lookup_cycles``)."""
    m = machine
    macs = batch * k * n
    eff = 1.0 / (1.0 + ARM_TAU * (threads - 1))
    t_base = max(macs / (FIG12_BASELINE_MAC_RATE * threads * eff),
                 k * n * BPW[ql] / 8.0 / ARM_EFF_BW)

    # de-/quant conversions the CPU performs on PIM outputs: one partial
    # sum per (out elem, K-group) plus activation quantization
    conv_elems = batch * n * (k // 256) + batch * k
    t_cpu_tc = conv_elems * CPU_TC_NS_PER_ELEM * 1e-9 / threads
    arrays = threads * m.arrays_per_thread
    t_sram_tc = (conv_elems * typeconv.sram_cycles(17)
                 / (arrays * m.array_cols) / m.freq_hz)

    t_nc = bitserial_gemv_cycles(m, batch, k, n, ql, 8, threads) / m.freq_hz
    t_lut = lut_gemv_cycles(m, batch, k, n, nbw, ql, 8, threads,
                            1.0 - PAPER_CYCLE_REDUCTION,
                            kernel_level=True) / m.freq_hz
    return {
        "baseline": t_base,                    # native f32: no conversions
        "neural_cache": t_nc + t_cpu_tc,
        "lut": t_lut + t_cpu_tc,
        # Algorithm 1 runs in-array and pipelines behind the GEMV; a quarter
        # of its cycles remain exposed at the pipeline tail
        "lut_tc": t_lut + 0.25 * t_sram_tc,
    }


def fig1_efficiency_gain(ql: int, batch: int, nbw: int = None,
                         machine: SailMachine = SailMachine()) -> float:
    """Fig. 1: LUT-based vs bit-serial computing efficiency gain for one
    lutmm_1k-shaped workload at a given quantization level and batch."""
    m = machine
    if nbw is None:
        nbw = min((lut_gemv_cycles(m, batch, 1024, 1024, g, ql,
                                   kernel_level=True), g)
                  for g in (1, 2, 3, 4))[1]
    lut = lut_gemv_cycles(m, batch, 1024, 1024, nbw, ql, kernel_level=True)
    bs = bitserial_gemv_cycles(m, batch, 1024, 1024, ql)
    return bs / lut


# GCP monthly prices, Table IV
MONTHLY_PRICE = {
    "cpu_5c": 292.31,
    "cpu_16c": 665.45,
    "v100_1x": 1861.5,
    "v100_4x": 7446.0,
    "sail_16c": 665.45,   # SAIL = 16-core CPU node + ~2% silicon
}


def tokens_per_dollar(tokens_per_s: float, system: str) -> float:
    """TPD = tokens/s * 30 days / monthly price (Sec. V-H)."""
    return tokens_per_s * 30 * 24 * 3600 / MONTHLY_PRICE[system]


# ---------------------------------------------------------------------------
# Paper-published reference data (for validation benchmarks/tests)
# ---------------------------------------------------------------------------

# Table II: tokens/s, [1, 2, 4, 8, 16] threads
PAPER_TABLE_II = {
    ("7b", 2):  {"arm": [0.68, 1.34, 2.63, 4.97, 9.30],
                 "amx": [2.06, 4.02, 7.65, 14.25, 24.96],
                 "sail": [6.42, 12.62, 24.00, 43.50, 81.63]},
    ("7b", 3):  {"arm": [0.70, 1.38, 2.71, 5.11, 9.62],
                 "amx": [2.02, 3.93, 7.47, 13.69, 24.50],
                 "sail": [5.53, 10.93, 20.87, 38.40, 73.75]},
    ("7b", 4):  {"arm": [0.70, 1.37, 2.67, 5.15, 9.85],
                 "amx": [3.45, 6.72, 11.51, 21.13, 33.55],
                 "sail": [4.82, 9.61, 18.67, 35.17, 72.10]},
    ("7b", 5):  {"arm": [0.60, 1.17, 2.32, 4.48, 8.49],
                 "amx": [1.30, 2.56, 4.84, 9.17, 16.48],
                 "sail": [3.98, 7.96, 15.52, 29.62, 61.84]},
    ("7b", 6):  {"arm": [0.79, 1.20, 2.36, 4.52, 8.31],
                 "amx": [1.20, 2.33, 4.47, 8.10, 14.62],
                 "sail": [3.34, 6.67, 12.97, 24.60, 50.63]},
    ("7b", 8):  {"arm": [0.66, 1.28, 2.51, 4.69, 5.54],
                 "amx": [2.30, 4.51, 7.50, 13.55, 18.39],
                 "sail": [2.60, 5.22, 10.28, 19.86, 43.27]},
    ("13b", 2): {"arm": [0.35, 0.70, 1.38, 2.68, 5.05],
                 "amx": [1.06, 2.06, 3.91, 7.28, 12.75],
                 "sail": [3.77, 7.44, 14.34, 26.63, 52.55]},
    ("13b", 3): {"arm": [0.35, 0.69, 1.36, 2.63, 5.01],
                 "amx": [1.02, 2.01, 3.82, 7.00, 12.62],
                 "sail": [3.67, 7.33, 13.84, 25.70, 51.10]},
    ("13b", 4): {"arm": [0.36, 0.72, 1.41, 2.75, 5.27],
                 "amx": [1.82, 3.53, 5.79, 10.95, 17.42],
                 "sail": [2.81, 5.62, 11.00, 21.06, 45.07]},
    ("13b", 5): {"arm": [0.31, 0.61, 1.20, 2.34, 4.44],
                 "amx": [0.67, 1.32, 2.52, 4.78, 8.56],
                 "sail": [2.32, 4.64, 9.10, 17.60, 38.24]},
    ("13b", 6): {"arm": [0.32, 0.62, 1.23, 2.40, 4.52],
                 "amx": [0.62, 1.18, 2.17, 4.14, 7.25],
                 "sail": [1.94, 3.88, 7.60, 14.61, 31.32]},
    ("13b", 8): {"arm": [0.34, 0.68, 1.29, 2.46, 4.80],
                 "amx": [1.15, 2.20, 3.89, 7.19, 10.07],
                 "sail": [1.51, 3.03, 5.98, 10.75, 26.25]},
}

# Table III: GPU token generation (tokens/s, best batch), paper-measured
PAPER_TABLE_III = {
    # (model, ql): {platform: {ctx: tok/s}}
    ("7b", 4): {"v100_1x": {512: 216.3, 1024: 173.4, 2048: 123.6, 4096: 78.98},
                "v100_2x": {512: 229.3, 1024: 179.6, 2048: 129.7, 4096: 88.02},
                "a100":    {512: 670.7, 1024: 425.8, 2048: 255.8, 4096: 129.3},
                "sail":    {4096: 134.22}},
    ("7b", 8): {"v100_1x": {512: 190.5, 1024: 126.9, 2048: 84.98, 4096: 41.62},
                "v100_2x": {512: 196.3, 1024: 163.3, 2048: 112.6, 4096: 81.90},
                "a100":    {512: 652.4, 1024: 418.2, 2048: 252.7, 4096: 120.4},
                "sail":    {4096: 113.84}},
    ("13b", 4): {"v100_1x": {512: 173.9, 1024: 126.4, 2048: 85.47, 4096: 39.97},
                 "v100_2x": {512: 148.5, 1024: 114.7, 2048: 81.99, 4096: 51.15},
                 "a100":    {512: 442.4, 1024: 278.8, 2048: 117.9, 4096: 87.50},
                 "sail":    {4096: 73.93}},
}

# Fig. 6 quoted anchor points: (batch, nbw, wbits) -> cycles
PAPER_FIG6_ANCHORS = {
    (24, 4, 2): 3.00e6,
    (24, 4, 4): 4.87e6,
    (24, 2, 2): 11.45e6,
}

# Fig. 12: final LUT+TC speedup over ARM baseline
PAPER_FIG12_SPEEDUP = 3.81

# Sec. III-C: online LUT creation overhead range
PAPER_LUT_OVERHEAD = {(8, 2, 2): 0.03, (32, 4, 4): 0.12}


def fig6_workload_cycles(batch: int, nbw: int, wbits: int,
                         machine: SailMachine = SailMachine()) -> float:
    """The DSE workload of Fig. 6: one ``lutmm_1k`` tile —
    [B,1024]x[1024,1024] — on a single thread pair (2 arrays), abits=8.
    (The figure characterizes the new instruction, Sec. IV-A.)"""
    return lut_gemv_cycles(machine, batch, 1024, 1024, nbw, wbits,
                           abits=8, threads=1)


def geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))
