"""ActivationTap: per-layer activation capture inside ``Engine.step()``.

``prt="measured"`` discounts were calibrated on synthetic or held-out
activations; the ROADMAP's "PRT hit rates from live traffic" item asks
for the real thing.  ``lm.decode_step(capture_layer_inputs=True)``
returns each layer's block input (the very vectors the DFM would stream
through the PRT), the engine hands them to the tap every decode
iteration, and ``Planner.replan(tap)`` turns the captured batches into
measured per-layer PRT discounts — and, with ``resolve=True``, a fresh
allocation — as traffic shifts.

The tap keeps a bounded ring per layer (``capacity`` rows), so a
long-running engine pays constant memory and replans always see the most
recent traffic window.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, Optional

import numpy as np


class ActivationTap:
    """Bounded per-layer ring buffer of decode-time activation rows."""

    def __init__(self, capacity: int = 512, capture_every: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if capture_every < 1:
            raise ValueError(f"capture_every must be >= 1, got {capture_every}")
        self.capacity = int(capacity)
        self.capture_every = int(capture_every)
        self._rows: Dict[int, deque] = {}
        self.observations = 0  # decode iterations captured
        self.rows_seen = 0  # activation rows captured (across layers)

    def should_capture(self, iteration: int) -> bool:
        """Subsample capture to every ``capture_every``-th iteration (the
        np.asarray transfer forces a device sync, so heavy serving loops
        may not want every step)."""
        return iteration % self.capture_every == 0

    def observe(self, layer_inputs, active_mask=None) -> None:
        """Record one decode iteration's layer inputs.

        ``layer_inputs``: [L, B, 1, D] (or [L, B, D]) block inputs from
        ``lm.decode_step(capture_layer_inputs=True)``.  ``active_mask``
        ([B] bool) drops retired slots' dead lanes — their activations
        are stale values the engine ignores, and they would pollute the
        measured repeat statistics.
        """
        arr = np.asarray(layer_inputs, np.float32)
        if arr.ndim == 4:  # [L, B, T=1, D]
            arr = arr[:, :, 0, :]
        if arr.ndim != 3:
            raise ValueError(f"layer_inputs must be [L, B, D], got {arr.shape}")
        if active_mask is not None:
            mask = np.asarray(active_mask, bool)
            arr = arr[:, mask, :]
        if arr.shape[1] == 0:
            return
        for layer in range(arr.shape[0]):
            ring = self._rows.get(layer)
            if ring is None:
                ring = self._rows[layer] = deque(maxlen=self.capacity)
            ring.extend(arr[layer])
        self.observations += 1
        self.rows_seen += int(arr.shape[0] * arr.shape[1])

    # -- consumers --------------------------------------------------------

    @property
    def n_layers(self) -> int:
        return len(self._rows)

    def __len__(self) -> int:
        """Rows currently held for layer 0 (the ring fill level)."""
        ring = self._rows.get(0)
        return len(ring) if ring is not None else 0

    def rows(self, layer: int) -> Optional[np.ndarray]:
        """f32 [n, D] captured batch for one layer (None if empty)."""
        ring = self._rows.get(layer)
        if not ring:
            return None
        return np.stack(ring).astype(np.float32)

    def calib(self, max_rows: Optional[int] = None) -> Optional[Dict]:
        """Per-layer calibration mapping for ``DecodeCostModel``/
        ``Planner.replan``: ``{layer: [n, D] f32, None: merged}`` (the
        ``None`` entry is the cross-layer fallback for units without
        their own capture).  Returns None when nothing was captured."""
        if not self._rows:
            return None
        out: Dict = {}
        for layer in sorted(self._rows):
            batch = self.rows(layer)
            if batch is None:
                continue
            if max_rows is not None and batch.shape[0] > max_rows:
                batch = batch[-max_rows:]
            out[layer] = batch
        if not out:
            return None
        merged = np.concatenate(list(out.values()), axis=0)
        if max_rows is not None and merged.shape[0] > max_rows:
            merged = merged[-max_rows:]
        out[None] = merged
        return out

    def clear(self) -> None:
        self._rows.clear()
