"""DecodeCostModel: one pricing facade for precision plans.

Consolidates the cost primitives that used to be wired together ad hoc
(``mixed_decode_cycles`` / ``resolve_prt_discount`` / ``best_nbw_for_unit``)
and — the DRAM-aware objective from the ROADMAP — folds the weight-stream
time into the modeled decode iteration:

    t_iter = max(t_dram, t_compute)        (ping-pong overlap, Sec. III-A)
    t_dram = total_weight_bytes / (dram_bw * dram_efficiency)

so a byte-heavy allocation can no longer hide behind the compute bound.
Because the iteration time is a max of two linear terms, an SLO (target
decode tokens/s at a batch) decomposes *exactly* into two linear budgets
the joint allocator already knows how to enforce:

    T            = batch / target_tps            seconds per iteration
    cycle_budget = T * freq_hz                   C-SRAM compute budget
    byte_budget  = T * dram_bw * eff - fixed     weight-stream budget

(``fixed`` is the DRAM traffic of the leaves the policy does not
quantize — embeddings, norms — which streams every iteration whatever
the plan says.)  ``Planner.solve(slo=...)`` is just this decomposition
plus the existing solver.

Tensor-parallel pricing (PR 10): sharding the weight tree ``tp`` ways
divides both the compute and the weight stream but adds a wire term —
two ring all-reduces per layer (``wo`` and ``w_down`` partial sums):

    t_iter = max(t_compute / M, t_dram / M, t_wire)
    t_wire = 2(M-1)/M * batch * allreduce_elems * wire_bits/8 / link_bw

so the Planner can trade bits against shards at a fixed SLO: per-shard
budgets scale by M, while ``t_wire`` — which no bit allocation changes —
caps how far sharding helps.  ``wire_bits=8`` prices the compressed
(int8+scale) all-reduce.

Per-layer PRT calibration: ``calib`` may be one f32 ``[B, K]`` activation
batch or a ``{layer: batch}`` mapping (``None`` key = global fallback),
e.g. from ``repro.planning.tap.ActivationTap.calib()`` — each unit is
then discounted by its own layer's measured hit rate.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.core import cost_model as cm
from repro.core.pattern import calib_for_layer

# Inter-shard link bandwidth when no measured/configured value is given:
# one PCIe 4.0 x16 link's practical ~16 GB/s — the class of interconnect
# the commodity-hardware deployments SAIL targets actually have.
DEFAULT_LINK_BW = 16e9


def tp_allreduce_elems(cfg) -> int:
    """All-reduce payload elements per decode token: one ``d_model``
    partial sum per attention (``wo``) and one per MLP (``w_down``) in
    every layer.  ``cfg`` is duck-typed (needs ``n_layers``/``d_model``)."""
    return 2 * int(cfg.n_layers) * int(cfg.d_model)


@dataclasses.dataclass(frozen=True)
class Slo:
    """A decode service-level objective: aggregate tokens/s at a batch."""

    target_tps: float
    batch: int = 8

    def __post_init__(self):
        if self.target_tps <= 0:
            raise ValueError(f"target_tps must be positive, got {self.target_tps}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")

    @property
    def seconds_per_iteration(self) -> float:
        """One masked decode iteration commits ``batch`` tokens, so the
        SLO bounds its latency at batch/target seconds."""
        return self.batch / self.target_tps


@dataclasses.dataclass(frozen=True)
class Budgets:
    """SLO-derived solver budgets (see module docstring for derivation)."""

    seconds: float
    cycle_budget: float
    byte_budget: Optional[int]
    fixed_bytes: int = 0


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Modeled cost of one plan/policy on one model.

    ``t_compute`` / ``t_dram`` are per-shard times (already divided by
    the model's ``tp``); ``t_wire`` is the per-iteration all-reduce time
    (0.0 at ``tp=1``)."""

    cycles: float
    quant_bytes: int
    fixed_bytes: int
    t_compute: float
    t_dram: float
    seconds_per_iteration: float
    tokens_per_second: float
    t_wire: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.quant_bytes + self.fixed_bytes

    @property
    def dram_bound(self) -> bool:
        return self.t_dram > self.t_compute

    @property
    def bound(self) -> str:
        """Which term sets the iteration time: "compute", "dram", or
        "wire" — the regime the SLO solver is trading within."""
        terms = {"compute": self.t_compute, "dram": self.t_dram,
                 "wire": self.t_wire}
        return max(terms, key=terms.get)


@dataclasses.dataclass(frozen=True)
class DecodeCostModel:
    """Prices (cycles, bytes, seconds, tokens/s) of precision plans.

    ``prt`` selects the pattern-discount model (False/"off", True/"paper",
    "measured"); ``nbw`` is a fixed NBW or "auto" (per-unit cycle-optimal);
    ``include_dram=False`` reverts to the legacy compute-only objective
    (the pre-PlanSpec behavior, kept for A/B in the bench).

    ``tp`` / ``wire_bits`` / ``link_bw`` / ``allreduce_elems`` price
    tensor-parallel serving (module docstring): compute and DRAM divide
    by the shard count, the all-reduce adds ``t_wire``.
    ``dispatch_cycles`` is an optional per-(NBW, abits) fixed
    kernel-dispatch overhead fitted by ``planning.calibrate_cost`` —
    (((nbw, abits), cycles), ...) pairs, charged once per kernel
    invocation.
    """

    machine: cm.SailMachine = dataclasses.field(default_factory=cm.SailMachine)
    batch: int = 8
    threads: int = 16
    prt: Any = "paper"
    nbw: Any = "auto"
    include_dram: bool = True
    calib: Any = None
    tp: int = 1
    wire_bits: int = 32
    link_bw: Optional[float] = None
    allreduce_elems: float = 0.0
    dispatch_cycles: Any = None

    def __post_init__(self):
        from repro.core import pattern

        object.__setattr__(self, "calib", pattern.canonical_calib(self.calib))
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.wire_bits not in (8, 32):
            raise ValueError(f"wire_bits must be 8 or 32, got {self.wire_bits}")
        disp = self.dispatch_cycles
        if disp is not None and not isinstance(disp, tuple):
            # accept dicts / lists (JSON provenance) but store hashably
            items = disp.items() if hasattr(disp, "items") else disp
            disp = tuple(
                sorted(
                    (
                        (
                            (int(k.split(":")[0]), int(k.split(":")[1]))
                            if isinstance(k, str)
                            else (int(k[0]), int(k[1]))
                        ),
                        float(v),
                    )
                    for k, v in items
                )
            )
            object.__setattr__(self, "dispatch_cycles", disp)

    # -- per-unit pricing -------------------------------------------------

    def discount(self, nbw: int, wbits: int, abits: int, layer=None) -> float:
        """Lookup-cycle discount for one (nbw, wbits, abits) point, using
        the layer's own calibration batch when one was captured."""
        return cm.resolve_prt_discount(
            self.prt, nbw, wbits, abits, calib_for_layer(self.calib, layer), self.machine
        )

    def _dispatch(self, nbw: int, abits: int) -> float:
        """Fixed per-invocation dispatch overhead at this (NBW, abits)
        cell (0.0 when no calibration fitted one)."""
        if not self.dispatch_cycles:
            return 0.0
        want = (int(nbw), int(abits))
        for key, cyc in self.dispatch_cycles:
            if key == want:
                return cyc
        return 0.0

    def unit_cycles(self, k, n, wbits, abits, copies: int = 1, layer=None) -> float:
        """C-SRAM cycles of one [K, N] matrix at its allocated precision
        (f32 activations — abits None — are priced at the 8-bit default,
        matching ``mixed_decode_cycles``)."""
        ab = 8 if abits is None else int(abits)
        calib = calib_for_layer(self.calib, layer)
        if self.nbw == "auto":
            nbw_used, cyc = cm._best_nbw_and_cycles(
                k, n, wbits, ab, self.batch, self.threads, self.machine, self.prt, calib
            )
        else:
            nbw_used = int(self.nbw)
            disc = cm.resolve_prt_discount(self.prt, nbw_used, wbits, ab, calib, self.machine)
            cyc = cm.lut_gemv_cycles(
                self.machine, self.batch, k, n, nbw_used, wbits, ab, self.threads, disc
            )
        return copies * (cyc + self._dispatch(nbw_used, ab))

    def best_nbw(self, k, n, wbits, abits, layer=None) -> int:
        ab = 8 if abits is None else int(abits)
        return cm._best_nbw_and_cycles(
            k,
            n,
            wbits,
            ab,
            self.batch,
            self.threads,
            self.machine,
            self.prt,
            calib_for_layer(self.calib, layer),
        )[0]

    # -- whole-plan pricing -----------------------------------------------

    def cycles(self, units) -> float:
        """Projected C-SRAM cycles of one decode iteration.

        ``units``: (k, n, wbits, abits, copies[, layer]) tuples — the
        output of :func:`policy_units`.
        """
        total = 0.0
        for u in units:
            k, n, wb, ab, copies = u[0], u[1], u[2], u[3], u[4]
            layer = u[5] if len(u) > 5 else None
            total += self.unit_cycles(k, n, wb, ab, copies, layer)
        return total

    def qbytes(self, units, group_size: int) -> int:
        """QTensor bytes of the allocation (packed words + scales)."""
        return sum(cm.qtensor_bytes(u[0], u[1], u[2], group_size, u[4]) for u in units)

    def t_compute(self, cycles: float) -> float:
        """Per-shard compute time: each of the ``tp`` shards runs 1/tp of
        every matmul's lookups."""
        return cycles / self.machine.freq_hz / self.tp

    def t_dram(self, total_bytes: float) -> float:
        """Per-shard weight-stream time: the sharded tree streams 1/tp of
        the bytes per device."""
        if not self.include_dram:
            return 0.0
        return total_bytes / (self.machine.dram_bw * self.machine.dram_efficiency) / self.tp

    def t_wire(self, batch=None) -> float:
        """Per-iteration all-reduce time: a ring all-reduce moves
        ``2(M-1)/M`` of the payload per shard, and the payload is one
        partial sum per row-parallel matmul per token
        (``allreduce_elems`` elements at ``wire_bits``)."""
        if self.tp <= 1 or self.allreduce_elems <= 0:
            return 0.0
        b = self.batch if batch is None else batch
        payload = b * self.allreduce_elems * self.wire_bits / 8.0
        bw = self.link_bw if self.link_bw is not None else DEFAULT_LINK_BW
        return 2.0 * (self.tp - 1) / self.tp * payload / bw

    def iteration_seconds(self, cycles: float, total_bytes: float) -> float:
        """Ping-pong LLC overlap: the weight stream hides behind compute
        (or vice versa) and the all-reduce overlaps the other layers'
        work, so one iteration costs the max of the three terms."""
        return max(self.t_compute(cycles), self.t_dram(total_bytes), self.t_wire())

    def tokens_per_second(self, cycles: float, total_bytes: float, batch=None) -> float:
        b = self.batch if batch is None else batch
        return b / max(self.iteration_seconds(cycles, total_bytes), 1e-30)

    def budgets(self, slo: Slo, fixed_bytes: int = 0) -> Budgets:
        """Decompose an SLO into the joint solver's two linear budgets.

        Under TP the per-shard budgets scale by the shard count (the
        model streams/computes 1/tp per device), while ``t_wire`` —
        which no bit allocation changes — must fit on its own or the SLO
        is unreachable at this (tp, wire) point."""
        t = slo.seconds_per_iteration
        tw = self.t_wire(slo.batch)
        if tw >= t:
            raise ValueError(
                f"SLO {slo.target_tps} tok/s @ batch {slo.batch} is unreachable at "
                f"tp={self.tp}, wire={self.wire_bits}: the all-reduce alone takes "
                f"{tw:.2e}s of the {t:.2e}s iteration budget — no bit allocation "
                "can fix a wire-bound plan (fewer shards or wire=8 might)"
            )
        cycle_budget = t * self.machine.freq_hz * self.tp
        byte_budget = None
        if self.include_dram:
            byte_budget = int(
                t * self.machine.dram_bw * self.machine.dram_efficiency * self.tp
            ) - int(fixed_bytes)
            if byte_budget < 0:
                raise ValueError(
                    f"SLO {slo.target_tps} tok/s @ batch {slo.batch} is unreachable: "
                    f"streaming the {fixed_bytes} unquantized bytes alone exceeds the "
                    f"{t:.2e}s iteration budget"
                )
        return Budgets(
            seconds=t,
            cycle_budget=cycle_budget,
            byte_budget=byte_budget,
            fixed_bytes=int(fixed_bytes),
        )

    def evaluate(self, params, policy, batch=None) -> PlanCost:
        """Price a resolved policy on a concrete parameter tree.

        ``batch`` overrides the model's batch for the WHOLE evaluation —
        lookup cycles scale with it, not just the tokens-per-iteration
        numerator — so pricing at an SLO's batch is one consistent
        re-evaluation, never a mixed-batch ratio."""
        if batch is not None and batch != self.batch:
            return dataclasses.replace(self, batch=int(batch)).evaluate(params, policy)
        units = policy_units(params, policy)
        cycles = self.cycles(units)
        qbytes = self.qbytes(units, policy.group_size)
        fixed = unquantized_bytes(params, policy) if self.include_dram else 0
        total = qbytes + fixed
        tc, td, tw = self.t_compute(cycles), self.t_dram(total), self.t_wire()
        secs = max(tc, td, tw)
        b = self.batch if batch is None else batch
        return PlanCost(
            cycles=cycles,
            quant_bytes=qbytes,
            fixed_bytes=fixed,
            t_compute=tc,
            t_dram=td,
            t_wire=tw,
            seconds_per_iteration=secs,
            tokens_per_second=b / max(secs, 1e-30),
        )


def policy_units(params, policy) -> List[Tuple[int, int, int, Optional[int], int, Optional[int]]]:
    """Cost-model units of every leaf ``policy`` quantizes:
    (k, n, wbits, abits, copies, layer) — per-layer entries for scan
    stacks whose assignment varies by layer, one aggregated entry
    otherwise.  This is the single source the engine, planner, and
    benchmarks price plans with."""
    from repro.core import sensitivity as sens

    def at(spec, i):
        if spec is None or not isinstance(spec, (tuple, list)):
            return spec
        return spec[i]

    units: List[Tuple[int, int, int, Optional[int], int, Optional[int]]] = []
    for pstr, w, stacked in sens.quantizable_units(params, policy):
        k, n = int(w.shape[-2]), int(w.shape[-1])
        spec = policy.bits_for(pstr)
        aspec = policy.abits_for(pstr)
        if stacked:
            per_slice = 1
            for d in w.shape[1:-2]:
                per_slice *= int(d)
            layers = int(w.shape[0])
            layered = isinstance(spec, (tuple, list)) or isinstance(aspec, (tuple, list))
            if layered:
                for i in range(layers):
                    units.append((k, n, int(at(spec, i)), _opt(at(aspec, i)), per_slice, i))
            else:
                units.append((k, n, int(spec), _opt(aspec), per_slice * layers, None))
        else:
            units.append((k, n, int(spec), _opt(aspec), 1, None))
    return units


def _opt(ab):
    return None if ab is None else int(ab)


def unquantized_bytes(params, policy) -> int:
    """DRAM bytes of the leaves ``policy`` leaves in f32 (embeddings,
    norms, small tensors).  They stream every decode iteration no matter
    what the plan allocates, so the DRAM-aware objective charges them as
    a fixed term."""
    import jax

    from repro.core import sensitivity as sens

    quantized = {p for p, _, _ in sens.quantizable_units(params, policy)}
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        pstr = jax.tree_util.keystr(path)
        if pstr not in quantized:
            total += int(leaf.size) * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# Self-speculative pricing (PlanSpec.draft: a bit-gap buys tokens/round)
# ---------------------------------------------------------------------------


def expected_tokens_per_round(acceptance: float, k: int) -> float:
    """Expected committed tokens of one draft-k/verify round.

    Greedy speculative sampling commits the longest draft prefix the
    verifier agrees with, plus the verifier's own next token: with
    per-position acceptance ``a``, that is ``sum_{i=0..k} a^i`` =
    ``(1 - a^(k+1)) / (1 - a)`` — between 1 (every draft rejected, the
    round still commits the verifier's correction) and ``k + 1``
    (all-accept plus the bonus token)."""
    a = min(max(float(acceptance), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculative_round_seconds(
    cost: "DecodeCostModel",
    verify_units,
    draft_units,
    group_size: int,
    fixed_bytes: int,
    k: int,
) -> float:
    """Modeled seconds of one speculative round at ``cost.batch`` lanes.

    The draft phase runs ``k`` single-token iterations under the draft
    tree (its own, smaller, weight stream); the verify phase is ONE
    iteration whose lookups carry ``batch * (k + 1)`` rows but whose
    weight stream is the same conservative bytes a plain iteration
    streams — the amortization speculative decoding banks on: DRAM
    traffic per round is ``k * draft_bytes + verify_bytes`` for up to
    ``k + 1`` committed tokens per lane."""
    d_cycles = cost.cycles(draft_units)
    d_bytes = cost.qbytes(draft_units, group_size) + fixed_bytes
    t_draft = cost.iteration_seconds(d_cycles, d_bytes)
    verify = dataclasses.replace(cost, batch=cost.batch * (k + 1))
    v_cycles = verify.cycles(verify_units)
    v_bytes = cost.qbytes(verify_units, group_size) + fixed_bytes
    t_verify = verify.iteration_seconds(v_cycles, v_bytes)
    return k * t_draft + t_verify


# ---------------------------------------------------------------------------
# KV-cache pricing (the third PlanSpec dimension: kv_bits buys concurrency)
# ---------------------------------------------------------------------------


def kv_token_bytes(n_layers: int, n_kv: int, head_dim: int, kv_bits: int = 32) -> int:
    """Bytes one cached token costs across all layers (K and V).

    ``kv_bits=8`` prices the served int8 layout: one int8 code per element
    plus one f32 absmax scale per (token, kv-head) for each of K and V —
    the exact arrays ``lm.init_paged_cache(quant_kv=True)`` allocates.
    """
    if kv_bits == 8:
        per_side = n_kv * head_dim + n_kv * 4  # int8 codes + f32 scales
    elif kv_bits == 32:
        per_side = n_kv * head_dim * 4
    else:
        raise ValueError(f"kv_bits must be 8 or 32, got {kv_bits}")
    return 2 * n_layers * per_side


def kv_block_bytes(
    block_size: int, n_layers: int, n_kv: int, head_dim: int, kv_bits: int = 32
) -> int:
    """Bytes of one paged KV block (``block_size`` tokens)."""
    return block_size * kv_token_bytes(n_layers, n_kv, head_dim, kv_bits)


def kv_pool_blocks(
    budget_bytes: int,
    block_size: int,
    n_layers: int,
    n_kv: int,
    head_dim: int,
    kv_bits: int = 32,
) -> int:
    """Paged blocks a KV byte budget buys — quantized KV literally buys
    concurrency: at ``kv_bits=8`` the same budget holds ~4x the tokens
    (minus the scale overhead), so admission sustains more users."""
    blk = kv_block_bytes(block_size, n_layers, n_kv, head_dim, kv_bits)
    return max(1, int(budget_bytes) // blk)
