"""Planner: solve, price, and re-solve PlanSpecs.

``Planner.solve`` turns an *auto* ``PlanSpec`` into a solved one.  With
an :class:`~repro.planning.cost.Slo` it derives the joint solver's cycle
AND byte budgets from the target decode tokens/s (cycle-budget
autoscaling + the DRAM-aware objective — two ROADMAP items); without one
it reproduces the legacy match-uniform / bits-per-weight budgets.

``Planner.replan`` consumes the per-layer activation batches an
:class:`~repro.planning.tap.ActivationTap` captured inside
``Engine.step()`` and recomputes the measured PRT discounts (and, with
``resolve=True``, the whole allocation) from live traffic — the engine
then swaps onto the result via ``Engine.apply_plan`` without dropping a
request.

Sensitivity probes are cached on the planner: the expensive forward
probes run once, and every subsequent ``solve``/``replan`` (budget
sweeps, SLO changes, online recalibration) reuses them.

Invariants:

- ``solve`` is deterministic for a given (params, plan, slo, calib) —
  probes are seeded and cached, so repeated solves return the same spec.
- A returned ``PlanResult.spec`` is always *solved*: ``auto`` modes carry
  ``weights_per_unit``/``acts_per_unit`` and a ``kv_bits`` of ``"auto"``
  is resolved to a concrete 8 or 32 (per-layer KV probe vs
  ``kv_tolerance``) before the result leaves the planner.  A ``tp`` of
  ``"auto"`` is pinned to the smallest shard count whose modeled
  ``t_iter`` meets the SLO *before* the bit solve, so the per-shard
  budgets the allocator then sees already include the xM scaling — this
  is how the planner trades bits against shards at a fixed target.
- ``replan`` never mutates the served plan's allocation unless
  ``resolve=True``; the cheap path only re-prices under measured PRT
  discounts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core import pattern
from repro.core import sensitivity as sens
from repro.planning.cost import DecodeCostModel, PlanCost, Slo, unquantized_bytes
from repro.planning.spec import PlanSpec


@dataclasses.dataclass
class PlanResult:
    """One solved plan: the spec (source of truth), the servable policy,
    solver diagnostics, and the modeled cost under the DRAM-aware
    objective."""

    spec: PlanSpec
    policy: Any
    report: Any = None
    cost: Optional[PlanCost] = None
    budgets: Any = None
    measured_prt_hit_rate: Optional[float] = None
    # per-layer KV quantization probe (when the plan asked kv_bits="auto")
    kv_sensitivity: Optional[dict] = None

    @property
    def meets_slo(self) -> Optional[bool]:
        if self.spec.target_tps is None or self.cost is None:
            return None
        return self.cost.tokens_per_second >= self.spec.target_tps * (1 - 1e-9)


def _solver_prt(prt: str):
    """PlanSpec prt mode -> the cost model's switch values."""
    return False if prt == "off" else prt


class Planner:
    """Solves one model's precision plans against one cost model."""

    def __init__(
        self,
        params,
        cfg,
        plan: PlanSpec | str | None = None,
        base=None,
        cost: Optional[DecodeCostModel] = None,
        tokens=None,
        scores=None,
        act_scores=None,
        kv_tolerance: float = 0.05,
    ):
        from repro.models.sail_linear import QuantPolicy

        self.params = params
        self.cfg = cfg
        if isinstance(plan, str):
            plan = PlanSpec.parse(plan)
        self.plan = plan if plan is not None else PlanSpec(mode="auto", act_bits=8)
        self.base = base or QuantPolicy(
            bits=self.plan.weight_bits or 4,
            group_size=self.plan.group_size or 128,
            min_size=self.plan.min_size or 65536,
        )
        if cost is None:
            prt = _solver_prt(self.plan.prt)
            if self.plan.calibration is not None:
                from repro.planning.calibrate_cost import (
                    dispatch_from_json,
                    machine_from_json,
                )

                cost = DecodeCostModel(
                    machine=machine_from_json(self.plan.calibration),
                    prt=prt,
                    dispatch_cycles=dispatch_from_json(self.plan.calibration),
                )
            else:
                cost = DecodeCostModel(prt=prt)
        self.cost = cost
        self._tokens = tokens
        self._scores = scores
        self._act_scores = act_scores
        self.kv_tolerance = kv_tolerance
        self._kv_scores: Optional[dict] = None
        # measured draft acceptance per (draft_bits, act_bits) — k-independent
        self._draft_acceptance: dict = {}
        self._fixed_bytes: Optional[int] = None
        self.last: Optional[PlanResult] = None

    # -- probe caching ----------------------------------------------------

    def _ensure_scores(self, joint: bool) -> None:
        if self._tokens is None:
            self._tokens = sens.calibration_tokens(self.cfg.vocab)
        if self._scores is None:
            self._scores = sens.output_sensitivity(self.params, self.cfg, self._tokens, self.base)
        if joint and self._act_scores is None:
            self._act_scores = sens.activation_sensitivity(
                self.params, self.cfg, self._tokens, self.base
            )

    def fixed_bytes(self) -> int:
        """DRAM bytes of the leaves the plan cannot allocate (cached)."""
        if self._fixed_bytes is None:
            self._fixed_bytes = unquantized_bytes(self.params, self.base)
        return self._fixed_bytes

    def _tp_cost(self, cost: DecodeCostModel, plan: PlanSpec) -> DecodeCostModel:
        """Apply a plan's tensor-parallel knobs to a cost model: shard
        count, wire precision, and the model's all-reduce payload."""
        tp = plan.tp if isinstance(plan.tp, int) else 1
        if tp <= 1 and plan.wire is None:
            return cost
        from repro.planning.cost import tp_allreduce_elems

        return dataclasses.replace(
            cost,
            tp=max(tp, 1),
            wire_bits=plan.wire if plan.wire is not None else 32,
            allreduce_elems=(float(tp_allreduce_elems(self.cfg)) if tp > 1 else 0.0),
        )

    def budgets(self, slo: Slo, plan: Optional[PlanSpec] = None):
        """SLO -> (seconds, cycle budget, byte budget); monotone in the
        target: a higher tokens/s target can only shrink both budgets.
        With a tensor-parallel plan the budgets are per-shard (xM)."""
        cost = dataclasses.replace(self.cost, batch=slo.batch)
        if plan is not None:
            cost = self._tp_cost(cost, plan)
        return cost.budgets(slo, self.fixed_bytes())

    # -- solving ----------------------------------------------------------

    def solve(
        self, slo: Optional[Slo] = None, calib=None, plan: Optional[PlanSpec] = None
    ) -> PlanResult:
        """Solve the plan (optionally under an SLO) and price the result.

        ``calib``: measured activation batches for ``prt="measured"``
        pricing — one f32 [B, K] array or an ``ActivationTap.calib()``
        per-layer mapping; defaults to the cost model's batch.
        """
        plan = plan or self.plan
        kv_scores = None
        if plan.kv_bits == "auto":
            plan, kv_scores = self._resolve_kv(plan)
        if plan.tp == "auto":
            if slo is None and plan.target_tps is not None:
                slo = Slo(plan.target_tps, plan.slo_batch or self.cost.batch)
            plan = self._resolve_tp(plan, slo)
        if plan.mode != "auto":
            if plan.draft == "auto":
                # draft="auto" keeps the plan unsolved; the conservative
                # policy is already determined, so strip the draft to
                # materialize it for the acceptance probe and pricing
                conservative = dataclasses.replace(plan, draft=None).to_policy(self.base)
                plan = self._resolve_draft(plan, conservative, slo)
            policy = plan.to_policy(self.base)
            result = PlanResult(
                spec=plan,
                policy=policy,
                cost=self._price(policy, plan, calib, slo),
                kv_sensitivity=kv_scores,
            )
            self.last = result
            return result
        if slo is None and plan.target_tps is not None:
            slo = Slo(plan.target_tps, plan.slo_batch or self.cost.batch)
        joint = plan.act_bits is not None
        self._ensure_scores(joint)
        calib = calib if calib is not None else self.cost.calib
        kwargs: dict = {
            "scores": self._scores,
            "tokens": self._tokens,
            "max_segments": plan.max_segments,
            "machine": self.cost.machine,
            "cost_batch": slo.batch if slo is not None else self.cost.batch,
            "cost_threads": self.cost.threads,
        }
        if joint:
            kwargs.update(
                act_scores=self._act_scores,
                abits_candidates=sens.SUPPORTED_ABITS,
                match_uniform_abits=int(plan.act_bits),
                prt=_solver_prt(plan.prt),
                prt_calib=calib,
            )
        budgets = None
        if slo is not None:
            if not joint and not self.cost.include_dram:
                raise ValueError(
                    "a weight-only SLO solve needs the DRAM term: without it the "
                    "SLO only constrains cycles, which weight-only allocation "
                    "does not budget (add act bits for a joint solve, or enable "
                    "include_dram)"
                )
            budgets = self.budgets(slo, plan)
            if joint:
                kwargs["cycle_budget"] = budgets.cycle_budget
            if budgets.byte_budget is not None:
                kwargs["budget_bytes"] = budgets.byte_budget
        elif plan.budget_bpw is not None:
            kwargs["budget_bpw"] = plan.budget_bpw
        else:
            kwargs["match_uniform"] = int(plan.weight_bits)
        policy, report = sens.calibrate_policy(self.params, self.cfg, self.base, **kwargs)
        solved = self._solved_spec(plan, report, slo)
        if solved.draft == "auto":
            solved = self._resolve_draft(solved, policy, slo)
        result = PlanResult(
            spec=solved,
            policy=policy,
            report=report,
            cost=self._price(policy, plan, calib, slo),
            budgets=budgets,
            kv_sensitivity=kv_scores,
        )
        self.last = result
        return result

    def _resolve_kv(self, plan: PlanSpec):
        """Resolve ``kv_bits="auto"`` to a concrete 8 or 32.

        Runs the per-layer KV quantization probe (cached): int8 KV is
        adopted when the summed decode-logit error, relative to the
        reference logit power, stays within ``kv_tolerance`` — otherwise
        the plan keeps f32 KV and pays the bytes.
        """
        if self._kv_scores is None:
            if self._tokens is None:
                self._tokens = sens.calibration_tokens(self.cfg.vocab)
            self._kv_scores = sens.kv_sensitivity(self.params, self.cfg, self._tokens)
        bits = 8 if self._kv_scores["relative"] <= self.kv_tolerance else 32
        solved = dataclasses.replace(plan, kv_bits=bits, quant_kv=bits == 8)
        return solved, self._kv_scores

    #: ``tp="auto"`` search grid — shard counts worth pricing (powers of
    #: two; divisibility against the concrete model is the engine's check)
    TP_GRID = (1, 2, 4, 8)

    def _resolve_tp(self, plan: PlanSpec, slo: Optional[Slo]) -> PlanSpec:
        """Resolve ``tp="auto"`` to the smallest shard count meeting the
        SLO.

        Prices the plan's *anchor* precision (the uniform/rules policy,
        or the auto mode's match-uniform anchor) at each grid point under
        the full three-term model — more shards divide compute and DRAM
        but grow the wire term, so the sweep naturally stops helping once
        the plan goes wire-bound.  Without an SLO there is nothing to
        meet and the honest answer is ``tp=1``: sharding costs hardware
        and buys nothing the plan asked for."""
        if slo is None:
            return dataclasses.replace(plan, tp=1)
        anchor = self._anchor_policy(plan)
        chosen = self.TP_GRID[-1]
        for m in self.TP_GRID:
            cand = dataclasses.replace(plan, tp=int(m))
            cost = self._tp_cost(
                dataclasses.replace(
                    self.cost, batch=slo.batch, nbw=plan.nbw, prt=_solver_prt(plan.prt)
                ),
                cand,
            )
            modeled = cost.evaluate(self.params, anchor)
            if modeled.tokens_per_second >= slo.target_tps * (1 - 1e-9):
                chosen = int(m)
                break
        return dataclasses.replace(plan, tp=chosen)

    def _anchor_policy(self, plan: PlanSpec):
        """The policy ``_resolve_tp`` prices: the plan's own when it is
        directly servable, else the auto mode's match-uniform anchor."""
        probe = dataclasses.replace(plan, tp=None, draft=None)
        if probe.solved:
            return probe.to_policy(self.base)
        return dataclasses.replace(
            self.base,
            bits=int(plan.weight_bits) if plan.weight_bits is not None else self.base.bits,
            act_bits=plan.act_bits if plan.act_bits is not None else self.base.act_bits,
        )

    #: ``draft="auto"`` search grid — aggressive bit widths the draft tree
    #: may requantize to, and lookahead depths worth pricing.
    DRAFT_BITS_GRID = (2, 3, 4)
    DRAFT_K_GRID = (2, 3, 4, 6, 8)
    #: modeled tokens/s must beat plain decode by this factor before the
    #: planner commits a draft (draft=None is the honest answer otherwise)
    DRAFT_MIN_GAIN = 1.02

    def _resolve_draft(self, plan: PlanSpec, policy, slo: Optional[Slo]) -> PlanSpec:
        """Resolve ``draft="auto"`` to a concrete DraftSpec (or None).

        Grid search over (draft bits, lookahead k) maximizing modeled
        accepted tokens/s: ``batch * E[tokens/round] / round_seconds``,
        where the per-token acceptance of each bit width is *measured*
        (greedy teacher-forced agreement against the conservative tree,
        :func:`repro.serving.speculative.measure_acceptance`, cached — the
        probe is k-independent so the grid reuses it across k) and rounds
        are priced by :func:`~repro.planning.cost.speculative_round_seconds`
        under the DRAM-aware model.  A candidate only wins if it beats
        plain decode by ``DRAFT_MIN_GAIN``; otherwise the plan ships with
        ``draft=None`` — speculating would slow this plan down.
        """
        from repro.planning.cost import (
            expected_tokens_per_round,
            policy_units,
            speculative_round_seconds,
        )
        from repro.planning.spec import DraftSpec
        from repro.serving.speculative import draft_policy, measure_acceptance

        cost = dataclasses.replace(
            self.cost, batch=slo.batch if slo is not None else self.cost.batch
        )
        fixed = self.fixed_bytes()
        verify_units = policy_units(self.params, policy)
        plain_secs = cost.iteration_seconds(
            cost.cycles(verify_units), cost.qbytes(verify_units, policy.group_size) + fixed
        )
        plain_tps = cost.batch / plain_secs
        abits = plan.act_bits
        # probe on the same deterministic corpus the sensitivity probes use
        if self._tokens is None:
            self._tokens = sens.calibration_tokens(self.cfg.vocab)
        prompt = [int(t) for t in self._tokens[0]]
        best: Optional[tuple] = None  # (tps, DraftSpec)
        for bits in self.DRAFT_BITS_GRID:
            key = (int(bits), abits)
            if key not in self._draft_acceptance:
                self._draft_acceptance[key] = measure_acceptance(
                    self.params, self.cfg, policy, bits, act_bits=abits, prompt=prompt
                )
            alpha = self._draft_acceptance[key]
            d_units = policy_units(self.params, draft_policy(policy, DraftSpec(bits, abits, 1)))
            for k in self.DRAFT_K_GRID:
                secs = speculative_round_seconds(
                    cost, verify_units, d_units, policy.group_size, fixed, k
                )
                tps = cost.batch * expected_tokens_per_round(alpha, k) / secs
                if best is None or tps > best[0]:
                    best = (tps, DraftSpec(int(bits), abits, k, acceptance=alpha))
        if best is None or best[0] < plain_tps * self.DRAFT_MIN_GAIN:
            return dataclasses.replace(plan, draft=None)
        return dataclasses.replace(plan, draft=best[1])

    def _solved_spec(self, plan: PlanSpec, report, slo: Optional[Slo]) -> PlanSpec:
        assign = report.bits_by_unit
        joint = any(isinstance(s, (tuple, list)) for s in assign.values())
        if joint:
            weights = sens.spec_map_from_units({k: s[0] for k, s in assign.items()})
            acts = sens.spec_map_from_units({k: s[1] for k, s in assign.items()})
        else:
            weights, acts = sens.spec_map_from_units(assign), None
        return dataclasses.replace(
            plan,
            weights_per_unit=weights,
            acts_per_unit=acts,
            target_tps=slo.target_tps if slo is not None else plan.target_tps,
            slo_batch=slo.batch if slo is not None else plan.slo_batch,
            group_size=self.base.group_size,
            min_size=self.base.min_size,
        )

    def _price(self, policy, plan: PlanSpec, calib, slo: Optional[Slo]) -> PlanCost:
        # price at the SLO's batch when one is in play: lookup cycles
        # scale with batch, so budgets and the evaluation must agree
        cost = dataclasses.replace(
            self.cost,
            prt=_solver_prt(plan.prt),
            calib=calib if calib is not None else self.cost.calib,
            nbw=plan.nbw,
            batch=slo.batch if slo is not None else self.cost.batch,
        )
        return self._tp_cost(cost, plan).evaluate(self.params, policy)

    def _traffic_hit_rate(self, plan: PlanSpec, calib) -> float:
        """PRT hit rate of the captured traffic at the plan's operating
        point: the plan's NBW when fixed, else the cycle-optimal NBW for
        the traffic's own feature width at the plan's anchor precisions;
        per-layer batches average their per-layer rates (the headline
        number ``Engine.stats()['prt_hit_rate']`` tracks — the solver
        itself prices each unit's own layer separately)."""
        abits = plan.act_bits if plan.act_bits is not None else 8
        wbits = plan.weight_bits if plan.weight_bits is not None else 4
        batches = (
            [v for k, v in sorted(calib.items(), key=lambda kv: (kv[0] is None, kv[0]))
             if k is not None] or [calib[None]]
            if isinstance(calib, dict)
            else [calib]
        )
        rates = []
        for batch in batches:
            nbw = plan.nbw
            if not isinstance(nbw, int):
                k = int(batch.shape[-1])
                nbw = self.cost.best_nbw(k, k, wbits, abits)
            rates.append(pattern.prt_hit_rate(nbw, abits, batch))
        return float(sum(rates) / len(rates))

    # -- online recalibration ---------------------------------------------

    def replan(self, tap, resolve: bool = False, slo: Optional[Slo] = None) -> PlanResult:
        """Recalibrate against live traffic captured by an ActivationTap.

        Default: keep the current allocation and re-price it with PRT
        discounts measured on the tapped per-layer activations (cheap —
        no probes, no solve).  ``resolve=True`` additionally re-solves
        the allocation under the measured discounts (reusing the cached
        sensitivity probes).  Returns a PlanResult whose
        ``measured_prt_hit_rate`` is the traffic's PRT hit rate at the
        plan's (nbw, act-bits) operating point.
        """
        calib = tap.calib() if hasattr(tap, "calib") else tap
        if calib is None:
            raise ValueError("tap has captured no activations yet")
        base_plan = self.last.spec if self.last is not None else self.plan
        if slo is None and base_plan.target_tps is not None:
            # keep pricing (and meets_slo) at the batch the SLO was
            # quoted at, not the cost model's default
            slo = Slo(base_plan.target_tps, base_plan.slo_batch or self.cost.batch)
        plan = dataclasses.replace(base_plan, prt="measured")
        self.cost = dataclasses.replace(self.cost, prt="measured", calib=calib)
        hit = self._traffic_hit_rate(plan, calib)
        if resolve and plan.mode == "auto":
            fresh = dataclasses.replace(plan, weights_per_unit=None, acts_per_unit=None)
            result = self.solve(slo=slo, calib=calib, plan=fresh)
        else:
            policy = self.last.policy if self.last is not None else plan.to_policy(self.base)
            result = PlanResult(
                spec=plan,
                policy=policy,
                report=self.last.report if self.last is not None else None,
                cost=self._price(policy, plan, calib, slo),
            )
            self.last = result
        result.measured_prt_hit_rate = hit
        return result
