"""Precision planning: the single front door for mixed-precision serving.

``PlanSpec`` (the typed plan), ``DecodeCostModel`` (DRAM-aware pricing),
``Planner`` (offline solve + SLO budgets + online replan), and
``ActivationTap`` (live-traffic capture).  See ``repro/planning/spec.py``
for the object model and README "Planning API" for the migration story.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional

from repro.planning.calibrate_cost import (
    CalibrationResult,
    dispatch_from_json,
    machine_from_json,
    run_calibration,
)
from repro.planning.cost import (
    DEFAULT_LINK_BW,
    Budgets,
    DecodeCostModel,
    PlanCost,
    Slo,
    calib_for_layer,
    expected_tokens_per_round,
    kv_block_bytes,
    kv_pool_blocks,
    kv_token_bytes,
    policy_units,
    speculative_round_seconds,
    tp_allreduce_elems,
    unquantized_bytes,
)
from repro.planning.planner import Planner, PlanResult
from repro.planning.spec import DraftSpec, PlanRule, PlanSpec
from repro.planning.tap import ActivationTap

__all__ = [
    "ActivationTap",
    "Budgets",
    "CalibrationResult",
    "DEFAULT_LINK_BW",
    "DecodeCostModel",
    "DraftSpec",
    "PlanCost",
    "PlanRule",
    "PlanResult",
    "PlanSpec",
    "Planner",
    "Slo",
    "as_plan",
    "calib_for_layer",
    "dispatch_from_json",
    "expected_tokens_per_round",
    "kv_block_bytes",
    "kv_pool_blocks",
    "kv_token_bytes",
    "machine_from_json",
    "plan_from_arg",
    "policy_units",
    "resolve_plan",
    "speculative_round_seconds",
    "run_calibration",
    "tp_allreduce_elems",
    "unquantized_bytes",
]


def plan_from_arg(value: Any) -> PlanSpec:
    """CLI plan argument -> PlanSpec: an existing PlanSpec passes
    through; a string is loaded as a plan file when it exists on disk or
    ends in .json, else parsed as grammar.  The one sniffing rule every
    launcher shares."""
    import os

    if isinstance(value, PlanSpec):
        return value
    if isinstance(value, str) and (os.path.exists(value) or value.endswith(".json")):
        return PlanSpec.load(value)
    return as_plan(value)


def as_plan(obj: Any) -> PlanSpec:
    """Coerce any accepted plan form to a PlanSpec: an existing PlanSpec,
    a grammar string (the only place the legacy grammar enters), or a
    JSON/legacy dict."""
    if isinstance(obj, PlanSpec):
        return obj
    if isinstance(obj, str):
        return PlanSpec.parse(obj)
    if isinstance(obj, Mapping):
        return PlanSpec.from_json(obj)
    raise TypeError(f"cannot interpret {type(obj).__name__!r} as a PlanSpec")


def resolve_plan(
    plan: Any,
    params,
    cfg,
    base=None,
    slo: Optional[Slo] = None,
    cost: Optional[DecodeCostModel] = None,
    tokens=None,
    compute_cost: bool = False,
) -> PlanResult:
    """Plan -> servable PlanResult.

    Uniform/rules plans and *solved* auto plans (e.g. loaded from a
    ``plan.json``) resolve directly — no calibration runs.  Unsolved auto
    plans run a :class:`Planner` (sensitivity probes + joint solve,
    honoring ``slo``/``plan.target_tps``).  ``compute_cost`` prices the
    result under the DRAM-aware model (skipped by default: the engine
    hot path doesn't need it).
    """
    plan = as_plan(plan)
    if plan.solved:
        planner = Planner(params, cfg, plan, base=base, cost=cost, tokens=tokens)
        policy = plan.to_policy(planner.base)
        return PlanResult(
            spec=plan,
            policy=policy,
            cost=planner._price(policy, plan, None, slo) if compute_cost else None,
        )
    planner = Planner(params, cfg, plan, base=base, cost=cost, tokens=tokens)
    return planner.solve(slo=slo)
