"""PlanSpec: the typed source of truth for precision planning.

A serving deployment used to describe its precision configuration through
a string grammar (``--bit-policy "auto:q4a8,prt=measured,maxseg=4"``)
whose parsed dict was threaded differently through the engine, CLI,
benchmarks, and checkpoint manifests.  ``PlanSpec`` replaces that plumbing
with one frozen, JSON-serializable object:

  * the *request*: mode (uniform / rules / auto), the uniform ``ql`` and
    activation precision, regex rules, the auto-mode budget anchor
    (match-uniform bits, bits-per-weight, or an SLO target tokens/s),
    cost-model knobs (NBW, PRT mode, scan-segment cap), and the KV flag;
  * the *solution*: per-unit weight/activation bit assignments filled in
    by ``repro.planning.planner.Planner`` — a solved plan rebuilds its
    ``QuantPolicy`` (and therefore the exact mixed parameter tree)
    without re-running calibration.

The legacy string grammar survives as a thin :meth:`PlanSpec.parse` /
:meth:`PlanSpec.format` layer; ``repro.core.sensitivity.parse_bit_policy``
is now a deprecated shim over it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Any, Dict, Mapping, Optional, Tuple, Union

PLAN_VERSION = 1

_MODES = ("uniform", "rules", "auto")
_PRT_MODES = ("off", "paper", "measured")


def _bits_to_json(per_unit: Mapping[str, Any]) -> Dict[str, Any]:
    return {
        p: (list(map(int, b)) if isinstance(b, (tuple, list)) else int(b))
        for p, b in per_unit.items()
    }


def _bits_from_json(spec: Mapping[str, Any]) -> Dict[str, Any]:
    return {
        p: (tuple(int(x) for x in b) if isinstance(b, (list, tuple)) else int(b))
        for p, b in spec.items()
    }


def _parse_bits_token(tok: str) -> Tuple[Optional[int], Optional[int]]:
    """``"4"`` -> (4, None); ``"4a6"`` -> (4, 6); ``"a8"`` -> (None, 8)
    (an activation-only rule token)."""
    m = re.fullmatch(r"(\d+)?(?:a(\d+))?", tok.strip())
    if not m or (m.group(1) is None and m.group(2) is None):
        raise ValueError(f"bad bits token {tok!r} (expected <b>, <b>a<ab>, or a<ab>)")
    return (
        int(m.group(1)) if m.group(1) else None,
        int(m.group(2)) if m.group(2) else None,
    )


def _fmt_bits(bits: Optional[int], abits: Optional[int]) -> str:
    head = "" if bits is None else str(bits)
    return f"{head}a{abits}" if abits is not None else head


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """The draft half of a self-speculative plan.

    Self-speculative decoding serves ONE weight tree under two plans:
    ``k`` tokens are proposed per round with this aggressive low-bit
    precision and verified in one batched multi-token forward under the
    plan's own (conservative) precision.  ``acceptance`` records the
    measured greedy acceptance rate from the solver's calibration batch
    (None until a Planner measured it) — it feeds the expected
    accepted-tokens/s objective, not the serving datapath.

    Grammar token: ``q<b>[a<ab>]:k<k>`` (e.g. ``q2a8:k4``).
    """

    weight_bits: int = 4
    act_bits: Optional[int] = None
    k: int = 4
    acceptance: Optional[float] = None

    def __post_init__(self):
        from repro.core.quant import SUPPORTED_ABITS, SUPPORTED_BITS

        if self.weight_bits not in SUPPORTED_BITS:
            raise ValueError(
                f"draft weight_bits must be one of {SUPPORTED_BITS}, "
                f"got {self.weight_bits}"
            )
        if self.act_bits is not None and self.act_bits not in SUPPORTED_ABITS:
            raise ValueError(
                f"draft act_bits must be one of {SUPPORTED_ABITS} or None, got {self.act_bits}"
            )
        if self.k < 1:
            raise ValueError(f"draft k must be >= 1, got {self.k}")
        if self.acceptance is not None and not 0.0 <= self.acceptance <= 1.0:
            raise ValueError(f"draft acceptance must be in [0, 1], got {self.acceptance}")

    def format(self) -> str:
        return f"q{_fmt_bits(self.weight_bits, self.act_bits)}:k{self.k}"

    @staticmethod
    def parse(tok: str) -> "DraftSpec":
        m = re.fullmatch(r"q([^:]+):k(\d+)", tok.strip())
        if not m:
            raise ValueError(f"bad draft token {tok!r} (expected q<b>[a<ab>]:k<k> or auto)")
        bits, abits = _parse_bits_token(m.group(1))
        if bits is None:
            raise ValueError(f"draft token {tok!r} must pin weight bits")
        return DraftSpec(weight_bits=bits, act_bits=abits, k=int(m.group(2)))

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "weight_bits": int(self.weight_bits),
            "act_bits": self.act_bits,
            "k": int(self.k),
        }
        if self.acceptance is not None:
            out["acceptance"] = float(self.acceptance)
        return out

    @staticmethod
    def from_json(spec: Mapping[str, Any]) -> "DraftSpec":
        return DraftSpec(
            weight_bits=int(spec["weight_bits"]),
            act_bits=(int(spec["act_bits"]) if spec.get("act_bits") is not None else None),
            k=int(spec.get("k", 4)),
            acceptance=(
                float(spec["acceptance"]) if spec.get("acceptance") is not None else None
            ),
        )


def _coerce_draft(val) -> Optional[Union[str, "DraftSpec"]]:
    """None | "auto" | DraftSpec | grammar token | DraftSpec JSON dict."""
    if val is None or val == "auto" or isinstance(val, DraftSpec):
        return val
    if isinstance(val, str):
        return DraftSpec.parse(val)
    if isinstance(val, Mapping):
        return DraftSpec.from_json(val)
    raise ValueError(f"draft must be None, 'auto', a DraftSpec, a q<b>[a<ab>]:k<k> token, or a JSON dict; got {val!r}")


@dataclasses.dataclass(frozen=True)
class PlanRule:
    """One regex precision override: paths matching ``pattern`` serve at
    ``weight_bits`` (and ``act_bits`` activations when given).  A None
    ``weight_bits`` pins only the activation side (legacy independent
    ``act_rules`` entries); at least one side must be set."""

    pattern: str
    weight_bits: Optional[int]
    act_bits: Optional[int] = None

    def __post_init__(self):
        if self.weight_bits is None and self.act_bits is None:
            raise ValueError(f"rule {self.pattern!r} pins neither weights nor activations")

    def to_json(self) -> list:
        return [self.pattern, self.weight_bits, self.act_bits]

    @staticmethod
    def from_json(spec) -> "PlanRule":
        pat, wb = spec[0], spec[1]
        ab = spec[2] if len(spec) > 2 else None
        return PlanRule(
            pat,
            int(wb) if wb is not None else None,
            int(ab) if ab is not None else None,
        )


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """One precision-serving plan (request + optional solved allocation).

    ``weight_bits`` is the uniform ``ql`` (modes uniform/rules) or the
    match-uniform budget anchor (mode auto); ``act_bits`` is the lutmm
    activation precision (``None`` = f32 activations).  ``target_tps``
    turns an auto solve into an SLO solve: the Planner derives the cycle
    AND byte budgets from the target decode tokens/s at ``slo_batch``
    instead of matching the uniform reference's projected cycles.
    ``weights_per_unit`` / ``acts_per_unit`` carry the solved per-path
    (per-layer for scan stacks) assignment; a solved plan is the source
    of truth — checkpoints and ``--plan plan.json`` rebuild the policy
    from it with no recalibration.
    """

    mode: str = "uniform"
    # uniform precision / auto budget anchor; None (rules mode only)
    # inherits the serving default
    weight_bits: Optional[int] = 4
    act_bits: Optional[int] = None
    rules: Tuple[PlanRule, ...] = ()
    # auto-mode budget anchors (exactly one is used: target_tps wins,
    # then budget_bpw, else match-uniform at weight_bits/act_bits)
    budget_bpw: Optional[float] = None
    target_tps: Optional[float] = None
    slo_batch: Optional[int] = None
    # cost-model knobs
    nbw: Union[int, str] = "auto"
    prt: str = "paper"
    max_segments: Optional[int] = None
    # serving flags
    quant_kv: bool = True
    # KV-cache precision as a plan dimension: None (defer to the engine's
    # ``quant_kv`` flag), "auto" (Planner probes per-layer KV sensitivity
    # and picks 8 vs 32), or a concrete 8 / 32.  int8 KV shrinks every
    # paged block, so the same byte budget admits more concurrent users.
    kv_bits: Optional[Union[int, str]] = None
    group_size: Optional[int] = None
    min_size: Optional[int] = None
    # self-speculative draft plan: None (no speculation), "auto" (the
    # Planner grid-solves (draft bits, k) for expected accepted tokens/s
    # against a calibration-measured acceptance curve), or a concrete
    # DraftSpec / "q<b>[a<ab>]:k<k>" token.  Joined the schema in PR 9;
    # omitted from JSON when unset so older plan hashes are unchanged.
    draft: Optional[Union[str, "DraftSpec"]] = None
    # tensor-parallel shard count as the plan's fifth axis: None (defer
    # to the engine's ``tp`` flag), "auto" (Planner picks the smallest
    # shard count that meets the SLO — trading bits against shards at a
    # fixed target), or a concrete M.  ``wire`` is the all-reduce
    # precision (32 exact, 8 int8+scale compressed partial sums).
    # Joined the schema in PR 10; omitted from JSON when unset so older
    # plan hashes are unchanged.
    tp: Optional[Union[int, str]] = None
    wire: Optional[int] = None
    # solved allocation (None until a Planner ran)
    weights_per_unit: Optional[Mapping[str, Any]] = None
    acts_per_unit: Optional[Mapping[str, Any]] = None
    # measured-hardware provenance: fitted cost-model constants from
    # ``planning.calibrate_cost`` (``CalibrationResult.provenance()``).
    # When present, Planner budgets against the fitted machine, and the
    # saved plan records exactly which hardware it was priced for.
    calibration: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.prt not in _PRT_MODES:
            raise ValueError(f"prt must be one of {_PRT_MODES}, got {self.prt!r}")
        if not (self.nbw == "auto" or int(self.nbw) in (1, 2, 3, 4)):
            raise ValueError(f"nbw must be 'auto' or 1..4, got {self.nbw!r}")
        from repro.core.quant import SUPPORTED_ABITS, SUPPORTED_BITS

        if self.weight_bits is None:
            if self.mode != "rules":
                raise ValueError("weight_bits may only be None in rules mode")
        elif self.budget_bpw is None and self.weight_bits not in SUPPORTED_BITS:
            raise ValueError(f"weight_bits must be one of {SUPPORTED_BITS}, got {self.weight_bits}")
        if self.act_bits is not None and self.act_bits not in SUPPORTED_ABITS:
            raise ValueError(
                f"act_bits must be one of {SUPPORTED_ABITS} or None, got {self.act_bits}"
            )
        if self.max_segments is not None and self.max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {self.max_segments}")
        if self.target_tps is not None and self.target_tps <= 0:
            raise ValueError(f"target_tps must be positive, got {self.target_tps}")
        if self.kv_bits not in (None, "auto", 8, 32):
            raise ValueError(f"kv_bits must be None, 'auto', 8, or 32, got {self.kv_bits!r}")
        if not (self.tp is None or self.tp == "auto" or
                (isinstance(self.tp, int) and self.tp >= 1)):
            raise ValueError(f"tp must be None, 'auto', or an int >= 1, got {self.tp!r}")
        if self.wire not in (None, 8, 32):
            raise ValueError(f"wire must be None, 8, or 32, got {self.wire!r}")
        object.__setattr__(self, "draft", _coerce_draft(self.draft))

    # -- solved state -----------------------------------------------------

    @property
    def solved(self) -> bool:
        """Auto plans become solved once a Planner filled the per-unit
        assignment; uniform/rules plans are directly servable.  A
        ``kv_bits`` of ``"auto"`` keeps any plan unsolved — the Planner
        must first probe KV sensitivity and pin a concrete 8 or 32.  A
        ``draft`` of ``"auto"`` likewise: the Planner must grid-solve
        the (draft bits, k) pair against measured acceptance first; a
        ``tp`` of ``"auto"`` needs the Planner to pin a shard count."""
        if self.kv_bits == "auto" or self.draft == "auto" or self.tp == "auto":
            return False
        return self.mode != "auto" or self.weights_per_unit is not None

    def with_solution(self, weights_per_unit, acts_per_unit=None) -> "PlanSpec":
        return dataclasses.replace(
            self,
            weights_per_unit=dict(weights_per_unit),
            acts_per_unit=dict(acts_per_unit) if acts_per_unit else None,
        )

    @property
    def spec_hash(self) -> str:
        """Stable content hash (provenance key in ``Engine.stats()`` and
        serve-bench artifacts — plan churn shows up as hash churn)."""
        blob = json.dumps(self.to_json(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    # -- string grammar (backward compat) ---------------------------------

    @staticmethod
    def parse(spec: str) -> "PlanSpec":
        """Parse the legacy ``--bit-policy`` grammar into a PlanSpec.

          uniform:<b>[a<ab>][,kv=...][,draft=...][,tp=...][,wire=...]
                                              one precision everywhere
          rules:<regex>=<b>[a<ab>],...        per-path overrides
                                              (``default=``/``*=`` sets the
                                              fallback precision)
          auto:q<b>[a<ab>][,<opt>...]         calibrated allocation within
                                              the uniform-(b[, ab]) budget
          auto:<f>bpw[,<opt>...]              ... within f bits/weight

        Auto options: ``prt=off|paper|measured``, ``maxseg=<n>``,
        ``a=<ab>``, ``kv=8|32|auto`` (KV-cache precision; ``auto`` probes
        per-layer KV sensitivity), ``slo=<tps>`` (derive the budgets
        from a target decode tokens/s instead of the uniform reference),
        and ``draft=q<b>[a<ab>]:k<k>|auto`` (self-speculative draft
        plan; ``auto`` grid-solves the draft-bits/k pair on measured
        acceptance).  ``tp=<M>|auto`` shards the quantized weight tree
        M ways (``auto`` picks the smallest M meeting the SLO) and
        ``wire=8|32`` sets the all-reduce precision.  ``kv=``,
        ``draft=``, ``tp=``, and ``wire=`` also apply to uniform mode.
        """
        kind, _, rest = spec.partition(":")
        if kind == "uniform":
            head, *opts = [p.strip() for p in rest.split(",") if p.strip()]
            bits, abits = _parse_bits_token(head)
            kw: Dict[str, Any] = {}
            for opt in opts:
                key, _, val = opt.partition("=")
                if key == "kv":
                    kw["kv_bits"] = val if val == "auto" else int(val)
                elif key == "draft":
                    kw["draft"] = val if val == "auto" else DraftSpec.parse(val)
                elif key == "tp":
                    kw["tp"] = val if val == "auto" else int(val)
                elif key == "wire":
                    kw["wire"] = int(val)
                else:
                    raise ValueError(
                        f"unknown uniform option {opt!r} in {spec!r} "
                        "(only kv=8|32|auto, draft=q<b>[a<ab>]:k<k>|auto, "
                        "tp=<M>|auto, and wire=8|32)")
            return PlanSpec(mode="uniform", weight_bits=bits,
                            act_bits=abits, **kw)
        if kind == "rules":
            rules = []
            default_bits, default_act = None, None
            for part in filter(None, rest.split(",")):
                pat, _, b = part.rpartition("=")
                if not pat:
                    raise ValueError(f"bad rule {part!r} in {spec!r}")
                bits, abits = _parse_bits_token(b)
                if pat in ("default", "*"):
                    default_bits, default_act = bits, abits
                else:
                    rules.append(PlanRule(pat, bits, abits))
            return PlanSpec(
                mode="rules",
                weight_bits=default_bits,
                act_bits=default_act,
                rules=tuple(rules),
            )
        if kind == "auto":
            parts = [p.strip() for p in rest.split(",") if p.strip()]
            if not parts:
                raise ValueError(f"empty auto spec {spec!r}")
            budget = parts[0]
            kw: Dict[str, Any] = {"mode": "auto"}
            if budget.startswith("q"):
                bits, abits = _parse_bits_token(budget[1:])
                kw["weight_bits"] = bits
                kw["act_bits"] = abits
            elif budget.endswith("bpw"):
                kw["budget_bpw"] = float(budget[:-3])
            else:
                raise ValueError(f"auto budget must be q<b>[a<ab>] or <f>bpw, got {budget!r}")
            for opt in parts[1:]:
                key, _, val = opt.partition("=")
                if key == "prt":
                    if val not in _PRT_MODES:
                        raise ValueError(f"prt must be off|paper|measured, got {val!r}")
                    kw["prt"] = val
                elif key == "maxseg":
                    if int(val) < 1:
                        raise ValueError(f"maxseg must be >= 1, got {val}")
                    kw["max_segments"] = int(val)
                elif key == "a":
                    kw["act_bits"] = int(val)
                elif key == "kv":
                    kw["kv_bits"] = val if val == "auto" else int(val)
                elif key == "slo":
                    kw["target_tps"] = float(val)
                elif key == "draft":
                    kw["draft"] = val if val == "auto" else DraftSpec.parse(val)
                elif key == "tp":
                    kw["tp"] = val if val == "auto" else int(val)
                elif key == "wire":
                    kw["wire"] = int(val)
                else:
                    raise ValueError(f"unknown auto option {opt!r} in {spec!r}")
            return PlanSpec(**kw)
        raise ValueError(f"unknown bit policy {spec!r} (expected uniform:/rules:/auto:)")

    def format(self) -> str:
        """Canonical grammar string of the *request* (the inverse of
        :meth:`parse` up to spec equivalence; the solved per-unit
        assignment has no grammar form — serialize those as JSON)."""
        if self.mode == "uniform":
            head = f"uniform:{_fmt_bits(self.weight_bits, self.act_bits)}"
            if self.kv_bits is not None:
                head += f",kv={self.kv_bits}"
            if self.draft is not None:
                head += f",draft={self._fmt_draft()}"
            if self.tp is not None:
                head += f",tp={self.tp}"
            if self.wire is not None:
                head += f",wire={self.wire}"
            return head
        if self.mode == "rules":
            parts = [f"{r.pattern}={_fmt_bits(r.weight_bits, r.act_bits)}" for r in self.rules]
            if self.weight_bits is not None or self.act_bits is not None:
                parts.append(f"default={_fmt_bits(self.weight_bits, self.act_bits)}")
            return "rules:" + ",".join(parts)
        if self.budget_bpw is not None:
            head = f"auto:{self.budget_bpw}bpw"
        else:
            head = f"auto:q{_fmt_bits(self.weight_bits, self.act_bits)}"
        opts = []
        if self.prt != "paper":
            opts.append(f"prt={self.prt}")
        if self.max_segments is not None:
            opts.append(f"maxseg={self.max_segments}")
        if self.kv_bits is not None:
            opts.append(f"kv={self.kv_bits}")
        if self.target_tps is not None:
            opts.append(f"slo={self.target_tps:g}")
        if self.draft is not None:
            opts.append(f"draft={self._fmt_draft()}")
        if self.tp is not None:
            opts.append(f"tp={self.tp}")
        if self.wire is not None:
            opts.append(f"wire={self.wire}")
        return ",".join([head] + opts)

    def _fmt_draft(self) -> str:
        return self.draft if isinstance(self.draft, str) else self.draft.format()

    # -- JSON round-trip --------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "version": PLAN_VERSION,
            "mode": self.mode,
            "weight_bits": int(self.weight_bits) if self.weight_bits is not None else None,
            "act_bits": self.act_bits,
            "nbw": self.nbw,
            "prt": self.prt,
            "quant_kv": bool(self.quant_kv),
        }
        if self.rules:
            out["rules"] = [r.to_json() for r in self.rules]
        # kv_bits joined the schema in PR 8, tp/wire in PR 10; omitted
        # when unset so older plan hashes are unchanged
        keys = (
            "budget_bpw",
            "target_tps",
            "slo_batch",
            "max_segments",
            "kv_bits",
            "group_size",
            "min_size",
            "tp",
            "wire",
        )
        for key in keys:
            val = getattr(self, key)
            if val is not None:
                out[key] = val
        if self.draft is not None:
            out["draft"] = self.draft if isinstance(self.draft, str) else self.draft.to_json()
        if self.weights_per_unit is not None:
            out["weights_per_unit"] = _bits_to_json(self.weights_per_unit)
        if self.acts_per_unit is not None:
            out["acts_per_unit"] = _bits_to_json(self.acts_per_unit)
        if self.calibration is not None:
            out["calibration"] = dict(self.calibration)
        return out

    @staticmethod
    def from_json(spec: Mapping[str, Any]) -> "PlanSpec":
        if "weight_bits" not in spec and "mode" in spec:
            # legacy parse_bit_policy dict (pre-PlanSpec engine configs)
            return PlanSpec.from_legacy_dict(spec)
        version = int(spec.get("version", PLAN_VERSION))
        if version > PLAN_VERSION:
            raise ValueError(f"plan version {version} is newer than {PLAN_VERSION}")
        wpu = spec.get("weights_per_unit")
        apu = spec.get("acts_per_unit")
        cal = spec.get("calibration")
        return PlanSpec(
            mode=spec.get("mode", "uniform"),
            weight_bits=(
                int(spec["weight_bits"]) if spec.get("weight_bits") is not None else None
            ),
            act_bits=(int(spec["act_bits"]) if spec.get("act_bits") is not None else None),
            rules=tuple(PlanRule.from_json(r) for r in spec.get("rules", ())),
            budget_bpw=(float(spec["budget_bpw"]) if spec.get("budget_bpw") is not None else None),
            target_tps=(float(spec["target_tps"]) if spec.get("target_tps") is not None else None),
            slo_batch=(int(spec["slo_batch"]) if spec.get("slo_batch") is not None else None),
            nbw=spec.get("nbw", "auto"),
            prt=spec.get("prt", "paper"),
            max_segments=(
                int(spec["max_segments"]) if spec.get("max_segments") is not None else None
            ),
            quant_kv=bool(spec.get("quant_kv", True)),
            kv_bits=(
                spec.get("kv_bits")
                if spec.get("kv_bits") in (None, "auto")
                else int(spec["kv_bits"])
            ),
            group_size=(int(spec["group_size"]) if spec.get("group_size") is not None else None),
            min_size=(int(spec["min_size"]) if spec.get("min_size") is not None else None),
            draft=_coerce_draft(spec.get("draft")),
            tp=(
                spec.get("tp")
                if spec.get("tp") in (None, "auto")
                else int(spec["tp"])
            ),
            wire=(int(spec["wire"]) if spec.get("wire") is not None else None),
            weights_per_unit=(_bits_from_json(wpu) if wpu is not None else None),
            acts_per_unit=(_bits_from_json(apu) if apu is not None else None),
            calibration=(dict(cal) if cal is not None else None),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @staticmethod
    def load(path: str) -> "PlanSpec":
        with open(path) as f:
            return PlanSpec.from_json(json.load(f))

    # -- legacy dict bridge (parse_bit_policy's output format) ------------

    def to_legacy_dict(self) -> Dict[str, Any]:
        """The exact dict :func:`repro.core.sensitivity.parse_bit_policy`
        used to return — the deprecated shim's return value."""
        if self.mode == "uniform":
            out: Dict[str, Any] = {"mode": "uniform", "bits": int(self.weight_bits)}
            if self.act_bits is not None:
                out["abits"] = int(self.act_bits)
            return out
        if self.mode == "rules":
            out = {
                "mode": "rules",
                "rules": [
                    (r.pattern, int(r.weight_bits))
                    for r in self.rules
                    if r.weight_bits is not None
                ],
            }
            act_rules = [(r.pattern, int(r.act_bits)) for r in self.rules if r.act_bits is not None]
            if act_rules:
                out["act_rules"] = act_rules
            if self.weight_bits is not None:
                out["bits"] = int(self.weight_bits)
            if self.act_bits is not None:
                out["abits"] = int(self.act_bits)
            return out
        out = {"mode": "auto"}
        if self.budget_bpw is not None:
            out["budget_bpw"] = float(self.budget_bpw)
        else:
            out["match_uniform"] = int(self.weight_bits)
        if self.act_bits is not None:
            out["abits"] = int(self.act_bits)
        if self.prt != "paper":
            out["prt"] = self.prt
        if self.max_segments is not None:
            out["max_segments"] = int(self.max_segments)
        if self.target_tps is not None:
            out["target_tps"] = float(self.target_tps)
        return out

    @staticmethod
    def from_legacy_dict(spec: Mapping[str, Any]) -> "PlanSpec":
        spec = dict(spec)
        mode = spec.pop("mode", None)
        known = {
            "bits",
            "abits",
            "rules",
            "act_rules",
            "match_uniform",
            "budget_bpw",
            "prt",
            "max_segments",
            "target_tps",
        }
        extra = set(spec) - known
        if extra:
            raise ValueError(
                f"unsupported legacy bit_policy keys {sorted(extra)} — these "
                "solver options moved to repro.planning.Planner / "
                "repro.core.sensitivity.calibrate_policy"
            )
        if mode == "uniform":
            return PlanSpec(
                mode="uniform",
                weight_bits=int(spec["bits"]),
                act_bits=(int(spec["abits"]) if spec.get("abits") is not None else None),
            )
        if mode == "rules":
            act = {p: int(b) for p, b in spec.get("act_rules", ())}
            rules = tuple(PlanRule(p, int(b), act.pop(p, None)) for p, b in spec.get("rules", ()))
            # act-only patterns (no weight rule) keep their own entry —
            # resolve_bit_policy applied the two rule lists independently
            rules += tuple(PlanRule(p, None, b) for p, b in act.items())
            bits = spec.get("bits")
            return PlanSpec(
                mode="rules",
                weight_bits=int(bits) if bits is not None else None,
                act_bits=(int(spec["abits"]) if spec.get("abits") is not None else None),
                rules=rules,
            )
        if mode == "auto":
            kw: Dict[str, Any] = {"mode": "auto"}
            if "match_uniform" in spec:
                kw["weight_bits"] = int(spec["match_uniform"])
            if spec.get("budget_bpw") is not None:
                kw["budget_bpw"] = float(spec["budget_bpw"])
            if spec.get("abits") is not None:
                kw["act_bits"] = int(spec["abits"])
            if spec.get("prt") is not None:
                kw["prt"] = spec["prt"]
            if spec.get("max_segments") is not None:
                kw["max_segments"] = int(spec["max_segments"])
            if spec.get("target_tps") is not None:
                kw["target_tps"] = float(spec["target_tps"])
            return PlanSpec(**kw)
        raise ValueError(f"unknown legacy bit_policy dict mode {mode!r}")

    # -- QuantPolicy bridge ------------------------------------------------

    def to_policy(self, base=None):
        """Materialize the ``QuantPolicy`` this plan serves with.

        ``base`` supplies the serving defaults the plan doesn't pin
        (group_size / min_size / codebook / fallback act_bits).  Unsolved
        auto plans raise — run them through a ``Planner`` first.
        """
        from repro.models.sail_linear import BitAllocation, QuantPolicy

        base = base or QuantPolicy()
        if not self.solved:
            raise ValueError(
                "auto plan has no solved allocation — use repro.planning."
                "Planner.solve (or Engine/resolve_plan, which run it)"
            )
        kw: Dict[str, Any] = {
            "group_size": self.group_size if self.group_size is not None else base.group_size,
            "min_size": self.min_size if self.min_size is not None else base.min_size,
        }
        if self.mode == "uniform":
            return dataclasses.replace(
                base,
                bits=int(self.weight_bits),
                act_bits=self.act_bits if self.act_bits is not None else base.act_bits,
                **kw,
            )
        if self.mode == "rules":
            return dataclasses.replace(
                base,
                bits=int(self.weight_bits) if self.weight_bits is not None else base.bits,
                rules=tuple(
                    (r.pattern, int(r.weight_bits))
                    for r in self.rules
                    if r.weight_bits is not None
                ),
                act_rules=tuple(
                    (r.pattern, int(r.act_bits)) for r in self.rules if r.act_bits is not None
                ),
                act_bits=self.act_bits if self.act_bits is not None else base.act_bits,
                **kw,
            )
        allocation = BitAllocation(
            per_path=dict(self.weights_per_unit),
            act_per_path=dict(self.acts_per_unit or {}),
        )
        return dataclasses.replace(
            base,
            bits=int(self.weight_bits),
            act_bits=self.act_bits if self.act_bits is not None else base.act_bits,
            allocation=allocation,
            **kw,
        )

    @staticmethod
    def from_policy(policy, quant_kv: bool = True) -> "PlanSpec":
        """Best-effort PlanSpec for an explicit ``QuantPolicy`` (legacy
        ``bit_policy=QuantPolicy(...)`` configs and checkpoint manifests)
        — the codebook, which is not plan state, stays on the policy."""
        alloc = policy.allocation
        if alloc is not None:
            return PlanSpec(
                mode="auto",
                weight_bits=int(policy.bits),
                act_bits=policy.act_bits,
                quant_kv=quant_kv,
                group_size=int(policy.group_size),
                min_size=int(policy.min_size),
                weights_per_unit=dict(alloc.per_path),
                acts_per_unit=(dict(alloc.act_per_path) if alloc.act_per_path else None),
            )
        if policy.rules or policy.act_rules:
            act = {p: int(b) for p, b in policy.act_rules}
            rules = tuple(PlanRule(p, int(b), act.pop(p, None)) for p, b in policy.rules)
            rules += tuple(PlanRule(p, None, b) for p, b in act.items())
            return PlanSpec(
                mode="rules",
                weight_bits=int(policy.bits),
                act_bits=policy.act_bits,
                rules=rules,
                quant_kv=quant_kv,
                group_size=int(policy.group_size),
                min_size=int(policy.min_size),
            )
        return PlanSpec(
            mode="uniform",
            weight_bits=int(policy.bits),
            act_bits=policy.act_bits,
            quant_kv=quant_kv,
            group_size=int(policy.group_size),
            min_size=int(policy.min_size),
        )
