"""Fit DecodeCostModel constants to *measured* kernel timings.

The planning stack up to PR 5 budgeted against the paper's calibrated
C-SRAM constants — modeled hardware.  This module holds the cost model to
measurement instead: it times the jitted LUT-GEMV kernels across the
(wbits, abits, NBW) grid on the attached backend, fits the SailMachine
dataflow constants (LUT build overhead, per-group control cost, lookup
base/slope) by linear least squares in cycle space, and measures the
achievable stream bandwidth so the DRAM side of the ping-pong model
(``t_iter = max(t_dram, t_compute)``) is bounded by real hardware too.

The fitted constants persist into ``PlanSpec.calibration`` provenance, so
a plan solved against measured hardware records exactly which machine it
was priced for — ``Planner.solve(slo=...)`` then budgets tokens/s against
numbers a kernel actually achieved, not numbers a model hoped for.

The timing target is ``repro.core.lut_gemv.lut_gemv`` — the faithful
bit-serial LUT-GEMV whose executed work genuinely varies along the
(nbw, abits) axes the cost model prices (``2**nbw`` LUT entries, ``K/nbw``
groups, ``abits`` bit-planes), exactly the structure of
``cost_model.lut_gemv_cycles``.

Host backends add a fixed per-invocation dispatch overhead the dataflow
model has no column for — at low (wbits, abits, nbw) the kernel's real
work shrinks until that constant dominates, which is exactly where the
pre-PR-10 fit's worst grid point (~0.69 relative error) lived.  The fit
therefore carries one extra *indicator column per (NBW, abits) cell*: a
fixed cycle count charged per kernel call, fitted jointly with the
dataflow constants.  The per-cell split matters because the trace each
(NBW, abits) pair compiles to differs in fixed structure (LUT build
fan-in and the bit-plane loop count), not just in per-element work —
measured grids show e.g. the (nbw=1, abits=8) cell sitting ~4x off the
neighboring cells while the wbits axis within a cell moves only with
timing noise.  The fitted ``dispatch_cycles`` ride the provenance into
``PlanSpec.calibration`` and ``DecodeCostModel.dispatch_cycles``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import SailMachine, lut_gemv_cycles

# Machine fields a calibration is allowed to override.  Everything else
# (frequency, array geometry, ...) stays structural.
FITTED_FIELDS = (
    "lookup_base_cycles",
    "lookup_per_bit_cycles",
    "rebuild_ctrl_cycles",
    "build_overhead",
    "dram_bw",
    "dram_efficiency",
)

DEFAULT_WBITS = (2, 4, 8)
DEFAULT_ABITS = (4, 6, 8)
DEFAULT_NBW = (1, 2, 3, 4)


def timeit_s(fn, *args, iters: int = 10) -> float:
    """Median wall seconds per call (one warmup, whole result blocked)."""
    import jax

    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Fitted machine constants + the measurements behind them."""

    machine_overrides: Dict[str, float]
    points: Tuple[Mapping[str, Any], ...]  # per grid point: config + errors
    shape: Tuple[int, int, int]  # (batch, k, n) timed
    backend: str
    max_rel_err: float
    mean_rel_err: float
    dram_bw_measured: float
    # fitted per-(NBW, abits) fixed dispatch overhead (cycles per kernel
    # call); empty when the fit ran without dispatch columns (pre-PR-10
    # artifacts)
    dispatch_cycles: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)

    def machine(self, base: Optional[SailMachine] = None) -> SailMachine:
        base = base if base is not None else SailMachine()
        return dataclasses.replace(base, **self.machine_overrides)

    def cost_model(self, **kwargs):
        from repro.planning.cost import DecodeCostModel

        if self.dispatch_cycles and "dispatch_cycles" not in kwargs:
            kwargs["dispatch_cycles"] = tuple(sorted(self.dispatch_cycles.items()))
        return DecodeCostModel(machine=self.machine(), **kwargs)

    def provenance(self) -> Dict[str, Any]:
        """Compact JSON-safe record for ``PlanSpec.calibration``."""
        out = {
            "machine_overrides": {k: float(v) for k, v in self.machine_overrides.items()},
            "backend": self.backend,
            "shape": list(self.shape),
            "max_rel_err": float(self.max_rel_err),
            "mean_rel_err": float(self.mean_rel_err),
            "dram_bw_measured": float(self.dram_bw_measured),
        }
        if self.dispatch_cycles:
            out["dispatch_cycles"] = {
                f"{nbw}:{ab}": float(v)
                for (nbw, ab), v in sorted(self.dispatch_cycles.items())
            }
        return out

    def to_json(self) -> Dict[str, Any]:
        d = self.provenance()
        d["points"] = [dict(p) for p in self.points]
        return d

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "CalibrationResult":
        pts = tuple(dict(p) for p in d.get("points", ()))
        return CalibrationResult(
            machine_overrides={k: float(v) for k, v in d["machine_overrides"].items()},
            points=pts,
            shape=tuple(int(s) for s in d["shape"]),
            backend=str(d.get("backend", "unknown")),
            max_rel_err=float(d["max_rel_err"]),
            mean_rel_err=float(d["mean_rel_err"]),
            dram_bw_measured=float(d.get("dram_bw_measured", 0.0)),
            dispatch_cycles=_parse_dispatch(d.get("dispatch_cycles", {})),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @staticmethod
    def load(path: str) -> "CalibrationResult":
        with open(path) as f:
            return CalibrationResult.from_json(json.load(f))


def machine_from_json(
    calibration: Mapping[str, Any], base: Optional[SailMachine] = None
) -> SailMachine:
    """``PlanSpec.calibration`` provenance -> fitted SailMachine."""
    base = base if base is not None else SailMachine()
    overrides = {
        k: float(v)
        for k, v in calibration.get("machine_overrides", {}).items()
        if k in FITTED_FIELDS
    }
    return dataclasses.replace(base, **overrides)


def _parse_dispatch(disp: Mapping[Any, Any]) -> Dict[Tuple[int, int], float]:
    """JSON ``"nbw:abits" -> cycles`` mapping (or in-memory tuple keys)
    back to the ``{(nbw, abits): cycles}`` form."""
    out: Dict[Tuple[int, int], float] = {}
    for key, v in disp.items():
        if isinstance(key, str):
            nbw, ab = key.split(":")
        else:
            nbw, ab = key
        out[(int(nbw), int(ab))] = float(v)
    return out


def dispatch_from_json(
    calibration: Mapping[str, Any],
) -> Optional[Tuple[Tuple[Tuple[int, int], float], ...]]:
    """``PlanSpec.calibration`` provenance -> the hashable per-(NBW,
    abits) dispatch table ``DecodeCostModel.dispatch_cycles`` takes (None
    when the calibration predates the dispatch fit)."""
    disp = calibration.get("dispatch_cycles")
    if not disp:
        return None
    return tuple(sorted(_parse_dispatch(disp).items()))


def _design_row(
    m: SailMachine, batch: int, k: int, n: int, nbw: int, wbits: int, abits: int
) -> np.ndarray:
    """Feature vector so that cycles = row @ theta with
    theta = [build_overhead, rebuild_ctrl_cycles, lookup_base_cycles,
             lookup_per_bit_cycles] (threads=1, no PRT discount)."""
    import math

    arrays = m.arrays_per_thread
    n_tiles = math.ceil(n / m.array_cols)
    scale = n_tiles * (k / nbw) / arrays
    entry_bits = wbits + max(1, math.ceil(math.log2(max(nbw, 2))))
    n_adds = max((1 << nbw) - nbw - 1, 0)
    adds_load = n_adds * m.add_cycles(entry_bits) + nbw * 2.0
    ctrl_shape = (2.0 / nbw) ** m.rebuild_nbw_exp
    return scale * np.array([adds_load, ctrl_shape, batch * abits, batch * abits * wbits])


def fit_constants(
    points: Sequence[Mapping[str, Any]],
    batch: int,
    k: int,
    n: int,
    machine_base: Optional[SailMachine] = None,
    fit_dispatch: bool = False,
):
    """Least-squares fit of the dataflow constants in cycle space.

    ``points``: dicts with wbits/abits/nbw/t_s.  Cycles are taken at the
    machine's nominal frequency — on a host backend the fitted constants
    become *effective* costs for this host, which is exactly what an SLO
    budget needs.  Negative solutions are clipped to zero and the
    remaining columns refit (non-negative constants only).

    ``fit_dispatch=True`` adds one indicator column per distinct (NBW,
    abits) cell — a fixed per-invocation overhead (module docstring) —
    and returns ``(constants, dispatch_cycles)`` instead of the bare
    constants dict.
    """
    m = machine_base if machine_base is not None else SailMachine()
    feats = [_design_row(m, batch, k, n, p["nbw"], p["wbits"], p["abits"]) for p in points]
    rows = np.stack(feats)
    cells: List[Tuple[int, int]] = []
    if fit_dispatch:
        cells = sorted({(int(p["nbw"]), int(p["abits"])) for p in points})
        ind = np.zeros((rows.shape[0], len(cells)))
        for i, p in enumerate(points):
            ind[i, cells.index((int(p["nbw"]), int(p["abits"])))] = 1.0
        rows = np.concatenate([rows, ind], axis=1)
    target = np.array([p["t_s"] * m.freq_hz for p in points])
    # weight by 1/measured so the solve minimizes *relative* error — the
    # quantity the CI gate bounds — instead of letting the slowest grid
    # points dominate the residual
    rows = rows / target[:, None]
    target = np.ones_like(target)
    active = list(range(rows.shape[1]))
    theta = np.zeros(rows.shape[1])
    for _ in range(rows.shape[1]):
        sol, *_ = np.linalg.lstsq(rows[:, active], target, rcond=None)
        if (sol >= 0).all():
            theta[active] = sol
            break
        active = [a for a, s in zip(active, sol) if s >= 0]
        if not active:
            break
    constants = {
        "build_overhead": float(theta[0]),
        "rebuild_ctrl_cycles": float(theta[1]),
        "lookup_base_cycles": float(theta[2]),
        "lookup_per_bit_cycles": float(theta[3]),
    }
    if not fit_dispatch:
        return constants
    dispatch = {cell: float(theta[4 + i]) for i, cell in enumerate(cells)}
    return constants, dispatch


def measure_stream_bandwidth(nbytes: int = 64 * 2**20, iters: int = 5) -> float:
    """Achievable stream bandwidth (bytes/s): read + write one big array."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((nbytes // 4,), jnp.float32)
    f = jax.jit(lambda a: a * 1.0000001)
    t = timeit_s(f, a, iters=iters)
    return 2.0 * nbytes / t


def run_calibration(
    batch: int = 8,
    k: int = 512,
    n: int = 256,
    wbits_grid: Sequence[int] = DEFAULT_WBITS,
    abits_grid: Sequence[int] = DEFAULT_ABITS,
    nbw_grid: Sequence[int] = DEFAULT_NBW,
    iters: int = 10,
    machine_base: Optional[SailMachine] = None,
) -> CalibrationResult:
    """Time the LUT-GEMV grid, fit constants, report modeled-vs-measured."""
    import jax

    from repro.core import lut_gemv as lg

    m = machine_base if machine_base is not None else SailMachine()
    key = jax.random.PRNGKey(0)
    raw: List[Dict[str, Any]] = []
    for wbits in wbits_grid:
        qmax = (1 << (wbits - 1)) - 1 if wbits > 1 else 1
        wq = jax.random.randint(key, (k, n), -qmax, qmax + 1, dtype=np.int32)
        for abits in abits_grid:
            amax = (1 << (abits - 1)) - 1
            xq = jax.random.randint(
                jax.random.PRNGKey(abits), (batch, k), -amax, amax + 1, dtype=np.int32
            )
            for nbw in nbw_grid:
                t = timeit_s(
                    lambda x, w, nbw=nbw, abits=abits: lg.lut_gemv(x, w, nbw=nbw, abits=abits),
                    xq,
                    wq,
                    iters=iters,
                )
                raw.append(dict(wbits=wbits, abits=abits, nbw=nbw, t_s=t))

    overrides, dispatch = fit_constants(raw, batch, k, n, machine_base=m,
                                        fit_dispatch=True)
    bw = measure_stream_bandwidth()
    overrides["dram_bw"] = bw
    overrides["dram_efficiency"] = 1.0  # measured BW is already achieved
    fitted = dataclasses.replace(m, **overrides)

    points = []
    errs = []
    for p in raw:
        wb, ab, nbw = p["wbits"], p["abits"], p["nbw"]
        modeled = lut_gemv_cycles(fitted, batch, k, n, nbw, wb, ab, threads=1)
        modeled += dispatch.get((int(nbw), int(ab)), 0.0)
        measured = p["t_s"] * m.freq_hz
        rel = abs(modeled - measured) / measured
        errs.append(rel)
        points.append(
            dict(
                p,
                measured_cycles=float(measured),
                modeled_cycles=float(modeled),
                rel_err=float(rel),
            )
        )

    return CalibrationResult(
        machine_overrides=overrides,
        points=tuple(points),
        shape=(batch, k, n),
        backend=jax.default_backend(),
        max_rel_err=float(np.max(errs)),
        mean_rel_err=float(np.mean(errs)),
        dram_bw_measured=bw,
        dispatch_cycles=dispatch,
    )
