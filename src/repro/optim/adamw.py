"""Hand-rolled AdamW + schedules (optax is not available on this box,
and the assignment requires every substrate layer to be built).

Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; fused step with
global-norm clipping and decoupled weight decay (masked to >=2D params).
Optimizer moments can be sharded independently of params (ZeRO-1) by
giving the state tree its own out_shardings in the train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda t: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), t)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return self.learning_rate

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mu_hat = jax.tree_util.tree_map(
            lambda m: m / (1 - b1 ** step.astype(jnp.float32)), mu)
        nu_hat = jax.tree_util.tree_map(
            lambda v: v / (1 - b2 ** step.astype(jnp.float32)), nu)
        lr = self.lr(step)

        def upd(p, m, v):
            u = m / (jnp.sqrt(v) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, params, mu_hat, nu_hat)
        return updates, AdamWState(step=step, mu=mu, nu=nu), gnorm

    def apply(self, params, updates):
        return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) /
                     max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
