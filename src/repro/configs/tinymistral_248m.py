"""TinyMistral-248M [hf:Locutusque/TinyMistral-248M] — the paper's small
evaluation model (mistral family: GQA, SWA)."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="tinymistral-248m", family="dense", vocab=32005, d_model=1024,
        n_layers=12, n_heads=32, n_kv=8, d_ff=4096, act="swiglu",
        norm="rmsnorm", pos="rope", window=4096, max_seq=32768)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tinymistral-248m-smoke", family="dense", vocab=256,
        d_model=64, n_layers=2, n_heads=8, n_kv=2, d_ff=128, act="swiglu",
        window=64, attn_chunk=32, max_seq=512)
