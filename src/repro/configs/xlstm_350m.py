"""xLSTM-350M [arXiv:2405.04517]: mLSTM blocks with an sLSTM block every
4th layer (scanned as homogeneous super-blocks); O(1) recurrent state ->
runs long_500k."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm", vocab=50304, d_model=1024,
        n_layers=24, n_heads=4, n_kv=4, d_ff=0, act="swiglu",
        norm="rmsnorm", pos="none", ssm_expand=2.0, slstm_every=4,
        max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm", vocab=256, d_model=64,
        n_layers=2, n_heads=2, n_kv=2, d_ff=0, act="swiglu", pos="none",
        ssm_expand=2.0, slstm_every=2, attn_chunk=32, max_seq=512)
