"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
MoE with 32 experts, top-8, per-expert ffn 512, tied embeddings."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe", vocab=49155,
        d_model=1024, n_layers=24, n_heads=16, n_kv=8, d_ff=512,
        act="swiglu", norm="rmsnorm", pos="rope", n_experts=32, top_k=8,
        moe_ffn=512, moe_shard="expert", tie_embeddings=True,
        max_seq=131072)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_ff=64, act="swiglu", n_experts=4,
        top_k=2, moe_ffn=64, moe_shard="expert", tie_embeddings=True,
        attn_chunk=32, max_seq=512)
