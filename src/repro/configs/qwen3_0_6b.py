"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B]: GQA kv=8 with explicit head_dim=128
and qk-norm, SwiGLU, RMSNorm, tied embeddings."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense", vocab=151936, d_model=1024,
        n_layers=28, n_heads=16, n_kv=8, d_head=128, d_ff=3072,
        act="swiglu", norm="rmsnorm", pos="rope", rope_theta=1e6,
        qk_norm=True, tie_embeddings=True, max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-smoke", family="dense", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_head=32, d_ff=128, act="swiglu",
        qk_norm=True, tie_embeddings=True, attn_chunk=32, max_seq=512)
