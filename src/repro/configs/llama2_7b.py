"""Llama-2-7B [arXiv:2307.09288] — the paper's primary evaluation model."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense", vocab=32000, d_model=4096,
        n_layers=32, n_heads=32, n_kv=32, d_ff=11008, act="swiglu",
        norm="rmsnorm", pos="rope", max_seq=4096)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b-smoke", family="dense", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=4, d_ff=128, act="swiglu",
        attn_chunk=32, max_seq=512)
