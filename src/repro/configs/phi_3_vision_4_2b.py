"""Phi-3-vision-128k [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone (MHA kv=32, SwiGLU, RMSNorm) + CLIP-ViT-L/14 frontend stub
(576 patch embeddings at 336px provided by input_specs)."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="dense", vocab=32064, d_model=3072,
        n_layers=32, n_heads=32, n_kv=32, d_ff=8192, act="swiglu",
        norm="rmsnorm", pos="rope", rope_theta=1e4, frontend="vision",
        vision_tokens=576, max_seq=131072)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke", family="dense", vocab=256,
        d_model=64, n_layers=2, n_heads=4, n_kv=4, d_ff=128, act="swiglu",
        frontend="vision", vision_tokens=8, attn_chunk=32, max_seq=512)
