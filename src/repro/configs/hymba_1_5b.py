"""Hymba-1.5B [arXiv:2411.13676]: hybrid-head blocks running attention and
mamba heads in parallel; SWA on attention heads + O(1) SSM state ->
runs long_500k.  25 heads x 64 = 1600; kv=5."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", vocab=32001, d_model=1600,
        n_layers=32, n_heads=25, n_kv=5, d_ff=5504, act="swiglu",
        norm="rmsnorm", pos="rope", window=1024, ssm_state=16,
        ssm_expand=2.0, hybrid_ratio=0.5, max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_ff=128, act="swiglu", window=32,
        ssm_state=4, ssm_expand=2.0, hybrid_ratio=0.5, attn_chunk=32,
        max_seq=512)
