"""StarCoder2-7B [arXiv:2402.19173]: dense GQA, RoPE, GELU, LayerNorm,
attention+MLP biases.  36 heads x 128 = 4608; kv=4."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense", vocab=49152, d_model=4608,
        n_layers=32, n_heads=36, n_kv=4, d_ff=18432, act="gelu",
        norm="layernorm", pos="rope", rope_theta=1e5,
        attention_bias=True, mlp_bias=False, max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense", vocab=256, d_model=72,
        n_layers=2, n_heads=6, n_kv=2, d_ff=144, act="gelu",
        norm="layernorm", pos="rope", attention_bias=True,
        attn_chunk=32, max_seq=512)
