"""H2O-Danube3-4B [arXiv:2401.16818 family]: llama+mistral mix with
sliding-window attention (window 4096) -> runs long_500k."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense", vocab=32000, d_model=3840,
        n_layers=24, n_heads=32, n_kv=8, d_ff=10240, act="swiglu",
        norm="rmsnorm", pos="rope", rope_theta=1e4, window=4096,
        max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_ff=128, act="swiglu", window=64,
        attn_chunk=32, max_seq=512)
