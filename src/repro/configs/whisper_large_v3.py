"""Whisper-large-v3 [arXiv:2212.04356]: encoder-decoder, MHA (kv=20),
GELU, LayerNorm, attention biases; conv audio frontend is a stub
(input_specs provides frame embeddings)."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec", vocab=51866, d_model=1280,
        n_layers=32, n_enc_layers=32, n_heads=20, n_kv=20, d_ff=5120,
        act="gelu", norm="layernorm", pos="sinusoidal",
        attention_bias=True, enc_seq=1500, frontend="audio", max_seq=65536)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="encdec", vocab=256,
        d_model=64, n_layers=2, n_enc_layers=2, n_heads=4, n_kv=4, d_ff=128,
        act="gelu", norm="layernorm", pos="sinusoidal", attention_bias=True,
        enc_seq=32, frontend="audio", attn_chunk=32, max_seq=512)
