"""Architecture config registry: ``--arch <id>`` resolution.

Each module defines ``full()`` (the exact published configuration) and
``smoke()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.common import ModelConfig

ARCHS: List[str] = [
    "starcoder2_7b",
    "llama3_2_1b",
    "h2o_danube_3_4b",
    "qwen3_0_6b",
    "whisper_large_v3",
    "phi_3_vision_4_2b",
    "hymba_1_5b",
    "granite_moe_1b_a400m",
    "mixtral_8x7b",
    "xlstm_350m",
    # the paper's own evaluation models
    "llama2_7b",
    "llama2_13b",
    "tinymistral_248m",
]

ASSIGNED: List[str] = ARCHS[:10]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.full()


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.smoke()
