"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, GQA kv=8, SWA 4096
(-> runs long_500k).  Experts shard FFN-dim over the model axis
(8 experts < 16-way axis)."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", vocab=32000, d_model=4096,
        n_layers=32, n_heads=32, n_kv=8, d_ff=14336, act="swiglu",
        norm="rmsnorm", pos="rope", rope_theta=1e6, n_experts=8, top_k=2,
        moe_ffn=14336, moe_shard="ffn", window=4096, max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_ff=128, act="swiglu", n_experts=4,
        top_k=2, moe_ffn=128, moe_shard="ffn", window=64, attn_chunk=32,
        max_seq=512)
