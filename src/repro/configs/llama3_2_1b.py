"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: dense GQA kv=8, SwiGLU,
RMSNorm, RoPE theta 5e5, tied embeddings."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense", vocab=128256, d_model=2048,
        n_layers=16, n_heads=32, n_kv=8, d_ff=8192, act="swiglu",
        norm="rmsnorm", pos="rope", rope_theta=5e5, tie_embeddings=True,
        max_seq=1048576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense", vocab=256, d_model=64,
        n_layers=2, n_heads=4, n_kv=2, d_ff=128, act="swiglu",
        tie_embeddings=True, attn_chunk=32, max_seq=512)
