"""Llama-2-13B [arXiv:2307.09288] — the paper's larger evaluation model."""
from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama2-13b", family="dense", vocab=32000, d_model=5120,
        n_layers=40, n_heads=40, n_kv=40, d_ff=13824, act="swiglu",
        norm="rmsnorm", pos="rope", max_seq=4096)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama2-13b-smoke", family="dense", vocab=256, d_model=80,
        n_layers=2, n_heads=4, n_kv=4, d_ff=160, act="swiglu",
        attn_chunk=32, max_seq=512)
