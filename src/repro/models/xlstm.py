"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

xlstm-350m alternates mLSTM blocks with an sLSTM block every
``cfg.slstm_every`` layers.  Both are O(1)-state recurrences, so decode at
500k context carries only (C, n, m) — the reason this arch runs the
long_500k shape.

mLSTM: per head, matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T with
exponential gating stabilized by the max-tracker m_t; output h_t =
(C_t q_t) / max(|n_t^T q_t|, 1).  Training uses a jax.lax.scan over T
(recurrent form); the chunkwise-parallel form is a further optimization
documented in EXPERIMENTS.md.

sLSTM: scalar memory per head-channel with exponential gating.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import dense_init
from repro.models.sail_linear import mm
from repro.dist.sharding import maybe_constrain


class MLSTMState(NamedTuple):
    c: jax.Array   # [B, H, Dh, Dh]
    n: jax.Array   # [B, H, Dh]
    m: jax.Array   # [B, H]


class SLSTMState(NamedTuple):
    c: jax.Array   # [B, D]
    n: jax.Array   # [B, D]
    m: jax.Array   # [B, D]


def mlstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    inner = int(cfg.ssm_expand * d)
    h = cfg.n_heads
    return {
        "w_up": dense_init(ks[0], (d, 2 * inner)),        # x and gate
        "w_q": dense_init(ks[1], (inner, inner)),
        "w_k": dense_init(ks[2], (inner, inner)),
        "w_v": dense_init(ks[3], (inner, inner)),
        "w_if": dense_init(ks[4], (inner, 2 * h)),        # i, f gate logits
        "w_down": dense_init(ks[5], (inner, d), fan_in=inner),
    }


def _chunked_scan(step, init, xs, chunk: int = 128):
    """lax.scan with sqrt-style rematerialization: the outer scan saves
    carries only at chunk boundaries; inner chunks recompute in backward.
    Without this, training saves the [B,H,Dh,Dh] matrix memory at every
    timestep (O(T) x state — hundreds of GB at seq 4096)."""
    t = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if t <= chunk or t % chunk != 0:
        return jax.lax.scan(step, init, xs)
    n = t // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys = jax.lax.scan(chunk_body, init, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((t,) + a.shape[2:]), ys)
    return carry, ys


def apply_mlstm(p, x, cfg: ModelConfig, state: Optional[MLSTMState] = None,
                return_state: bool = False):
    b, t, d = x.shape
    inner = p["w_q"].shape[-1]
    h = cfg.n_heads
    dh = inner // h

    up = mm(x, p["w_up"])
    xs, z = jnp.split(up, 2, axis=-1)
    q = mm(xs, p["w_q"]).reshape(b, t, h, dh) / (dh ** 0.5)
    k = mm(xs, p["w_k"]).reshape(b, t, h, dh) / (dh ** 0.5)
    v = mm(xs, p["w_v"]).reshape(b, t, h, dh)
    v = maybe_constrain(v, "batch", None, None, "model")
    gates = mm(xs, p["w_if"])                                 # [B, T, 2H]
    ig, fg = jnp.split(gates, 2, axis=-1)                  # log-space gates

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh))
        n0 = jnp.zeros((b, h, dh))
        m0 = jnp.full((b, h), -jnp.inf)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp                           # [B,H,Dh]x3, [B,H]
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        fda = jnp.exp(logf + jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_safe)
        ida = jnp.exp(it - m_safe)
        c = fda[..., None, None] * c + ida[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])           # [B,H,Dh,Dh]
        n = fda[..., None] * n + ida[..., None] * kt
        hn = jnp.einsum("bhij,bhj->bhi", c, qt)
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)), 1.0)
        out = hn / denom[..., None]
        return (c, n, m_new), out

    xs_t = lambda a: jnp.moveaxis(a, 1, 0)
    (c, n, m), outs = _chunked_scan(
        step, (c0, n0, m0),
        (xs_t(q), xs_t(k), xs_t(v),
         xs_t(ig.reshape(b, t, h)), xs_t(fg.reshape(b, t, h))))
    y = jnp.moveaxis(outs, 0, 1).reshape(b, t, inner)
    y = y * jax.nn.silu(z)
    out = mm(y, p["w_down"])
    if return_state:
        return out, MLSTMState(c=c, n=n, m=m)
    return out


def slstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    return {
        "w_z": dense_init(ks[0], (d, d)),
        "w_i": dense_init(ks[1], (d, d)),
        "w_f": dense_init(ks[2], (d, d)),
        "w_o": dense_init(ks[3], (d, d)),
        "w_down": dense_init(ks[4], (d, d)),
    }


def apply_slstm(p, x, cfg: ModelConfig, state: Optional[SLSTMState] = None,
                return_state: bool = False):
    b, t, d = x.shape
    zt = jnp.tanh(mm(x, p["w_z"]))
    it = mm(x, p["w_i"])
    ft = mm(x, p["w_f"])
    ot = jax.nn.sigmoid(mm(x, p["w_o"]))

    if state is None:
        c0, n0 = jnp.zeros((b, d)), jnp.zeros((b, d))
        m0 = jnp.full((b, d), -jnp.inf)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        z_, i_, f_, o_ = inp
        logf = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(logf + m, i_)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        fda = jnp.exp(logf + jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_safe)
        ida = jnp.exp(i_ - m_safe)
        c = fda * c + ida * z_
        n = fda * n + ida
        out = o_ * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), out

    mv = lambda a: jnp.moveaxis(a, 1, 0)
    (c, n, m), outs = _chunked_scan(step, (c0, n0, m0),
                                    (mv(zt), mv(it), mv(ft), mv(ot)))
    y = mm(jnp.moveaxis(outs, 0, 1), p["w_down"])
    if return_state:
        return y, SLSTMState(c=c, n=n, m=m)
    return y


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    inner = int(cfg.ssm_expand * cfg.d_model)
    dh = inner // cfg.n_heads
    return MLSTMState(c=jnp.zeros((batch, cfg.n_heads, dh, dh)),
                      n=jnp.zeros((batch, cfg.n_heads, dh)),
                      m=jnp.full((batch, cfg.n_heads), -jnp.inf))


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    return SLSTMState(c=jnp.zeros((batch, cfg.d_model)),
                      n=jnp.zeros((batch, cfg.d_model)),
                      m=jnp.full((batch, cfg.d_model), -jnp.inf))
