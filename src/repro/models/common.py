"""Model configuration covering every assigned architecture family.

One dataclass parameterizes dense GQA transformers (starcoder2, llama3.2,
h2o-danube, qwen3, phi-3 backbone), MoE (granite, mixtral), hybrids
(hymba: parallel attention+mamba), recurrent (xlstm), and encoder-decoder
(whisper).  ``src/repro/configs/<arch>.py`` instantiates the exact
published dimensions plus a ``smoke()`` reduction for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | encdec
    vocab: int = 32000
    d_model: int = 1024
    n_layers: int = 12
    n_heads: int = 16
    n_kv: int = 8
    d_head: Optional[int] = None   # default d_model // n_heads
    d_ff: int = 4096
    act: str = "swiglu"            # swiglu | gelu | geglu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    pos: str = "rope"              # rope | learned | sinusoidal | none
    rope_theta: float = 10000.0
    qk_norm: bool = False          # qwen3
    window: Optional[int] = None   # SWA width (danube, mixtral, hymba attn)
    attention_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    max_seq: int = 131072
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_ffn: Optional[int] = None  # per-expert hidden dim (defaults d_ff)
    moe_shard: str = "expert"      # expert (EP) | ffn (TP inside expert)
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: float = 2.0
    hybrid_ratio: float = 0.5      # fraction of width given to mamba branch
    # --- xLSTM ---
    slstm_every: int = 4           # every Nth block is sLSTM (else mLSTM)
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500            # audio frame embeddings after conv stub
    # --- frontends (stubs; see DESIGN.md) ---
    frontend: Optional[str] = None  # "audio" | "vision"
    vision_tokens: int = 576       # CLIP-ViT-L/14 @336: (336/14)^2 patches
    # --- numerics ---
    dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 512          # flash-attention KV block in pure JAX

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    @property
    def expert_ffn(self) -> int:
        return self.moe_ffn if self.moe_ffn is not None else self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D MODEL_FLOPS)."""
        d, f = self.d_model, self.d_ff
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "ssm":  # xlstm
            inner = int(self.ssm_expand * d)
            per = 2 * d * 2 * inner + 2 * inner * d  # qkv-ish proj + out
            blocks = self.n_layers * per
        elif self.family == "hybrid":
            inner = int(self.ssm_expand * d * self.hybrid_ratio)
            mamba = 2 * d * inner + inner * self.ssm_state * 2 + inner * d
            mlp = 3 * d * f if self.act in ("swiglu", "geglu") else 2 * d * f
            blocks = self.n_layers * (attn + mamba + mlp)
        elif self.family == "moe":
            e = self.n_experts * (3 * d * self.expert_ffn
                                  if self.act in ("swiglu", "geglu")
                                  else 2 * d * self.expert_ffn)
            router = d * self.n_experts
            blocks = self.n_layers * (attn + e + router)
        else:
            mlp = 3 * d * f if self.act in ("swiglu", "geglu") else 2 * d * f
            blocks = self.n_layers * (attn + mlp)
            if self.family == "encdec":
                blocks += self.n_enc_layers * (attn + mlp) + \
                    self.n_layers * attn  # cross attention
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return blocks + embed

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE uses top_k of n_experts."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        e_all = self.n_experts * 3 * d * self.expert_ffn
        e_act = self.top_k * 3 * d * self.expert_ffn
        return self.param_count() - self.n_layers * (e_all - e_act)
