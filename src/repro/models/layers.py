"""Core transformer layers: norms, RoPE, memory-efficient attention, MLP.

Pure-functional JAX (params are nested dicts).  Attention in the training /
prefill path is a chunked online-softmax ("flash") implementation in plain
jnp — bounded live memory under remat, the structure a TPU splash kernel
would have; the decode path uses repro.kernels.decode_attn semantics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.sail_linear import mm
from repro.dist.sharding import maybe_constrain, tp_all_reduce

Initializer = jax.nn.initializers.Initializer


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.truncated_normal(key, -2, 2, shape, dtype) * std


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    return inv.astype(dtype)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """x: [B, T, H, Dh]; positions: [B, T] (absolute)."""
    inv = rope_freqs(cfg)
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, T, Dh/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window / cross / bidirectional)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim)),
        "wk": dense_init(ks[1], (d, cfg.kv_dim)),
        "wv": dense_init(ks[2], (d, cfg.kv_dim)),
        "wo": dense_init(ks[3], (cfg.q_dim, d), fan_in=cfg.q_dim),
    }
    if cfg.attention_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,))
        p["bk"] = jnp.zeros((cfg.kv_dim,))
        p["bv"] = jnp.zeros((cfg.kv_dim,))
        p["bo"] = jnp.zeros((d,))
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((cfg.head_dim,))}
        p["k_norm"] = {"scale": jnp.ones((cfg.head_dim,))}
    return p


def _qk_norm(x, scale, eps):
    var = (x.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                    chunk: int, q_offset: int = 0,
                    kv_valid: Optional[jax.Array] = None,
                    q_chunk: int = 512):
    """Chunked online-softmax attention in pure jnp (q and kv blocked).

    q: [B, T, H, Dh]; k, v: [B, S, KV, Dh].  GQA via head grouping.
    The outer loop blocks queries (so the scan carry — and therefore the
    O(n_kv_chunks x carry) backward storage of lax.scan — is
    O(B*q_chunk*H*Dh), not O(B*T*H*Dh)); the inner scan walks KV blocks
    with a running (m, l, acc).  q_offset: absolute position of q[0]
    relative to k[0].  kv_valid: [B, S] bool padding mask.
    """
    b, t, h, dh = q.shape
    if t > q_chunk and t % q_chunk:
        # largest divisor of t <= q_chunk (vision prefixes give T=4672 etc)
        for d in range(q_chunk, 0, -1):
            if t % d == 0:
                q_chunk = d
                break
    if t > q_chunk and t % q_chunk == 0:
        nq = t // q_chunk
        qb = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, dh), 1, 0)
        s_full = k.shape[1]

        if window is not None and causal and kv_valid is None \
                and s_full > 2 * (window + q_chunk):
            # §Perf C1: sliding-window attention only needs KV in
            # [q_lo - window, q_hi); slice that band per q block instead of
            # masking the full quadratic sweep (16x fewer chunk passes at
            # 32k/window-1k).  Band length is static; offset is traced.
            band = -(-(window + q_chunk) // chunk) * chunk

            def one(args):
                qi, off = args
                lo = jnp.clip(off + q_chunk - band, 0, s_full - band)
                kb = jax.lax.dynamic_slice_in_dim(k, lo, band, 1)
                vb = jax.lax.dynamic_slice_in_dim(v, lo, band, 1)
                return flash_attention(qi, kb, vb, causal=causal,
                                       window=window, chunk=chunk,
                                       q_offset=off - lo, q_chunk=t)
        else:
            def one(args):
                qi, off = args
                return flash_attention(qi, k, v, causal=causal,
                                       window=window, chunk=chunk,
                                       q_offset=off, kv_valid=kv_valid,
                                       q_chunk=t)
        outs = jax.lax.map(
            jax.checkpoint(one),
            (qb, q_offset + jnp.arange(nq) * q_chunk))
        return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, dh)
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, t, kv, g, dh).astype(jnp.float32)

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        padkv = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k, v = padkv(k), padkv(v)
    if kv_valid is not None:
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)),
                           constant_values=False)
    kc = k.reshape(b, n_chunks, chunk, kv, dh).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, kv, dh).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(t)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, ci = inputs
        kv_pos = ci * chunk + jnp.arange(chunk)
        # scores: [B, T, KV, G, chunk]
        scores = jnp.einsum("btghd,bcgd->btghc", qg, kb) * scale
        valid = jnp.ones((b, t, chunk), bool)
        valid &= (kv_pos < s)[None, None, :]
        if causal:
            valid &= kv_pos[None, None, :] <= q_pos[None, :, None]
        if window is not None:
            valid &= kv_pos[None, None, :] > (q_pos[None, :, None] - window)
        if kv_valid is not None:
            vblk = jax.lax.dynamic_slice_in_dim(kv_valid, ci * chunk, chunk, 1)
            valid &= vblk[:, None, :]
        scores = jnp.where(valid[:, :, None, None, :], scores, -jnp.inf)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        # guard -inf rows (fully masked chunk)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(valid[:, :, None, None, :], p, 0.0)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "btghc,bcgd->btghd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, t, kv, g), -jnp.inf)
    l0 = jnp.zeros((b, t, kv, g))
    acc0 = jnp.zeros((b, t, kv, g, dh))
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    # remat each KV chunk: backward recomputes scores/p per chunk instead
    # of storing [B,T,H,chunk] residuals for every chunk simultaneously
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0),
        (kc_t, vc_t, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, t, h, dh).astype(q.dtype)


def apply_attention(p, x, cfg: ModelConfig, *, positions, causal=True,
                    kv_x: Optional[jax.Array] = None,
                    kv_valid: Optional[jax.Array] = None,
                    window: Optional[int] = None):
    """Full (prefill/train) attention.  kv_x given -> cross attention."""
    b, t, d = x.shape
    src = kv_x if kv_x is not None else x
    q = mm(x, p["wq"])
    k = mm(src, p["wk"])
    v = mm(src, p["wv"])
    if cfg.attention_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, src.shape[1], cfg.n_kv, cfg.head_dim)
    v = v.reshape(b, src.shape[1], cfg.n_kv, cfg.head_dim)
    q = maybe_constrain(q, "batch", None, "model", None)
    k = maybe_constrain(k, "batch", None, "model", None)
    v = maybe_constrain(v, "batch", None, "model", None)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if cfg.pos == "rope" and kv_x is None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    out = flash_attention(q, k, v, causal=causal and kv_x is None,
                          window=window, chunk=cfg.attn_chunk,
                          kv_valid=kv_valid)
    out = maybe_constrain(out, "batch", None, "model", None)
    out = tp_all_reduce(mm(out.reshape(b, t, cfg.q_dim), p["wo"]))
    if cfg.attention_bias:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, f)),
                "w_up": dense_init(ks[1], (d, f)),
                "w_down": dense_init(ks[2], (f, d), fan_in=f)}
    return {"w_up": dense_init(ks[0], (d, f)),
            "w_down": dense_init(ks[1], (f, d), fan_in=f)}


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(mm(x, p["w_gate"])) * mm(x, p["w_up"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(mm(x, p["w_gate"])) * mm(x, p["w_up"])
    else:
        h = jax.nn.gelu(mm(x, p["w_up"]))
    h = maybe_constrain(h, "batch", None, "model")
    return tp_all_reduce(mm(h, p["w_down"]))
