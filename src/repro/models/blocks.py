"""Decoder blocks for every architecture family, built scan-compatible:
all layers of an arch share one pytree structure so the layer stack lowers
as a single ``jax.lax.scan`` body (fast compile at 512 devices).

xLSTM's heterogeneous stack (one sLSTM per ``slstm_every`` mLSTMs) is
handled by scanning over homogeneous *super-blocks* of ``slstm_every``
layers (unrolled inside the scan body).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import ModelConfig
from repro.models.layers import (apply_attention, apply_mlp, apply_norm,
                                 attention_init, mlp_init, norm_init)
from repro.models.sail_linear import mm
from repro.dist.sharding import maybe_constrain, tp_all_reduce


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig):
    """One layer's params (stacked by the caller via vmap over keys)."""
    ks = jax.random.split(key, 8)
    if cfg.family == "ssm":  # xlstm super-block
        n_in = cfg.slstm_every
        sub_ks = jax.random.split(ks[0], n_in)
        subs = []
        for i in range(n_in):
            kk = jax.random.split(sub_ks[i], 2)
            if i == n_in - 1:  # last of the super-block is sLSTM
                subs.append({"norm": norm_init(cfg),
                             "slstm": xlstm_lib.slstm_init(kk[0], cfg)})
            else:
                subs.append({"norm": norm_init(cfg),
                             "mlstm": xlstm_lib.mlstm_init(kk[0], cfg)})
        return {"subs": subs}

    p: Dict[str, Any] = {
        "attn_norm": norm_init(cfg),
        "attn": attention_init(ks[0], cfg),
        "mlp_norm": norm_init(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    if cfg.family == "hybrid":
        p["ssm_norm"] = norm_init(cfg)
        p["ssm"] = ssm_lib.ssm_init(ks[2], cfg)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill: full sequence)
# ---------------------------------------------------------------------------

def block_apply_seq(p, x, cfg: ModelConfig, positions,
                    moe_mode: str = "dispatch",
                    collect_cache: bool = False):
    """Full-sequence block.  Returns (x, aux_loss, cache_entries).

    The output dtype always matches the input dtype (scan-carry stable
    under bf16 mixed precision)."""
    in_dtype = x.dtype
    x = maybe_constrain(x, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    cache = {}

    if cfg.family == "ssm":
        for i, sub in enumerate(p["subs"]):
            h = apply_norm(sub["norm"], x, cfg)
            if "slstm" in sub:
                if collect_cache:
                    y, st = xlstm_lib.apply_slstm(sub["slstm"], h, cfg,
                                                  return_state=True)
                    cache[f"slstm_{i}"] = st
                else:
                    y = xlstm_lib.apply_slstm(sub["slstm"], h, cfg)
            else:
                if collect_cache:
                    y, st = xlstm_lib.apply_mlstm(sub["mlstm"], h, cfg,
                                                  return_state=True)
                    cache[f"mlstm_{i}"] = st
                else:
                    y = xlstm_lib.apply_mlstm(sub["mlstm"], h, cfg)
            x = (x + y).astype(in_dtype)
        return x, aux, cache

    # --- attention (+ parallel mamba for hybrid) -------------------------
    h = apply_norm(p["attn_norm"], x, cfg)
    attn_out = apply_attention(p["attn"], h, cfg, positions=positions,
                               causal=True, window=cfg.window)
    if collect_cache:
        cache["kv"] = _kv_from_seq(p["attn"], h, cfg, positions)
    if cfg.family == "hybrid":
        hs = apply_norm(p["ssm_norm"], x, cfg)
        if collect_cache:
            ssm_out, st = ssm_lib.apply_ssm(p["ssm"], hs, cfg,
                                            return_state=True)
            cache["ssm"] = st
        else:
            ssm_out = ssm_lib.apply_ssm(p["ssm"], hs, cfg)
        x = (x + 0.5 * (attn_out + ssm_out)).astype(in_dtype)
    else:
        x = (x + attn_out).astype(in_dtype)

    # --- mlp / moe --------------------------------------------------------
    h = apply_norm(p["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        y, aux = moe_lib.apply_moe(p["moe"], h, cfg, mode=moe_mode)
    else:
        y = apply_mlp(p["mlp"], h, cfg)
    x = (x + y).astype(in_dtype)
    return x, aux, cache


def _kv_from_seq(attn_p, h, cfg: ModelConfig, positions):
    """Recompute K/V for the prefill cache (keys stored post-RoPE)."""
    from repro.models.layers import apply_rope, _qk_norm
    b, t, _ = h.shape
    k = mm(h, attn_p["wk"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    v = mm(h, attn_p["wv"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        k = _qk_norm(k, attn_p["k_norm"]["scale"], cfg.norm_eps)
    if cfg.pos == "rope":
        k = apply_rope(k, positions, cfg)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# decode (single token, cache update)
# ---------------------------------------------------------------------------

def block_apply_decode(p, x, cfg: ModelConfig, layer_cache, position,
                       cache_len: int, moe_mode: str = "dense",
                       quant_kv: bool = False, block_tables=None):
    """One-token decode.  x: [B, 1, D]; position: [B] absolute positions.

    layer_cache holds this layer's state (ring-buffered KV of size
    ``cache_len``, ssm/xlstm states).  Returns (x, new_cache).

    block_tables: optional [B, max_blocks] int32 — paged mode, where
    layer_cache KV is a block pool ``[num_blocks, block_size, KV, Dh]``
    and ``cache_len == max_blocks * block_size``.  The write scatters
    through the table; attention gathers the lane's blocks into a
    contiguous view and reuses the ring validity math (paged lanes never
    wrap, so "slot holds position slot" makes the two formulas agree).
    """
    from repro.core.quant import quantize_kv
    from repro.models.layers import apply_rope, _qk_norm
    new_cache = dict(layer_cache)
    in_dtype = x.dtype
    b = x.shape[0]

    if cfg.family == "ssm":
        for i, sub in enumerate(p["subs"]):
            h = apply_norm(sub["norm"], x, cfg)
            if "slstm" in sub:
                y, st = xlstm_lib.apply_slstm(
                    sub["slstm"], h, cfg, state=layer_cache[f"slstm_{i}"],
                    return_state=True)
                new_cache[f"slstm_{i}"] = st
            else:
                y, st = xlstm_lib.apply_mlstm(
                    sub["mlstm"], h, cfg, state=layer_cache[f"mlstm_{i}"],
                    return_state=True)
                new_cache[f"mlstm_{i}"] = st
            x = (x + y).astype(in_dtype)
        return x, new_cache

    h = apply_norm(p["attn_norm"], x, cfg)
    q = mm(h, p["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = mm(h, p["attn"]["wk"]).reshape(b, 1, cfg.n_kv, cfg.head_dim)
    v = mm(h, p["attn"]["wv"]).reshape(b, 1, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = _qk_norm(q, p["attn"]["q_norm"]["scale"], cfg.norm_eps)
        k = _qk_norm(k, p["attn"]["k_norm"]["scale"], cfg.norm_eps)
    if cfg.pos == "rope":
        q = apply_rope(q, position[:, None], cfg)
        k = apply_rope(k, position[:, None], cfg)

    if block_tables is not None:
        # paged write: scatter through the block table, then gather the
        # lane's blocks back into a contiguous [B, S, KV, Dh] view
        bs = layer_cache["k"].shape[1]
        nbp = block_tables.shape[1]
        logical = jnp.clip(position // bs, 0, nbp - 1)
        off = position % bs
        phys = jnp.take_along_axis(block_tables, logical[:, None],
                                   axis=1)[:, 0]
        gather = lambda pool: pool[block_tables].reshape(
            (b, nbp * bs) + pool.shape[2:])
        if quant_kv:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = _paged_write(layer_cache["k"], kq, phys, off)
            vc = _paged_write(layer_cache["v"], vq, phys, off)
            ksc = _paged_write(layer_cache["k_scale"], ks, phys, off)
            vsc = _paged_write(layer_cache["v_scale"], vs, phys, off)
            new_cache.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
            kf = gather(kc).astype(jnp.float32) * gather(ksc)
            vf = gather(vc).astype(jnp.float32) * gather(vsc)
        else:
            kc = _paged_write(layer_cache["k"], k, phys, off)
            vc = _paged_write(layer_cache["v"], v, phys, off)
            new_cache.update(k=kc, v=vc)
            kf, vf = gather(kc), gather(vc)
    else:
        # ring-buffer write at position % cache_len
        slot = (position % cache_len)[:, None, None, None]
        if quant_kv:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = _ring_write(layer_cache["k"], kq, slot)
            vc = _ring_write(layer_cache["v"], vq, slot)
            ksc = _ring_write(layer_cache["k_scale"], ks, slot)
            vsc = _ring_write(layer_cache["v_scale"], vs, slot)
            new_cache.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
            kf = kc.astype(jnp.float32) * ksc
            vf = vc.astype(jnp.float32) * vsc
        else:
            kc = _ring_write(layer_cache["k"], k, slot)
            vc = _ring_write(layer_cache["v"], v, slot)
            new_cache.update(k=kc, v=vc)
            kf, vf = kc, vc

    attn_out = _decode_attend(q, kf, vf, position, cfg, cache_len)
    attn_out = tp_all_reduce(
        mm(attn_out.reshape(b, 1, cfg.q_dim), p["attn"]["wo"]))

    if cfg.family == "hybrid":
        hs = apply_norm(p["ssm_norm"], x, cfg)
        ssm_out, st = ssm_lib.apply_ssm(p["ssm"], hs, cfg,
                                        state=layer_cache["ssm"],
                                        return_state=True)
        new_cache["ssm"] = st
        x = (x + 0.5 * (attn_out + ssm_out)).astype(in_dtype)
    else:
        x = (x + attn_out).astype(in_dtype)

    h = apply_norm(p["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        y, _ = moe_lib.apply_moe(p["moe"], h, cfg, mode=moe_mode)
    else:
        y = apply_mlp(p["mlp"], h, cfg)
    return (x + y).astype(in_dtype), new_cache


def _ring_write(cache, val, slot):
    """Scatter one token into the ring cache (in-place under donation).

    cache [B, S, KV, D(or 1)], val [B, 1, KV, D], slot [B,1,1,1].
    A batched dynamic-update (scatter) touches only the written slot —
    bytes ~ O(B*KV*D), not O(B*S*KV*D) like a one-hot masked rewrite.
    """
    b = cache.shape[0]
    idx = slot.reshape(b)
    return cache.at[jnp.arange(b), idx].set(
        val[:, 0].astype(cache.dtype), unique_indices=True,
        indices_are_sorted=False)


def _paged_write(pool, val, phys, off):
    """Scatter one token per lane into the paged block pool.

    pool [NB, BS, KV, D(or 1)], val [B, 1, KV, D], phys/off [B].
    No ``unique_indices``: retired lanes share the trash block, so
    duplicate destinations are expected — their values are dead either
    way (the engine never reads the trash block through a live table).
    """
    return pool.at[phys, off].set(val[:, 0].astype(pool.dtype))


# ---------------------------------------------------------------------------
# speculative verify (multi-token decode, cache update)
# ---------------------------------------------------------------------------

def block_apply_verify(p, x, cfg: ModelConfig, layer_cache, position,
                       cache_len: int, moe_mode: str = "dense",
                       quant_kv: bool = False, block_tables=None):
    """Multi-token decode for speculative verification.

    x: [B, T, D] — the pending token plus the drafted tokens, occupying
    absolute positions ``position + t`` (position: [B] is the first
    slot's position).  Writes KV for all T positions — overwriting any
    draft-precision KV the draft pass left at the same slots — and
    attends chunk-causally: query t sees every cached position ``<=
    position + t`` inside the window, including the tokens written this
    call, never the ones after it.

    Attention families only: recurrent state (ssm/hybrid) cannot be
    rolled back to an accepted frontier, so the engine gates speculative
    decoding off for those families.
    """
    from repro.core.quant import quantize_kv
    from repro.models.layers import apply_rope, _qk_norm
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"speculative verify requires a pure-attention family, "
            f"got {cfg.family!r}")
    new_cache = dict(layer_cache)
    in_dtype = x.dtype
    b, t, _ = x.shape

    h = apply_norm(p["attn_norm"], x, cfg)
    q = mm(h, p["attn"]["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = mm(h, p["attn"]["wk"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    v = mm(h, p["attn"]["wv"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = _qk_norm(q, p["attn"]["q_norm"]["scale"], cfg.norm_eps)
        k = _qk_norm(k, p["attn"]["k_norm"]["scale"], cfg.norm_eps)
    qpos = position[:, None] + jnp.arange(t)[None, :]    # [B, T] absolute
    if cfg.pos == "rope":
        q = apply_rope(q, qpos, cfg)
        k = apply_rope(k, qpos, cfg)

    if block_tables is not None:
        bs = layer_cache["k"].shape[1]
        nbp = block_tables.shape[1]
        logical = jnp.clip(qpos // bs, 0, nbp - 1)       # [B, T]
        off = qpos % bs
        phys = jnp.take_along_axis(block_tables, logical, axis=1)
        gather = lambda pool: pool[block_tables].reshape(
            (b, nbp * bs) + pool.shape[2:])
        if quant_kv:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = _paged_write_multi(layer_cache["k"], kq, phys, off)
            vc = _paged_write_multi(layer_cache["v"], vq, phys, off)
            ksc = _paged_write_multi(layer_cache["k_scale"], ks, phys, off)
            vsc = _paged_write_multi(layer_cache["v_scale"], vs, phys, off)
            new_cache.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
            kf = gather(kc).astype(jnp.float32) * gather(ksc)
            vf = gather(vc).astype(jnp.float32) * gather(vsc)
        else:
            kc = _paged_write_multi(layer_cache["k"], k, phys, off)
            vc = _paged_write_multi(layer_cache["v"], v, phys, off)
            new_cache.update(k=kc, v=vc)
            kf, vf = gather(kc), gather(vc)
    else:
        slot = qpos % cache_len                          # [B, T]
        if quant_kv:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = _ring_write_multi(layer_cache["k"], kq, slot)
            vc = _ring_write_multi(layer_cache["v"], vq, slot)
            ksc = _ring_write_multi(layer_cache["k_scale"], ks, slot)
            vsc = _ring_write_multi(layer_cache["v_scale"], vs, slot)
            new_cache.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
            kf = kc.astype(jnp.float32) * ksc
            vf = vc.astype(jnp.float32) * vsc
        else:
            kc = _ring_write_multi(layer_cache["k"], k, slot)
            vc = _ring_write_multi(layer_cache["v"], v, slot)
            new_cache.update(k=kc, v=vc)
            kf, vf = kc, vc

    attn_out = _verify_attend(q, kf, vf, position, cfg, cache_len)
    attn_out = tp_all_reduce(
        mm(attn_out.reshape(b, t, cfg.q_dim), p["attn"]["wo"]))
    x = (x + attn_out).astype(in_dtype)

    h = apply_norm(p["mlp_norm"], x, cfg)
    if cfg.family == "moe":
        y, _ = moe_lib.apply_moe(p["moe"], h, cfg, mode=moe_mode)
    else:
        y = apply_mlp(p["mlp"], h, cfg)
    return (x + y).astype(in_dtype), new_cache


def _ring_write_multi(cache, val, slot):
    """Scatter T consecutive tokens per lane into the ring cache.

    cache [B, S, KV, D(or 1)], val [B, T, KV, D], slot [B, T].  Slots are
    distinct within a lane whenever T <= S (speculative lanes never wrap
    — the engine's submit guard reserves prompt + max_new + k + 1 slots).
    """
    b = cache.shape[0]
    return cache.at[jnp.arange(b)[:, None], slot].set(
        val.astype(cache.dtype), unique_indices=True,
        indices_are_sorted=False)


def _paged_write_multi(pool, val, phys, off):
    """Scatter T tokens per lane through the block tables.

    pool [NB, BS, KV, D(or 1)], val [B, T, KV, D], phys/off [B, T].
    Masked lanes' tables point at the trash block, so duplicate
    destinations are expected there — no ``unique_indices``.
    """
    b, t = phys.shape
    flat = val.reshape((b * t,) + val.shape[2:])
    return pool.at[phys.reshape(-1), off.reshape(-1)].set(
        flat.astype(pool.dtype))


def _verify_attend(q, k, v, position, cfg: ModelConfig, cache_len: int):
    """Chunk-causal attention of T query tokens over the ring cache.

    q: [B, T, H, Dh]; k, v: [B, S, KV, Dh] (f32).  All T tokens' KV is
    already written, so slot contents correspond to ``final = position +
    T - 1``; query t at absolute position ``qpos = position + t`` then
    admits slots whose held position is in ``(qpos - window, qpos]`` —
    which excludes the later tokens of this same chunk (held > qpos) and
    reduces exactly to the single-token formula at T == 1."""
    b, t, hh, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = hh // kv
    qg = q.reshape(b, t, kv, g, dh).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)

    slots = jnp.arange(s)[None, :]                       # [1, S]
    final = (position + t - 1)[:, None]                  # [B, 1]
    cur_slot = final % s
    age = (cur_slot - slots) % s                         # 0 = newest
    held = final - age                                   # [B, S] absolute
    qpos = position[:, None] + jnp.arange(t)[None, :]    # [B, T]
    window = cfg.window if cfg.window is not None else cache_len
    valid = ((held[:, None, :] >= 0)
             & (held[:, None, :] <= qpos[:, :, None])
             & (held[:, None, :] > qpos[:, :, None] - window))  # [B, T, S]
    scores = jnp.where(valid[:, None, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, hh, dh).astype(q.dtype)


def _decode_attend(q, k, v, position, cfg: ModelConfig, cache_len: int):
    """Attention of one query token over the ring cache.

    q: [B, 1, H, Dh]; k, v: [B, S, KV, Dh] (f32).  Valid slots: those
    holding positions in (pos - effective_window, pos]."""
    b, _, hh, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = hh // kv
    qg = q.reshape(b, kv, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bghd,bsgd->bghs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)

    # slot i currently holds absolute position: the largest p <= position
    # with p % S == i  ->  valid iff that p > position - window and p >= 0
    slots = jnp.arange(s)[None, :]                       # [1, S]
    pos = position[:, None]                              # [B, 1]
    cur_slot = pos % s
    age = (cur_slot - slots) % s                         # 0 = newest
    held = pos - age                                     # absolute position
    window = cfg.window if cfg.window is not None else cache_len
    valid = (held >= 0) & (held > pos - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bghs,bsgd->bghd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, hh, dh).astype(q.dtype)
