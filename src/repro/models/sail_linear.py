"""SailLinear: quantized-weight matmul dispatch, the framework's integration
point for the paper's technique.

Every weight matmul in the model goes through ``mm(x, w)``:
  * training / unquantized serving: ``w`` is a plain array -> jnp.dot;
  * SAIL serving: ``w`` is a ``QTensor`` (packed intN + group scales +
    codebook LUT) -> the LUT-dequant matmul (Pallas kernel on TPU, its
    same-semantics jnp form when lowering on CPU / inside the dry-run).

``quantize_params`` converts a trained parameter tree into the SAIL serving
format (the offline step the ``ql`` instruction field selects at runtime);
embedding tables and 1-D params (norms, biases) stay in f32, mirroring the
paper's mixed-precision outlier handling.

Mixed precision: the paper's whole point is supporting *arbitrary* ql with
minimal overhead ("optimal bit precision varies across models and layers",
Sec. I).  ``QuantPolicy`` therefore resolves bits per parameter path:

  * ``rules``       — explicit (regex, bits) overrides, first match wins;
  * ``allocation``  — a :class:`BitAllocation` (typically produced by the
    sensitivity-driven allocator in ``repro.core.sensitivity``) mapping a
    path to a scalar or to a per-layer tuple of bits;
  * ``bits``        — the uniform fallback.

Scan-stacked layers can only carry one static ``bits`` per stack, so a
per-layer tuple on a ``blocks`` leaf splits the stack into maximal
uniform-bits *segments*: ``params["blocks"]`` becomes a list of stacked
trees the model applies back-to-back (``repro.models.lm`` scans each
segment; single-segment trees keep today's exact semantics).

Activation precision: the ``lutmm`` instruction parameterizes *both* the
weight (``ql``) and the activation precision per call, so the policy also
resolves ``abits`` per path (``act_rules`` / ``allocation.act_per_path`` /
``act_bits``).  A quantized leaf carries its allocated ``abits`` as static
metadata and ``mm``/``einsum_q`` run the *real* integer path: activations
are quantized per token (``quantize_activations``) and the integer codes
plus per-token scale enter the LUT-GEMV kernel directly (``abits=None``
keeps today's f32-activation semantics).  Fake-quant survives only as the
calibration probe (``ActQuantWeight``).  Per-layer ``abits`` tuples
segment the scan stack exactly like weight bits do — a segment is maximal
in the *joint* (wbits, abits) assignment.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.quant import (SUPPORTED_ABITS, SUPPORTED_BITS, QTensor,
                              _uniform_codebook, nf_codebook, quantize,
                              quantize_activations)

__all__ = [
    "ActQuantWeight", "BitAllocation", "QuantPolicy", "QTensor",
    "StackedQTensor", "act_fake_quant", "dequantize_any", "einsum_q", "mm",
    "nf_codebook", "quantize_params", "set_backend",
]

# Module-level backend switch: "jnp" (XLA path — used under pjit / dry-run)
# or "pallas" (kernel path, interpret=True on CPU).
_BACKEND = "jnp"


def set_backend(backend: str) -> None:
    global _BACKEND
    assert backend in ("jnp", "pallas")
    _BACKEND = backend


def act_fake_quant(x: jax.Array, abits: int) -> jax.Array:
    """Per-token activation quantize->dequantize at ``abits`` — the error
    a SAIL matmul serving ``lutmm(..., abits)`` would see on its inputs.
    Works for any leading shape (the last axis is the token's feature
    vector)."""
    xq, xs = quantize_activations(x, abits)
    return (xq.astype(jnp.float32) * xs).astype(x.dtype)


def _apply_act_quant(x: jax.Array, w: Any):
    """Unwrap an ``ActQuantWeight`` probe (gate-blended fake-quant, so one
    scan pass can probe a single layer of a stack).

    This is the *only* place fake-quant touches activations: quantized
    leaves carrying ``abits`` run the real integer path inside
    ``mm``/``einsum_q`` instead.  Returns the (possibly probed)
    activations and the unwrapped weight."""
    if isinstance(w, ActQuantWeight):
        fq = act_fake_quant(x, w.abits)
        x = x + w.gate.astype(x.dtype) * (fq - x)
        w = w.w
    return x, w


def mm(x: jax.Array, w: Any) -> jax.Array:
    """x [..., K] @ w [K, N] with QTensor dispatch."""
    x, w = _apply_act_quant(x, w)
    if isinstance(w, StackedQTensor) and w.packed.ndim == 2:
        # a scan-sliced layer: reinterpret as a plain QTensor
        w = QTensor(packed=w.packed, scales=w.scales,
                    codebook=w.codebook, bits=w.bits,
                    group_size=w.group_size, k=w.k, abits=w.abits)
    if isinstance(w, QTensor):
        from repro.kernels.lut_gemv.ops import lut_matmul
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = lut_matmul(x2, w, out_dtype=x.dtype if x.dtype != jnp.int32
                       else jnp.float32, backend=_BACKEND)
        return y.reshape(*lead, w.n)
    return x @ w


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ActQuantWeight:
    """Probe wrapper: a plain weight whose *matmul inputs* are quantized.

    Used by ``repro.core.sensitivity.activation_sensitivity`` to measure
    the end-to-end error of quantizing one unit's activations at a
    candidate ``abits`` while everything else stays at the baseline.  The
    ``gate`` array (scalar, or [L] for scan-stacked weights — scan slices
    both fields in lockstep) turns the fake-quant on per layer, so one
    compiled forward probes every layer of a stack."""
    w: jax.Array
    gate: jax.Array
    abits: int = dataclasses.field(metadata=dict(static=True))


# Bits for one path: a scalar, or one entry per scan-stacked layer.
BitsSpec = Union[int, Tuple[int, ...]]


def _bits_spec_to_json(per_path: Mapping[str, BitsSpec]) -> Dict[str, Any]:
    return {p: (list(map(int, b)) if isinstance(b, (tuple, list))
                else int(b))
            for p, b in per_path.items()}


def _bits_spec_from_json(spec: Mapping[str, Any]) -> Dict[str, BitsSpec]:
    return {p: (tuple(int(x) for x in b) if isinstance(b, (list, tuple))
                else int(b))
            for p, b in spec.items()}


@dataclasses.dataclass(frozen=True)
class BitAllocation:
    """Per-path bit-width assignment (the allocator's output).

    ``per_path`` maps ``jax.tree_util.keystr`` paths to a scalar weight
    bits or, for scan-stacked ``blocks`` leaves, a per-layer tuple.
    ``act_per_path`` carries the jointly allocated activation precision
    the same way (absent paths keep the policy's ``act_bits`` fallback).
    JSON-safe via ``to_spec``/``from_spec`` so checkpoints can embed the
    allocation; the legacy flat weight-only spec format still parses.
    """
    per_path: Mapping[str, BitsSpec]
    act_per_path: Mapping[str, BitsSpec] = dataclasses.field(
        default_factory=dict)

    def lookup(self, path: str) -> Optional[BitsSpec]:
        return self.per_path.get(path)

    def lookup_act(self, path: str) -> Optional[BitsSpec]:
        return self.act_per_path.get(path)

    def to_spec(self) -> Dict[str, Any]:
        if not self.act_per_path:
            return _bits_spec_to_json(self.per_path)   # legacy flat format
        return {"weights": _bits_spec_to_json(self.per_path),
                "activations": _bits_spec_to_json(self.act_per_path)}

    @staticmethod
    def from_spec(spec: Mapping[str, Any]) -> "BitAllocation":
        if "weights" in spec and "activations" in spec:
            return BitAllocation(
                per_path=_bits_spec_from_json(spec["weights"]),
                act_per_path=_bits_spec_from_json(spec["activations"]))
        return BitAllocation(per_path=_bits_spec_from_json(spec))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4                  # uniform fallback precision
    group_size: int = 128
    min_size: int = 65536          # don't quantize small tensors
    skip_embed: bool = True        # gathers can't stream through LUT-GEMV
    # None | array (single-precision policies only) | callable bits->array
    # (e.g. ``nf_codebook`` — mixed policies need a per-bits codebook)
    codebook: Optional[Any] = None
    rules: Tuple[Tuple[str, int], ...] = ()     # (regex, bits), first match
    allocation: Optional[BitAllocation] = None  # sensitivity allocator output
    # activation precision: uniform fallback (None = f32 activations) and
    # explicit per-path overrides, resolved like the weight side
    act_bits: Optional[int] = None
    act_rules: Tuple[Tuple[str, int], ...] = ()

    def bits_for(self, path: str) -> BitsSpec:
        """Resolve the bit width for one parameter path.

        Explicit rules override the automatic allocation, which overrides
        the uniform fallback."""
        for pat, b in self.rules:
            if re.search(pat, path):
                return _check_bits(int(b))
        if self.allocation is not None:
            got = self.allocation.lookup(path)
            if got is not None:
                return got
        return self.bits

    def abits_for(self, path: str) -> Optional[BitsSpec]:
        """Resolve the activation precision for one parameter path
        (``None`` = keep f32 activations for this matmul).  Same
        precedence as the weight side: act_rules > allocation >
        act_bits."""
        for pat, b in self.act_rules:
            if re.search(pat, path):
                return _check_abits(int(b))
        if self.allocation is not None:
            got = self.allocation.lookup_act(path)
            if got is not None:
                return got
        return self.act_bits

    def codebook_for(self, bits: int) -> Optional[jax.Array]:
        if self.codebook is None:
            return None
        if callable(self.codebook):
            return self.codebook(bits)
        if self.codebook.shape[-1] != (1 << bits):
            raise ValueError(
                f"explicit codebook has {self.codebook.shape[-1]} entries "
                f"but a leaf resolved to {bits} bits (2**{bits} needed) — "
                "mixed policies need a callable codebook factory")
        return self.codebook

    def is_mixed(self) -> bool:
        return (bool(self.rules) or bool(self.act_rules)
                or self.allocation is not None)

    def to_spec(self) -> Dict[str, Any]:
        """JSON-safe description (stored in checkpoint manifests)."""
        cb = self.codebook
        if cb is not None:
            if not callable(cb):
                raise ValueError(
                    "explicit codebook arrays are not spec-serializable; "
                    "use a named factory (nf_codebook) or None")
            if getattr(cb, "__name__", "") != "nf_codebook":
                raise ValueError(f"unknown codebook factory {cb!r}")
            cb = "nf"
        return {"bits": int(self.bits), "group_size": int(self.group_size),
                "min_size": int(self.min_size),
                "skip_embed": bool(self.skip_embed), "codebook": cb,
                "rules": [[p, int(b)] for p, b in self.rules],
                "allocation": (self.allocation.to_spec()
                               if self.allocation is not None else None),
                "act_bits": (int(self.act_bits)
                             if self.act_bits is not None else None),
                "act_rules": [[p, int(b)] for p, b in self.act_rules]}

    @staticmethod
    def from_spec(spec: Mapping[str, Any]) -> "QuantPolicy":
        cb = spec.get("codebook")
        if cb == "nf":
            cb = nf_codebook
        elif cb is not None:
            raise ValueError(f"unknown codebook spec {cb!r}")
        alloc = spec.get("allocation")
        act_bits = spec.get("act_bits")
        return QuantPolicy(
            bits=int(spec.get("bits", 4)),
            group_size=int(spec.get("group_size", 128)),
            min_size=int(spec.get("min_size", 65536)),
            skip_embed=bool(spec.get("skip_embed", True)),
            codebook=cb,
            rules=tuple((p, int(b)) for p, b in spec.get("rules", ())),
            allocation=(BitAllocation.from_spec(alloc)
                        if alloc else None),
            act_bits=int(act_bits) if act_bits is not None else None,
            act_rules=tuple((p, int(b))
                            for p, b in spec.get("act_rules", ())))


def _check_bits(b: int) -> int:
    if b not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {b}")
    return b


def _check_abits(b: Optional[int]) -> Optional[int]:
    if b is not None and b not in SUPPORTED_ABITS:
        raise ValueError(
            f"activation bits must be one of {SUPPORTED_ABITS} or None, "
            f"got {b}")
    return b


def _should_quantize(path: str, w, policy: QuantPolicy) -> bool:
    if not hasattr(w, "ndim") or w.ndim != 2:
        return False
    if w.size < policy.min_size:
        return False
    if policy.skip_embed and ("embed" in path):
        return False
    if w.shape[0] % policy.group_size != 0:
        return False
    return True


def _should_quantize_stacked(path: str, w, policy: QuantPolicy) -> bool:
    """Scan-stacked [L, K, N] / MoE [L, E, K, N] weights."""
    return (hasattr(w, "ndim") and w.ndim >= 3
            and "embed" not in path
            and w.shape[-2] % policy.group_size == 0
            and w.shape[-2] * w.shape[-1] >= policy.min_size)


def _scalar_bits(spec: BitsSpec, path: str, offset: int,
                 seg_len: Optional[int], check=_check_bits):
    """Resolve a BitsSpec to the single static bits of one leaf/segment."""
    if spec is None:
        return None
    if isinstance(spec, (tuple, list)):
        if seg_len is None:
            raise ValueError(
                f"per-layer bits on non-stacked leaf {path}: {spec}")
        window = set(spec[offset:offset + seg_len])
        if len(window) != 1:
            raise ValueError(
                f"heterogeneous bits {spec} for {path} require a top-level "
                "'blocks' stack (segmentation); got an unsplittable tree")
        return check(None if spec[offset] is None else int(spec[offset]))
    return check(int(spec))


def _quantize_stacked(w, bits: int, policy: QuantPolicy,
                      abits: Optional[int] = None) -> "StackedQTensor":
    """Quantize a stacked weight per slice (vmap over leading dims).

    The codebook is tiled along the first leading dim so the whole
    StackedQTensor can ride through ``lax.scan`` as an xs pytree."""
    from repro.core.quant import pack_grouped
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    g = policy.group_size
    cb = policy.codebook_for(bits)
    codebook = (_uniform_codebook(bits) if cb is None else cb).astype(
        jnp.float32)

    def one(w2d):
        wg = w2d.astype(jnp.float32).reshape(k // g, g, n)
        scale = jnp.max(jnp.abs(wg), axis=1)
        scale = jnp.where(scale == 0, 1.0, scale)
        codes = jnp.argmin(
            jnp.abs((wg / scale[:, None, :])[..., None] - codebook),
            axis=-1).astype(jnp.uint32).reshape(k, n)
        return pack_grouped(codes, bits, g), scale

    packed, scales = jax.vmap(one)(w.reshape((-1, k, n)))
    packed = packed.reshape(lead + packed.shape[1:])
    scales = scales.reshape(lead + scales.shape[1:])
    return StackedQTensor(
        packed=packed, scales=scales,
        codebook=jnp.tile(codebook[None], (lead[0], 1)),
        bits=bits, group_size=g, k=k, abits=abits)


def _quantize_tree(params, policy: QuantPolicy, offset: int = 0):
    """Quantize one tree whose resolved bits are uniform per leaf.

    ``offset`` is the absolute layer index of stacked leaves' first slice
    (nonzero when quantizing a blocks segment)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    before = after = 0
    out = []
    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        before += w.size * w.dtype.itemsize
        if _should_quantize(pstr, w, policy):
            b = _scalar_bits(policy.bits_for(pstr), pstr, 0, None)
            ab = _scalar_bits(policy.abits_for(pstr), pstr, 0, None,
                              check=_check_abits)
            qt = quantize(w, b, policy.group_size,
                          codebook=policy.codebook_for(b))
            if ab is not None:
                qt = dataclasses.replace(qt, abits=ab)
            after += qt.nbytes()
            out.append(qt)
        elif _should_quantize_stacked(pstr, w, policy):
            b = _scalar_bits(policy.bits_for(pstr), pstr, offset,
                             w.shape[0])
            ab = _scalar_bits(policy.abits_for(pstr), pstr, offset,
                              w.shape[0], check=_check_abits)
            stacked = _quantize_stacked(w, b, policy, abits=ab)
            after += stacked.packed.size * 4 + stacked.scales.size * 4
            out.append(stacked)
        else:
            after += w.size * w.dtype.itemsize
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out), before, after


def _segment_bounds(params, policy: QuantPolicy) -> Optional[List[int]]:
    """Layer cut points implied by per-layer bit specs on blocks leaves.

    Both the weight and the activation allocation segment the stack: a
    segment is maximal in the joint (wbits, abits) assignment, since a
    scan body can only carry one static precision pair per leaf.  Returns
    None when no segmentation is needed (no per-layer spec, or all
    per-layer specs constant)."""
    if not (isinstance(params, dict) and "blocks" in params
            and not isinstance(params["blocks"], (list, tuple))):
        return None
    flat = jax.tree_util.tree_flatten_with_path(
        {"blocks": params["blocks"]})[0]
    n_layers = None
    per_layer: List[Tuple[int, ...]] = []
    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        if not (_should_quantize(pstr, w, policy)
                or _should_quantize_stacked(pstr, w, policy)):
            continue
        for spec in (policy.bits_for(pstr), policy.abits_for(pstr)):
            if not isinstance(spec, (tuple, list)):
                continue
            if w.ndim < 3:
                raise ValueError(
                    f"per-layer bits on non-stacked leaf {pstr}")
            if len(spec) != w.shape[0]:
                raise ValueError(
                    f"allocation for {pstr} has {len(spec)} entries, stack "
                    f"has {w.shape[0]} layers")
            if n_layers is None:
                n_layers = w.shape[0]
            per_layer.append(tuple(spec))
    if not per_layer:
        return None
    cuts = [0]
    for layer in range(1, n_layers):
        if any(s[layer] != s[layer - 1] for s in per_layer):
            cuts.append(layer)
    cuts.append(n_layers)
    return cuts if len(cuts) > 2 else None


def quantize_params(params, policy: QuantPolicy = QuantPolicy()):
    """Convert a parameter tree to the SAIL serving format.

    Stacked weights — scan-stacked layers [L, K, N] and MoE experts
    [L, E, K, N] — are quantized per slice (vmap over leading dims).
    Bits are resolved per path (``policy.bits_for``); a per-layer tuple on
    a ``blocks`` leaf splits the stack into uniform-bits segments and the
    returned tree carries ``params["blocks"]`` as a list of stacked trees
    (see module docstring).  Returns (quantized tree, bytes_before,
    bytes_after).
    """
    bounds = _segment_bounds(params, policy)
    if bounds is None:
        return _quantize_tree(params, policy)
    rest = {k: v for k, v in params.items() if k != "blocks"}
    out, before, after = _quantize_tree(rest, policy)
    segments = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        sub = jax.tree_util.tree_map(lambda x: x[a:b], params["blocks"])
        qseg, sb, sa = _quantize_tree({"blocks": sub}, policy, offset=a)
        segments.append(qseg["blocks"])
        before += sb
        after += sa
    out = dict(out)
    out["blocks"] = segments
    return out, before, after


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedQTensor:
    """QTensor stacked along a leading axis (scan layers / MoE experts)."""
    packed: jax.Array      # [E, (K//G)*wpg, N]
    scales: jax.Array      # [E, K//G, N]
    codebook: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    abits: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))

    def __getitem__(self, i):
        cb = self.codebook if self.codebook.ndim == 1 else self.codebook[i]
        return QTensor(packed=self.packed[i], scales=self.scales[i],
                       codebook=cb, bits=self.bits,
                       group_size=self.group_size, k=self.k,
                       abits=self.abits)

    @property
    def n(self):
        return self.packed.shape[-1]

    @property
    def shape(self):
        """Logical (unquantized) weight shape."""
        lead = self.packed.shape[:-2]
        return lead + (self.k, self.packed.shape[-1])


def dequantize_any(w):
    """Array | QTensor | StackedQTensor -> f32 array (oracle path)."""
    from repro.core.quant import dequantize, unpack_grouped
    if isinstance(w, QTensor):
        return dequantize(w)
    if isinstance(w, StackedQTensor):
        cb = w.codebook if w.codebook.ndim == 1 else w.codebook[0]

        def one(packed, scales):
            codes = unpack_grouped(packed, w.bits, w.group_size, w.k)
            vals = cb[codes].reshape(
                w.k // w.group_size, w.group_size, -1)
            return (vals * scales[:, None, :]).reshape(w.k, -1)

        if w.packed.ndim == 2:
            return one(w.packed, w.scales)
        lead = w.packed.shape[:-2]
        flat_p = w.packed.reshape((-1,) + w.packed.shape[-2:])
        flat_s = w.scales.reshape((-1,) + w.scales.shape[-2:])
        out = jax.vmap(one)(flat_p, flat_s)
        return out.reshape(lead + out.shape[-2:])
    return w


def _einsum_scale_to_out(spec: str, x_shape, xs: jax.Array) -> Optional[jax.Array]:
    """Broadcast per-token activation scales to the einsum output.

    For ``spec`` where x's last subscript is the contracted axis and every
    other x subscript appears in the output (all MoE expert einsums),
    returns ``xs`` reshaped/transposed so ``einsum(xq, w) * xs_out`` equals
    the serve-path semantics.  Returns None when the spec doesn't fit
    (caller falls back to folding the scale into the input)."""
    lhs, out = spec.split("->")
    x_sub, _ = lhs.split(",")
    keep = x_sub[:-1]                       # non-contracted x subscripts
    if x_sub[-1] in out or any(c not in out for c in keep):
        return None
    xs_sq = xs[..., 0]                      # [*x_shape[:-1]]
    order = [keep.index(c) for c in out if c in keep]
    xs_t = jnp.transpose(xs_sq, order)
    dims, it = [], iter(xs_t.shape)
    for c in out:
        dims.append(next(it) if c in keep else 1)
    return xs_t.reshape(dims)


def einsum_q(spec: str, x: jax.Array, w: Any) -> jax.Array:
    """einsum where w may be stacked-quantized (MoE expert einsums).

    When the weight carries ``abits``, the real int path runs: per-token
    quantized activation codes enter the einsum and the per-token scale is
    applied to the output — the same integer-compute-then-scale semantics
    as the LUT-GEMV kernel, not fake-quant."""
    x, w = _apply_act_quant(x, w)
    if isinstance(w, (QTensor, StackedQTensor)):
        wd = dequantize_any(w).astype(x.dtype)
        if (w.abits is not None
                and jnp.issubdtype(x.dtype, jnp.floating)):
            xq, xs = quantize_activations(x, w.abits)
            xs_out = _einsum_scale_to_out(spec, x.shape, xs)
            if xs_out is not None:
                y = jnp.einsum(spec, xq.astype(jnp.float32),
                               wd.astype(jnp.float32))
                return (y * xs_out).astype(x.dtype)
            # spec not output-mappable: fold the scale into the input
            x = (xq.astype(jnp.float32) * xs).astype(x.dtype)
        return jnp.einsum(spec, x, wd)
    return jnp.einsum(spec, x, w)
