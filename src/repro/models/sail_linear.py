"""SailLinear: quantized-weight matmul dispatch, the framework's integration
point for the paper's technique.

Every weight matmul in the model goes through ``mm(x, w)``:
  * training / unquantized serving: ``w`` is a plain array -> jnp.dot;
  * SAIL serving: ``w`` is a ``QTensor`` (packed intN + group scales +
    codebook LUT) -> the LUT-dequant matmul (Pallas kernel on TPU, its
    same-semantics jnp form when lowering on CPU / inside the dry-run).

``quantize_params`` converts a trained parameter tree into the SAIL serving
format (the offline step the ``ql`` instruction field selects at runtime);
embedding tables and 1-D params (norms, biases) stay in f32, mirroring the
paper's mixed-precision outlier handling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize, _uniform_codebook

# Module-level backend switch: "jnp" (XLA path — used under pjit / dry-run)
# or "pallas" (kernel path, interpret=True on CPU).
_BACKEND = "jnp"


def set_backend(backend: str) -> None:
    global _BACKEND
    assert backend in ("jnp", "pallas")
    _BACKEND = backend


def mm(x: jax.Array, w: Any) -> jax.Array:
    """x [..., K] @ w [K, N] with QTensor dispatch."""
    if isinstance(w, StackedQTensor) and w.packed.ndim == 2:
        # a scan-sliced layer: reinterpret as a plain QTensor
        w = QTensor(packed=w.packed, scales=w.scales,
                    codebook=w.codebook, bits=w.bits,
                    group_size=w.group_size, k=w.k)
    if isinstance(w, QTensor):
        from repro.kernels.lut_gemv.ops import lut_matmul
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = lut_matmul(x2, w, out_dtype=x.dtype if x.dtype != jnp.int32
                       else jnp.float32, backend=_BACKEND)
        return y.reshape(*lead, w.n)
    return x @ w


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4
    group_size: int = 128
    min_size: int = 65536          # don't quantize small tensors
    skip_embed: bool = True        # gathers can't stream through LUT-GEMV
    codebook: Optional[jax.Array] = None


def _should_quantize(path: str, w, policy: QuantPolicy) -> bool:
    if not hasattr(w, "ndim") or w.ndim != 2:
        return False
    if w.size < policy.min_size:
        return False
    if policy.skip_embed and ("embed" in path):
        return False
    if w.shape[0] % policy.group_size != 0:
        return False
    return True


def quantize_params(params, policy: QuantPolicy = QuantPolicy()):
    """Convert a parameter tree to the SAIL serving format.

    Stacked weights — scan-stacked layers [L, K, N] and MoE experts
    [L, E, K, N] — are quantized per slice (vmap over leading dims).
    The codebook is tiled along the first leading dim so the whole
    StackedQTensor can ride through ``lax.scan`` as an xs pytree.
    Returns (quantized tree, bytes_before, bytes_after).
    """
    from repro.core.quant import pack_grouped
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    before = after = 0
    out = []

    def quantize_arrays(w2d, codebook):
        k, n = w2d.shape
        g = policy.group_size
        wg = w2d.astype(jnp.float32).reshape(k // g, g, n)
        scale = jnp.max(jnp.abs(wg), axis=1)
        scale = jnp.where(scale == 0, 1.0, scale)
        codes = jnp.argmin(
            jnp.abs((wg / scale[:, None, :])[..., None] - codebook),
            axis=-1).astype(jnp.uint32).reshape(k, n)
        return pack_grouped(codes, policy.bits, g), scale

    for path, w in flat:
        pstr = jax.tree_util.keystr(path)
        before += w.size * w.dtype.itemsize
        if _should_quantize(pstr, w, policy):
            qt = quantize(w, policy.bits, policy.group_size,
                          codebook=policy.codebook)
            after += qt.nbytes()
            out.append(qt)
        elif (hasattr(w, "ndim") and w.ndim >= 3
              and "embed" not in pstr
              and w.shape[-2] % policy.group_size == 0
              and w.shape[-2] * w.shape[-1] >= policy.min_size):
            lead = w.shape[:-2]
            k, n = w.shape[-2:]
            codebook = (policy.codebook if policy.codebook is not None
                        else _uniform_codebook(policy.bits)).astype(
                jnp.float32)
            flat_w = w.reshape((-1, k, n))
            qfn = jax.vmap(lambda a: quantize_arrays(a, codebook))
            packed, scales = qfn(flat_w)
            packed = packed.reshape(lead + packed.shape[1:])
            scales = scales.reshape(lead + scales.shape[1:])
            stacked = StackedQTensor(
                packed=packed, scales=scales,
                codebook=jnp.tile(codebook[None], (lead[0], 1)),
                bits=policy.bits, group_size=policy.group_size, k=k)
            after += packed.size * 4 + scales.size * 4
            out.append(stacked)
        else:
            after += w.size * w.dtype.itemsize
            out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out), before, after


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedQTensor:
    """QTensor stacked along a leading axis (scan layers / MoE experts)."""
    packed: jax.Array      # [E, (K//G)*wpg, N]
    scales: jax.Array      # [E, K//G, N]
    codebook: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    group_size: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))

    def __getitem__(self, i):
        cb = self.codebook if self.codebook.ndim == 1 else self.codebook[i]
        return QTensor(packed=self.packed[i], scales=self.scales[i],
                       codebook=cb, bits=self.bits,
                       group_size=self.group_size, k=self.k)

    @property
    def n(self):
        return self.packed.shape[-1]

    @property
    def shape(self):
        """Logical (unquantized) weight shape."""
        lead = self.packed.shape[:-2]
        return lead + (self.k, self.packed.shape[-1])


def dequantize_any(w):
    """Array | QTensor | StackedQTensor -> f32 array (oracle path)."""
    from repro.core.quant import dequantize, unpack_grouped
    if isinstance(w, QTensor):
        return dequantize(w)
    if isinstance(w, StackedQTensor):
        cb = w.codebook if w.codebook.ndim == 1 else w.codebook[0]

        def one(packed, scales):
            codes = unpack_grouped(packed, w.bits, w.group_size, w.k)
            vals = cb[codes].reshape(
                w.k // w.group_size, w.group_size, -1)
            return (vals * scales[:, None, :]).reshape(w.k, -1)

        if w.packed.ndim == 2:
            return one(w.packed, w.scales)
        lead = w.packed.shape[:-2]
        flat_p = w.packed.reshape((-1,) + w.packed.shape[-2:])
        flat_s = w.scales.reshape((-1,) + w.scales.shape[-2:])
        out = jax.vmap(one)(flat_p, flat_s)
        return out.reshape(lead + out.shape[-2:])
    return w


def einsum_q(spec: str, x: jax.Array, w: Any) -> jax.Array:
    """einsum where w may be stacked-quantized (MoE expert einsums)."""
    if isinstance(w, (QTensor, StackedQTensor)):
        w = dequantize_any(w).astype(x.dtype)
    return jnp.einsum(spec, x, w)
