"""Selective SSM (Mamba-style) branch for the hymba hybrid architecture.

Hymba (arXiv:2411.13676) runs attention heads and mamba heads *in parallel*
within each block, summing their (normalized) outputs.  This module
implements the mamba branch: in-projection -> short causal conv ->
selective SSM (input-dependent B, C, dt; diagonal A) -> out-projection.

Sequence processing uses an associative scan over the diagonal recurrence
h_t = a_t * h_{t-1} + b_t (parallel in T, the TPU-friendly form); decode
carries (conv window, ssm state) in the cache — O(1) per token, which is
why hymba runs the long_500k shape.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import dense_init
from repro.models.sail_linear import mm
from repro.dist.sharding import maybe_constrain


class SSMState(NamedTuple):
    conv: jax.Array   # [B, conv_k - 1, inner]
    h: jax.Array      # [B, inner, d_state]


def ssm_inner(cfg: ModelConfig) -> int:
    return int(cfg.ssm_expand * cfg.d_model * (
        cfg.hybrid_ratio if cfg.family == "hybrid" else 1.0))


def ssm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    d, n = cfg.d_model, cfg.ssm_state
    inner = ssm_inner(cfg)
    dt_rank = max(1, d // 16)
    return {
        "w_in": dense_init(ks[0], (d, 2 * inner)),        # x and gate z
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, inner), fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((inner,)),
        "w_bcdt": dense_init(ks[2], (inner, 2 * n + dt_rank)),
        "w_dt": dense_init(ks[3], (dt_rank, inner), fan_in=dt_rank),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (inner,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (inner, 1))),           # [inner, n]
        "d_skip": jnp.ones((inner,)),
        "w_out": dense_init(ks[5], (inner, d), fan_in=inner),
    }


def _conv_causal(x, w, b, state: Optional[jax.Array] = None):
    """Depthwise causal conv along T.  x: [B, T, C]; w: [K, C]."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):, :]


def _ssm_scan(a, bx, h0):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + bx_t via
    associative scan.  a, bx: [B, T, inner, n]."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_, b_ = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return a_ * h0[:, None] + b_   # fold in initial state


def apply_ssm(p, x, cfg: ModelConfig,
              state: Optional[SSMState] = None,
              return_state: bool = False):
    """x: [B, T, D] -> [B, T, D] (+ updated SSMState)."""
    b, t, d = x.shape
    n = cfg.ssm_state
    inner = p["w_in"].shape[-1] // 2
    dt_rank = p["w_bcdt"].shape[-1] - 2 * n

    xz = mm(x, p["w_in"])
    xs, z = jnp.split(xz, 2, axis=-1)                     # [B, T, inner]
    conv_state = state.conv if state is not None else None
    xs, new_conv = _conv_causal(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    xs = maybe_constrain(xs, "batch", None, "model")
    bcdt = mm(xs, p["w_bcdt"])
    bmat, cmat, dtr = jnp.split(bcdt, [n, 2 * n], axis=-1)
    dt = jax.nn.softplus(mm(dtr, p["w_dt"]) + p["dt_bias"])  # [B, T, inner]
    a = -jnp.exp(p["a_log"])                              # [inner, n]

    dt = maybe_constrain(dt, "batch", None, "model")
    da = jnp.exp(dt[..., None] * a)                       # [B, T, inner, n]
    da = maybe_constrain(da, "batch", None, "model", None)
    dbx = dt[..., None] * bmat[:, :, None, :] * xs[..., None]
    h0 = state.h if state is not None else jnp.zeros((b, inner, n))
    dbx = maybe_constrain(dbx, "batch", None, "model", None)
    h = _ssm_scan(da, dbx, h0)                            # [B, T, inner, n]
    h = maybe_constrain(h, "batch", None, "model", None)
    y = jnp.einsum("btin,btn->bti", h, cmat) + xs * p["d_skip"]
    y = y * jax.nn.silu(z)
    out = mm(y, p["w_out"])
    if return_state:
        return out, SSMState(conv=new_conv, h=h[:, -1])
    return out


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    inner = ssm_inner(cfg)
    return SSMState(conv=jnp.zeros((batch, cfg.ssm_conv - 1, inner)),
                    h=jnp.zeros((batch, inner, cfg.ssm_state)))
