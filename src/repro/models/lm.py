"""Causal language model: init / train forward / prefill / decode.

Layer stack lowers as one ``jax.lax.scan`` over stacked per-layer params
(compile-time friendly at 512 devices); each scan body is rematerialized
when cfg.remat.  Serving supports SAIL-quantized weights (QTensor leaves)
and optionally int8-quantized ring-buffer KV caches.

VLM (phi-3-vision) rides on the same class: ``prefix_embeds`` (the stubbed
CLIP patch embeddings) are concatenated ahead of the token embeddings.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import ModelConfig
from repro.models.layers import dense_init, norm_init, apply_norm, \
    sinusoidal_positions
from repro.models.sail_linear import mm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def n_scan_blocks(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        assert cfg.n_layers % cfg.slstm_every == 0
        return cfg.n_layers // cfg.slstm_every
    return cfg.n_layers


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_embed, k_blocks, k_head, k_pos = jax.random.split(key, 4)
    nb = n_scan_blocks(cfg)
    block_keys = jax.random.split(k_blocks, nb)
    blocks = jax.vmap(lambda k: blk.block_init(k, cfg))(block_keys)
    p = {
        "embed": dense_init(k_embed, (cfg.vocab, cfg.d_model)) * cfg.d_model ** 0.5,
        "blocks": blocks,
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab))
    if cfg.pos == "learned":
        p["pos_embed"] = dense_init(k_pos, (cfg.max_seq, cfg.d_model))
    return p


def _layer_slice(stacked, i):
    """Slice layer i out of scan-stacked params (handles QTensor leaves)."""
    return jax.tree_util.tree_map(lambda a: a[i], stacked)


# ---------------------------------------------------------------------------
# segmented layer stacks (mixed-precision serving)
# ---------------------------------------------------------------------------
#
# ``quantize_params`` with a per-layer bit allocation emits
# ``params["blocks"]`` as a LIST of scan-stacked trees (consecutive layers
# sharing one static precision each), because a single ``lax.scan`` can
# only carry one static ``bits``/``abits`` pair per stacked leaf — a
# segment is maximal in the JOINT (wbits, abits) assignment, so an
# activation-precision change cuts the stack exactly like a weight one.
# Inside a segment, every ``mm`` on a leaf carrying ``abits`` runs the
# *real* int-activation LUT-GEMV path (integer codes + per-token scale
# through the kernel), so the served datapath matches what the joint
# allocator priced.
# All model entry points below scan the segments back-to-back; a plain
# (non-list) blocks tree is the 1-segment case and lowers exactly as
# before.  Each segment traces and compiles its own scan body, so
# compile cost grows linearly with segment count — the allocator's
# ``max_segments`` cap (repro.core.sensitivity.enforce_max_segments)
# exists to bound it, and tests/test_joint_precision.py pins the
# scan-body-per-segment invariant.

def block_segments(params) -> list:
    """params["blocks"] as a list of stacked segment trees."""
    blocks = params["blocks"]
    if isinstance(blocks, (list, tuple)):
        return list(blocks)
    return [blocks]


def _segment_len(segment) -> int:
    """Number of layers in one stacked segment tree."""
    return jax.tree_util.tree_leaves(segment)[0].shape[0]


def _scan_segments(body_fn, x, segments):
    """Run ``lax.scan(body_fn, x, seg)`` over each segment in order.

    Returns (x, [per-segment stacked ys])."""
    ys = []
    for seg in segments:
        x, y = jax.lax.scan(body_fn, x, seg)
        ys.append(y)
    return x, ys


def _concat_segments(parts):
    """Concatenate per-segment stacked pytrees back to [L, ...] arrays."""
    if len(parts) == 1:
        return parts[0]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *parts)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig,
                 prefix_embeds: Optional[jax.Array] = None,
                 pos_offset: int = 0):
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    t = x.shape[1]
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos_offset,
                                             t, 0)[None]
    elif cfg.pos == "sinusoidal":
        x = x + sinusoidal_positions(pos_offset + t, cfg.d_model)[pos_offset:][None]
    return x


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return mm(x, params["lm_head"])


# ---------------------------------------------------------------------------
# train / full-sequence forward
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig,
            prefix_embeds: Optional[jax.Array] = None,
            moe_mode: str = "dispatch"):
    """tokens [B, T] -> (logits [B, T(+P), V], aux_loss)."""
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(carry, p_l):
        x = carry
        y, aux, _ = blk.block_apply_seq(p_l, x, cfg, positions,
                                        moe_mode=moe_mode)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxs = _scan_segments(body_fn, x, block_segments(params))
    x = apply_norm(params["final_norm"], x, cfg)
    aux = sum(jnp.sum(a) for a in auxs)
    return lm_logits(params, x, cfg), aux


def chunked_nll(x, head, targets, mask, chunk: int = 1024,
                transpose_head: bool = False):
    """Cross entropy without materializing full [B, T, V] logits.

    Computes per-T-chunk logits -> (sum nll, sum count), each chunk
    rematerialized so backward recomputes its logits instead of storing
    them (the vocab-sized f32 logits were the largest buffers in the
    dry-run memory analysis for V >= 32k).
    """
    b, t, d = x.shape
    if t % chunk or t <= chunk:
        return _nll_dense(x, head, targets, mask, transpose_head)
    n = t // chunk
    xc = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def one(carry, inp):
        s, c = carry
        xi, ti, mi = inp
        si, ci = _nll_dense(xi, head, ti, mi, transpose_head,
                            reduce_mean=False)
        return (s + si, c + ci), None

    (s, c), _ = jax.lax.scan(one, (jnp.zeros(()), jnp.zeros(())),
                             (xc, tc, mc))
    return s / jnp.maximum(c, 1.0)


def _nll_dense(x, head, targets, mask, transpose_head,
               reduce_mean: bool = True):
    logits = (x @ head.T if transpose_head else mm(x, head)).astype(
        jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    s = jnp.sum((logz - gold) * mask)
    c = jnp.sum(mask)
    if reduce_mean:
        return s / jnp.maximum(c, 1.0)
    return s, c


def loss_fn(params, batch, cfg: ModelConfig, moe_mode: str = "dispatch",
            aux_weight: float = 0.01):
    """Next-token cross entropy.  batch: {tokens [B, T+1]} (+prefix)."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = embed_tokens(params, inputs, cfg, batch.get("prefix_embeds"))
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(carry, p_l):
        y, aux, _ = blk.block_apply_seq(p_l, carry, cfg, positions,
                                        moe_mode=moe_mode)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxs = _scan_segments(body_fn, x, block_segments(params))
    x = apply_norm(params["final_norm"], x, cfg)
    npfx = x.shape[1] - targets.shape[1]
    if npfx:
        x = x[:, npfx:]
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    if cfg.tie_embeddings:
        nll = chunked_nll(x, params["embed"], targets, mask,
                          transpose_head=True)
    else:
        nll = chunked_nll(x, params["lm_head"], targets, mask)
    aux = sum(jnp.sum(a) for a in auxs)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with ring-buffer KV cache
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, batch: int, cache_len: int,
               quant_kv: bool = False) -> Dict[str, Any]:
    """Allocate the stacked per-layer decode cache.

    With ``batch = max_batch`` this is the serving engine's fixed slot
    pool: a ``[max_batch, cache_len]`` KV arena whose rows (slots) are
    independently written by ``prefill_into_slot`` and advanced by
    ``decode_step(..., active_mask=...)`` — requests come and go without
    the pool ever being reshaped or reallocated.
    """
    from repro.models import ssm as ssm_lib
    from repro.models import xlstm as xlstm_lib
    nb = n_scan_blocks(cfg)
    cache: Dict[str, Any] = {"length": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        layers: Dict[str, Any] = {}
        for i in range(cfg.slstm_every):
            if i == cfg.slstm_every - 1:
                st = xlstm_lib.init_slstm_state(cfg, batch)
                layers[f"slstm_{i}"] = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st)
            else:
                st = xlstm_lib.init_mlstm_state(cfg, batch)
                layers[f"mlstm_{i}"] = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st)
        cache["layers"] = layers
        return cache
    kv_shape = (nb, batch, cache_len, cfg.n_kv, cfg.head_dim)
    sc_shape = (nb, batch, cache_len, cfg.n_kv, 1)
    layers = {
        "k": jnp.zeros(kv_shape, jnp.int8 if quant_kv else jnp.float32),
        "v": jnp.zeros(kv_shape, jnp.int8 if quant_kv else jnp.float32),
    }
    if quant_kv:
        layers["k_scale"] = jnp.zeros(sc_shape, jnp.float32)
        layers["v_scale"] = jnp.zeros(sc_shape, jnp.float32)
    if cfg.family == "hybrid":
        st = ssm_lib.init_ssm_state(cfg, batch)
        layers["ssm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (nb,) + a.shape), st)
    cache["layers"] = layers
    return cache


def init_paged_cache(params, cfg: ModelConfig, max_batch: int,
                     num_blocks: int, block_size: int,
                     quant_kv: bool = False) -> Dict[str, Any]:
    """Allocate a paged KV block pool.

    KV arrays are ``[L, num_blocks, block_size, n_kv, head_dim]`` — a flat
    pool of fixed-size blocks shared by every request; which block holds
    which request's tokens is decided per step by the ``block_tables``
    argument of :func:`decode_step`.  Callers conventionally reserve the
    LAST physical block as a trash block: retired lanes' table entries and
    masked scatter positions point at it so dead writes never land in a
    live block.  ``length`` is still per-lane (``[max_batch]``).

    Attention families only — recurrent state (ssm/xlstm) is O(1) per lane
    and gains nothing from paging.
    """
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"paged KV cache requires an attention family, got {cfg.family!r}")
    nb = n_scan_blocks(cfg)
    kv_shape = (nb, num_blocks, block_size, cfg.n_kv, cfg.head_dim)
    sc_shape = (nb, num_blocks, block_size, cfg.n_kv, 1)
    layers: Dict[str, Any] = {
        "k": jnp.zeros(kv_shape, jnp.int8 if quant_kv else jnp.float32),
        "v": jnp.zeros(kv_shape, jnp.int8 if quant_kv else jnp.float32),
    }
    if quant_kv:
        layers["k_scale"] = jnp.zeros(sc_shape, jnp.float32)
        layers["v_scale"] = jnp.zeros(sc_shape, jnp.float32)
    return {"length": jnp.zeros((max_batch,), jnp.int32), "layers": layers}


def prefill(params, tokens, cfg: ModelConfig, cache_len: int,
            quant_kv: bool = False,
            prefix_embeds: Optional[jax.Array] = None,
            lengths: Optional[jax.Array] = None,
            moe_mode: str = "dense"):
    """Process the prompt, build the decode cache, return last logits.

    tokens: [B, T] (right-padded).  lengths: [B] true prompt lengths.
    """
    from repro.core.quant import quantize_kv
    b, t = tokens.shape
    if lengths is None:
        lengths = jnp.full((b,), t, jnp.int32)
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    tt = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(tt), (b, tt))

    def body(x, p_l):
        y, _, cache = blk.block_apply_seq(p_l, x, cfg, positions,
                                          moe_mode=moe_mode,
                                          collect_cache=True)
        return y, cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, cache_parts = _scan_segments(body_fn, x, block_segments(params))
    caches = _concat_segments(cache_parts)
    x = apply_norm(params["final_norm"], x, cfg)
    last = jnp.take_along_axis(
        x, (lengths - 1 + (tt - t))[:, None, None], axis=1)
    logits = lm_logits(params, last, cfg)[:, 0]

    # assemble ring cache from collected per-layer entries
    cache = init_cache(params, cfg, b, cache_len, quant_kv)
    cache["length"] = lengths + (tt - t)
    layers = dict(cache["layers"])
    if cfg.family == "ssm":
        for name, st in caches.items():
            layers[name] = st
    else:
        kv = caches["kv"]
        k_new, v_new = kv["k"], kv["v"]          # [L, B, T, KV, Dh]
        pad = cache_len - tt
        if pad >= 0:
            padkv = lambda a: jnp.pad(
                a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            k_new, v_new = padkv(k_new), padkv(v_new)
        else:
            k_new = k_new[:, :, -cache_len:]
            v_new = v_new[:, :, -cache_len:]
        if quant_kv:
            kq, ks = quantize_kv(k_new)
            vq, vs = quantize_kv(v_new)
            layers.update(k=kq, v=vq, k_scale=ks, v_scale=vs)
        else:
            layers.update(k=k_new, v=v_new)
        if cfg.family == "hybrid":
            layers["ssm"] = caches["ssm"]
    cache["layers"] = layers
    return logits, cache


def _scatter_slots(pool: Dict[str, Any], fresh: Dict[str, Any],
                   slots: jax.Array) -> Dict[str, Any]:
    """Write a freshly prefilled batch-b cache into pool rows ``slots``.

    Every stacked per-layer array carries batch at axis 1 ([L, B, ...]);
    ``length`` carries it at axis 0 — a pure scatter, so the pool is never
    reshaped and untouched slots keep their contents bit-for-bit.
    """
    layers = jax.tree_util.tree_map(
        lambda dst, src: dst.at[:, slots].set(src.astype(dst.dtype)),
        pool["layers"], fresh["layers"])
    length = pool["length"].at[slots].set(fresh["length"])
    return {"length": length, "layers": layers}


# Donating the pool lets XLA update the written rows in place; eager
# .at[].set would copy the whole [L, max_batch, cache_len, ...] arena on
# every admission.
_scatter_slots_jit = jax.jit(_scatter_slots, donate_argnums=(0,))


def prefill_into_slot(params, tokens, cache, slot, cfg: ModelConfig,
                      quant_kv: bool = False,
                      lengths: Optional[jax.Array] = None,
                      prefix_embeds: Optional[jax.Array] = None,
                      moe_mode: str = "dense"):
    """Prefill request(s) and write their KV into slots of an existing pool.

    tokens: [b, T] (right-padded prompts; typically b == 1 — one newly
    admitted request).  cache: the engine's ``[max_batch, cache_len]``
    pool from ``init_cache``.  slot: int or [b] int array of target rows.
    Returns (last-token logits [b, V], updated pool).  Handles the int8
    quant-KV path (codes + scales scattered together) and recurrent
    families (ssm/xlstm state rows are replaced the same way).
    """
    slots = jnp.atleast_1d(jnp.asarray(slot, jnp.int32))
    if cfg.family == "ssm":
        cache_len = 0
    else:
        cache_len = cache["layers"]["k"].shape[2]
    logits, fresh = prefill(params, tokens, cfg, cache_len=cache_len,
                            quant_kv=quant_kv, lengths=lengths,
                            prefix_embeds=prefix_embeds, moe_mode=moe_mode)
    return logits, _scatter_slots_jit(cache, fresh, slots)


def _scatter_blocks(pool: Dict[str, Any], fresh: Dict[str, Any],
                    slots: jax.Array, phys: jax.Array,
                    offs: jax.Array) -> Dict[str, Any]:
    """Write a freshly prefilled batch-b cache into pool blocks.

    fresh layers are ``[L, b, T, ...]``; ``phys``/``offs`` are flat
    ``[b*T]`` (physical block, in-block offset) destinations for each of
    the b*T prefilled token rows.  Padding rows and rows that land in
    SHARED prefix blocks are redirected to the trash block by the caller,
    so shared blocks are never rewritten (sharers keep attending to
    bit-identical KV) and duplicate trash writes only ever carry dead
    values.
    """
    def put(dst, src):
        flat = src.reshape((src.shape[0], -1) + src.shape[3:])
        return dst.at[:, phys, offs].set(flat.astype(dst.dtype))

    layers = jax.tree_util.tree_map(put, pool["layers"], fresh["layers"])
    length = pool["length"].at[slots].set(fresh["length"])
    return {"length": length, "layers": layers}


_scatter_blocks_jit = jax.jit(_scatter_blocks, donate_argnums=(0,))


def _copy_blocks(layers: Dict[str, Any], src: jax.Array,
                 dst: jax.Array) -> Dict[str, Any]:
    """Copy-on-write: duplicate pool blocks ``src`` into free blocks ``dst``."""
    return jax.tree_util.tree_map(
        lambda a: a.at[:, dst].set(a[:, src]), layers)


_copy_blocks_jit = jax.jit(_copy_blocks, donate_argnums=(0,))


def prefill_into_blocks(params, tokens, cache, slots, phys, offs,
                        cfg: ModelConfig, quant_kv: bool = False,
                        lengths: Optional[jax.Array] = None,
                        moe_mode: str = "dense"):
    """Prefill request(s) and scatter their KV into a paged block pool.

    tokens: [b, T] right-padded prompts.  cache: pool from
    :func:`init_paged_cache`.  slots: [b] decode-lane indices (for
    ``length``).  phys/offs: flat [b*T] block destinations (trash-redirected
    where a row must not be written — padding and shared prefix blocks).
    Returns (last-token logits [b, V], updated pool).
    """
    slots = jnp.atleast_1d(jnp.asarray(slots, jnp.int32))
    logits, fresh = prefill(params, tokens, cfg, cache_len=tokens.shape[1],
                            quant_kv=quant_kv, lengths=lengths,
                            moe_mode=moe_mode)
    return logits, _scatter_blocks_jit(
        cache, fresh, slots,
        jnp.asarray(phys, jnp.int32), jnp.asarray(offs, jnp.int32))


@partial(jax.jit, static_argnames=("cfg", "quant_kv", "moe_mode",
                                   "capture_layer_inputs"))
def decode_step(params, tokens, cache, cfg: ModelConfig,
                quant_kv: bool = False, moe_mode: str = "dense",
                active_mask: Optional[jax.Array] = None,
                capture_layer_inputs: bool = False,
                block_tables: Optional[jax.Array] = None):
    """One decode step.  tokens [B, 1] -> (logits [B, V], new cache).

    active_mask: optional [B] bool — retired slots keep their cache
    position frozen (their ``length`` does not advance) so the batch
    never reshapes as requests finish; their lanes still flow through
    the matmuls (the weight stream is shared either way) but their
    outputs are dead values the engine ignores until the slot is
    re-prefilled.

    block_tables: optional [B, max_blocks] int32 — paged mode.  cache is
    a pool from ``init_paged_cache``; lane i's logical block j lives in
    physical block ``block_tables[i, j]``.  Writes scatter through the
    table at ``position``; attention gathers the lane's blocks back into
    a contiguous [max_blocks*block_size] view.  Paged lanes must never
    wrap (callers enforce prompt+max_new <= max_blocks*block_size), under
    which the ring validity arithmetic reduces exactly to "slot <=
    position", so both layouts share one attention path.  Retired lanes'
    table rows point at the trash block.

    capture_layer_inputs: additionally return each block's input
    activations as a third result ([n_layers, B, 1, D]) — the vectors
    the DFM's Pattern Reuse Table would see.  The serving engine feeds
    them to ``repro.planning.tap.ActivationTap`` so measured PRT
    discounts can recalibrate on live traffic.
    """
    b = tokens.shape[0]
    position = cache["length"]                   # absolute position of token
    x = embed_tokens(params, tokens, cfg, pos_offset=0)
    if cfg.pos == "learned":
        x = jnp.take(params["embed"], tokens, axis=0) + \
            params["pos_embed"][position][:, None]
    cache_len = (cache["layers"]["k"].shape[2]
                 if cfg.family != "ssm" else 0)
    if block_tables is not None:
        # paged: logical length = table width * block size (shape[2] is the
        # block size for a [L, NB, BS, n_kv, head_dim] pool)
        cache_len = block_tables.shape[1] * cache["layers"]["k"].shape[2]

    def body(x, inp):
        p_l, cache_l = inp
        y, new_cache_l = blk.block_apply_decode(
            p_l, x, cfg, cache_l, position, cache_len,
            moe_mode=moe_mode, quant_kv=quant_kv,
            block_tables=block_tables)
        if capture_layer_inputs:
            return y, (new_cache_l, x)
        return y, new_cache_l

    segments = block_segments(params)
    new_parts = []
    captured = []
    offset = 0
    for seg in segments:
        n_seg = _segment_len(seg)
        cache_seg = jax.tree_util.tree_map(
            lambda a: a[offset:offset + n_seg], cache["layers"])
        x, new_seg = jax.lax.scan(body, x, (seg, cache_seg))
        if capture_layer_inputs:
            new_seg, xs_seg = new_seg
            captured.append(xs_seg)
        new_parts.append(new_seg)
        offset += n_seg
    new_layers = _concat_segments(new_parts)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params, x, cfg)[:, 0]
    if active_mask is None:
        new_length = cache["length"] + 1
    else:
        new_length = cache["length"] + active_mask.astype(jnp.int32)
    new_cache = {"length": new_length, "layers": new_layers}
    if capture_layer_inputs:
        return logits, new_cache, jnp.concatenate(captured, axis=0)
    return logits, new_cache


# ---------------------------------------------------------------------------
# speculative decoding: fused k-step draft + multi-token verify
# ---------------------------------------------------------------------------

# Stream salts keep speculative RNG draws (draft sampling, acceptance
# coin flips, residual resampling, bonus draws) on distinct key streams
# from the engine's committed-token sampler, all derived from the same
# (seed, uid, per-request sample index) triple so results are invariant
# to pool layout and preemption.
DRAFT_SALT = 0x0D_0A_F7
ACCEPT_SALT = 0x0A_CC_E7
RESAMPLE_SALT = 0x0E_55_1D
BONUS_SALT = 0x0B_00_05


@partial(jax.jit, static_argnames=("cfg", "quant_kv", "moe_mode"))
def verify_step(params, tokens, cache, cfg: ModelConfig,
                quant_kv: bool = False, moe_mode: str = "dense",
                active_mask: Optional[jax.Array] = None,
                block_tables: Optional[jax.Array] = None):
    """Multi-token decode for speculative verification.

    tokens [B, T] occupy absolute positions ``cache["length"] + t``.
    Returns ``(logits [B, T, V], new cache)``: row i is the next-token
    distribution after consuming ``tokens[:, :i+1]`` on top of the
    cache, and KV is written (conservative precision) for all T
    positions — overwriting whatever the draft pass left there.
    ``length`` advances by T for active lanes; the speculative driver
    resets it to the accepted frontier afterwards, which is the whole
    rollback for the ring layout (stale slots beyond the frontier have
    ``held < 0`` until they are rewritten in order).

    Not valid for ``cfg.pos == "sinusoidal"`` — like ``decode_step``
    this embeds with ``pos_offset=0``, but here T > 1 rows would get
    positions 0..T-1 instead of a constant; the engine gates that off.
    """
    b, t = tokens.shape
    position = cache["length"]
    x = embed_tokens(params, tokens, cfg, pos_offset=0)
    if cfg.pos == "learned":
        qpos = position[:, None] + jnp.arange(t)[None, :]
        x = jnp.take(params["embed"], tokens, axis=0) + \
            params["pos_embed"][qpos]
    cache_len = cache["layers"]["k"].shape[2]
    if block_tables is not None:
        cache_len = block_tables.shape[1] * cache["layers"]["k"].shape[2]

    def body(x, inp):
        p_l, cache_l = inp
        y, new_cache_l = blk.block_apply_verify(
            p_l, x, cfg, cache_l, position, cache_len,
            moe_mode=moe_mode, quant_kv=quant_kv,
            block_tables=block_tables)
        return y, new_cache_l

    segments = block_segments(params)
    new_parts = []
    offset = 0
    for seg in segments:
        n_seg = _segment_len(seg)
        cache_seg = jax.tree_util.tree_map(
            lambda a: a[offset:offset + n_seg], cache["layers"])
        x, new_seg = jax.lax.scan(body, x, (seg, cache_seg))
        new_parts.append(new_seg)
        offset += n_seg
    new_layers = _concat_segments(new_parts)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params, x, cfg)                   # [B, T, V]
    if active_mask is None:
        new_length = cache["length"] + t
    else:
        new_length = cache["length"] + t * active_mask.astype(jnp.int32)
    return logits, {"length": new_length, "layers": new_layers}


@partial(jax.jit, static_argnames=("cfg", "k", "quant_kv", "moe_mode",
                                   "temperature", "seed"))
def draft_tokens(params, tokens, cache, cfg: ModelConfig, k: int,
                 quant_kv: bool = False, moe_mode: str = "dense",
                 active_mask: Optional[jax.Array] = None,
                 block_tables: Optional[jax.Array] = None,
                 temperature: float = 0.0, seed: int = 0,
                 uids: Optional[jax.Array] = None,
                 indices: Optional[jax.Array] = None):
    """Draft ``k`` tokens per lane in ONE jitted dispatch.

    Python-unrolls k single-token decode steps (under the *draft* weight
    tree) into a single program, sampling between steps: argmax at
    ``temperature == 0``, else categorical with per-row keys
    ``fold_in(fold_in(fold_in(PRNGKey(seed), uid), index + i),
    DRAFT_SALT)`` so draft draws never collide with the committed-token
    sampler's stream.  This is where the speculative speedup comes from
    on the host backend: one dispatch (plus one verify dispatch) per
    ~E[accepted]+1 tokens instead of one per token.

    tokens: [B, 1] — the pending (committed-but-unfed) token.  Returns
    ``(draft [B, k] int32, draft_logits [B, k, V], new cache)``.  Draft
    KV lands at positions ``length .. length+k-1`` at draft precision;
    the verify pass overwrites every one of those slots, so nothing
    drafted ever survives in the cache.
    """
    drafted = []
    qlogits = []
    tok = tokens
    for i in range(k):
        logits, cache = decode_step(
            params, tok, cache, cfg, quant_kv=quant_kv, moe_mode=moe_mode,
            active_mask=active_mask, block_tables=block_tables)
        if temperature <= 0.0:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            base = jax.random.PRNGKey(seed)

            def draw(uid, idx, row):
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.fold_in(base, uid), idx),
                    DRAFT_SALT)
                return jax.random.categorical(key, row / temperature)

            nxt = jax.vmap(draw)(uids, indices + i, logits).astype(jnp.int32)
        drafted.append(nxt)
        qlogits.append(logits)
        tok = nxt[:, None]
    return (jnp.stack(drafted, axis=1), jnp.stack(qlogits, axis=1), cache)


def greedy_generate(params, prompt, cfg: ModelConfig, max_new: int,
                    cache_len: Optional[int] = None,
                    quant_kv: bool = False):
    """Reference generation loop (serving engine uses its own)."""
    b, t = prompt.shape
    cache_len = cache_len or (t + max_new)
    if cfg.window is not None:
        cache_len = min(cache_len, cfg.window)
    logits, cache = prefill(params, prompt, cfg, cache_len, quant_kv)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for _ in range(max_new):
        out.append(tok)
        logits, cache = decode_step(params, tok, cache, cfg, quant_kv)
        tok = jnp.argmax(logits, axis=-1)[:, None]
    return jnp.concatenate(out, axis=1)
