"""Encoder-decoder model (whisper-large-v3 backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings [B, S_enc, D] (the output the two
conv layers + GELU would produce).  Encoder: bidirectional attention,
sinusoidal positions.  Decoder: causal self-attention (ring KV cache) +
cross-attention to the encoder memory (computed once at prefill) + GELU
MLP.  Whisper uses LayerNorm and attention biases.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.blocks import _ring_write, _decode_attend
from repro.models.common import ModelConfig
from repro.models.layers import (apply_attention, apply_mlp, apply_norm,
                                 attention_init, dense_init, mlp_init,
                                 norm_init, sinusoidal_positions)
from repro.models.sail_linear import mm


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn_norm": norm_init(cfg), "attn": attention_init(k1, cfg),
                "mlp_norm": norm_init(cfg), "mlp": mlp_init(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self_norm": norm_init(cfg), "self_attn": attention_init(k1, cfg),
                "cross_norm": norm_init(cfg), "cross_attn": attention_init(k2, cfg),
                "mlp_norm": norm_init(cfg), "mlp": mlp_init(k3, cfg)}

    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": dense_init(ks[2], (cfg.vocab, cfg.d_model)) * cfg.d_model ** 0.5,
        "enc_blocks": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": norm_init(cfg),
        "dec_blocks": jax.vmap(dec_layer)(dec_keys),
        "dec_norm": norm_init(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, S_enc, D] stubbed conv-frontend output -> memory."""
    b, s, _ = frames.shape
    x = frames + sinusoidal_positions(s, cfg.d_model)[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, p_l):
        h = apply_norm(p_l["attn_norm"], x, cfg)
        x = x + apply_attention(p_l["attn"], h, cfg, positions=positions,
                                causal=False)
        h = apply_norm(p_l["mlp_norm"], x, cfg)
        return x + apply_mlp(p_l["mlp"], h, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(p_l, memory, cfg):
    b, s, _ = memory.shape
    k = mm(memory, p_l["cross_attn"]["wk"]).reshape(b, s, cfg.n_kv,
                                                    cfg.head_dim)
    v = mm(memory, p_l["cross_attn"]["wv"]).reshape(b, s, cfg.n_kv,
                                                    cfg.head_dim)
    if cfg.attention_bias:
        k = k + p_l["cross_attn"]["bk"].reshape(cfg.n_kv, cfg.head_dim)
        v = v + p_l["cross_attn"]["bv"].reshape(cfg.n_kv, cfg.head_dim)
    return k, v


def decode_forward(params, tokens, memory, cfg: ModelConfig,
                   return_hidden: bool = False):
    """Teacher-forced decoder pass (training).  tokens [B, T]."""
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoidal_positions(t, cfg.d_model)[None]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(x, p_l):
        h = apply_norm(p_l["self_norm"], x, cfg)
        x = x + apply_attention(p_l["self_attn"], h, cfg,
                                positions=positions, causal=True)
        h = apply_norm(p_l["cross_norm"], x, cfg)
        x = x + apply_attention(p_l["cross_attn"], h, cfg,
                                positions=positions, kv_x=memory)
        h = apply_norm(p_l["mlp_norm"], x, cfg)
        return x + apply_mlp(p_l["mlp"], h, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, cfg)
    if return_hidden:
        return x
    return x @ params["embed"].T          # whisper ties output to embedding


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {frames [B, S, D], tokens [B, T+1]}."""
    from repro.models.lm import chunked_nll
    x = decode_forward(params, batch["tokens"][:, :-1],
                       encode(params, batch["frames"], cfg), cfg,
                       return_hidden=True)
    targets = batch["tokens"][:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    nll = chunked_nll(x, params["embed"], targets, mask,
                      transpose_head=True)
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_dec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   enc_seq: int, quant_kv: bool = False):
    l = cfg.n_layers
    kv = (l, batch, cache_len, cfg.n_kv, cfg.head_dim)
    cache = {
        "length": jnp.zeros((batch,), jnp.int32),
        "layers": {
            "k": jnp.zeros(kv, jnp.int8 if quant_kv else jnp.float32),
            "v": jnp.zeros(kv, jnp.int8 if quant_kv else jnp.float32),
            "ck": jnp.zeros((l, batch, enc_seq, cfg.n_kv, cfg.head_dim)),
            "cv": jnp.zeros((l, batch, enc_seq, cfg.n_kv, cfg.head_dim)),
        },
    }
    if quant_kv:
        sc = (l, batch, cache_len, cfg.n_kv, 1)
        cache["layers"]["k_scale"] = jnp.zeros(sc, jnp.float32)
        cache["layers"]["v_scale"] = jnp.zeros(sc, jnp.float32)
    return cache


def serve_prefill(params, frames, cfg: ModelConfig, cache_len: int,
                  quant_kv: bool = False):
    """Encode audio, precompute cross-KV, return decode-ready cache."""
    memory = encode(params, frames, cfg)
    b = memory.shape[0]

    def body(_, p_l):
        k, v = _cross_kv(p_l, memory, cfg)
        return None, {"ck": k, "cv": v}

    _, cross = jax.lax.scan(body, None, params["dec_blocks"])
    cache = init_dec_cache(cfg, b, cache_len, memory.shape[1], quant_kv)
    cache["layers"]["ck"] = cross["ck"]
    cache["layers"]["cv"] = cross["cv"]
    return cache


@partial(jax.jit, static_argnames=("cfg", "quant_kv"))
def serve_decode_step(params, tokens, cache, cfg: ModelConfig,
                      quant_kv: bool = False):
    """One decoder token with self-KV ring cache + static cross-KV."""
    from repro.core.quant import quantize_kv
    b = tokens.shape[0]
    position = cache["length"]
    cache_len = cache["layers"]["k"].shape[2]
    x = jnp.take(params["embed"], tokens, axis=0)
    pe = sinusoidal_positions(cfg.max_seq, cfg.d_model)
    x = x + pe[jnp.minimum(position, cfg.max_seq - 1)][:, None]

    def body(x, inp):
        p_l, cache_l = inp
        new_cache_l = dict(cache_l)
        h = apply_norm(p_l["self_norm"], x, cfg)
        q = mm(h, p_l["self_attn"]["wq"]).reshape(b, 1, cfg.n_heads,
                                                  cfg.head_dim)
        k = mm(h, p_l["self_attn"]["wk"]).reshape(b, 1, cfg.n_kv,
                                                  cfg.head_dim)
        v = mm(h, p_l["self_attn"]["wv"]).reshape(b, 1, cfg.n_kv,
                                                  cfg.head_dim)
        slot = (position % cache_len)[:, None, None, None]
        if quant_kv:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kc = _ring_write(cache_l["k"], kq, slot)
            vc = _ring_write(cache_l["v"], vq, slot)
            ksc = _ring_write(cache_l["k_scale"], ks, slot)
            vsc = _ring_write(cache_l["v_scale"], vs, slot)
            new_cache_l.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
            kf = kc.astype(jnp.float32) * ksc
            vf = vc.astype(jnp.float32) * vsc
        else:
            kc = _ring_write(cache_l["k"], k, slot)
            vc = _ring_write(cache_l["v"], v, slot)
            new_cache_l.update(k=kc, v=vc)
            kf, vf = kc, vc
        att = _decode_attend(q, kf, vf, position, cfg, cache_len)
        x = x + mm(att.reshape(b, 1, cfg.q_dim), p_l["self_attn"]["wo"])

        h = apply_norm(p_l["cross_norm"], x, cfg)
        cq = mm(h, p_l["cross_attn"]["wq"]).reshape(b, 1, cfg.n_heads,
                                                    cfg.head_dim)
        g = cfg.n_heads // cfg.n_kv
        qg = cq.reshape(b, cfg.n_kv, g, cfg.head_dim).astype(jnp.float32)
        scores = jnp.einsum("bghd,bsgd->bghs", qg,
                            cache_l["ck"].astype(jnp.float32))
        scores = scores / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
        pa = jax.nn.softmax(scores, axis=-1)
        cro = jnp.einsum("bghs,bsgd->bghd", pa,
                         cache_l["cv"].astype(jnp.float32))
        x = x + mm(cro.reshape(b, 1, cfg.q_dim).astype(x.dtype),
                   p_l["cross_attn"]["wo"])

        h = apply_norm(p_l["mlp_norm"], x, cfg)
        return x + apply_mlp(p_l["mlp"], h, cfg), new_cache_l

    x, new_layers = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["layers"]))
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, {"length": cache["length"] + 1, "layers": new_layers}
