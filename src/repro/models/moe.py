"""Mixture-of-Experts layer (granite-moe 32e top-8, mixtral 8e top-2).

Two dispatch modes:
  * "dispatch": GShard-style capacity-based token dispatch (one-hot combine
    tensors, einsum over expert-major buffers).  FLOPs scale with top_k and
    capacity_factor — used for training where efficiency matters; experts
    shard over the mesh 'model' axis (EP) or within-expert FFN dim (TP),
    per cfg.moe_shard.
  * "dense": every expert computed for every token, combined by routing
    weights — exact (no capacity drops), used for tiny decode batches and
    as the correctness oracle for the dispatch path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import dense_init
from repro.models.sail_linear import einsum_q, mm
from repro.dist.sharding import maybe_constrain


def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.expert_ffn, cfg.n_experts
    return {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d), fan_in=f),
    }


def _router_probs(p, x, cfg: ModelConfig):
    logits = mm(x, p["router"])                              # [..., E]
    topv, topi = jax.lax.top_k(logits, cfg.top_k)
    probs = jax.nn.softmax(topv, axis=-1)                 # renormalized top-k
    return logits, topv, topi, probs


def apply_moe_dense(p, x, cfg: ModelConfig):
    """Exact dense-compute MoE: all experts, weighted by top-k router."""
    *lead, d = x.shape
    xt = x.reshape(-1, d)
    logits, _, topi, probs = _router_probs(p, xt, cfg)
    # combine weights over all experts: [T, E]
    comb = (jax.nn.one_hot(topi, cfg.n_experts) * probs[..., None]).sum(-2)
    if cfg.act == "gelu":
        h = jax.nn.gelu(einsum_q("td,edf->tef", xt, p["w_up"]))
    else:
        h = jax.nn.silu(einsum_q("td,edf->tef", xt, p["w_gate"])) * \
            einsum_q("td,edf->tef", xt, p["w_up"])
    y = einsum_q("tef,efd->ted", h, p["w_down"])        # [T, E, D]
    out = jnp.einsum("ted,te->td", y, comb)
    return out.reshape(*lead, d), _aux_loss(logits, comb, cfg)


MOE_GROUP_TOKENS = 512   # GShard dispatch group; the dispatch tensor is
# tokens x E x cap with cap = cf*tg*k/E, so bytes scale LINEARLY with
# tg — 512 keeps it ~4x smaller than 2048 at slightly higher drop
# variance (dry-run memory analysis, granite 32-expert cells)


def apply_moe_dispatch(p, x, cfg: ModelConfig):
    """Capacity-based dispatch (GShard): tokens are split into groups of
    ~MOE_GROUP_TOKENS; each group routes to per-expert buffers of capacity
    ``cf * group * k / E``.  The dispatch tensor is built per top-k slot
    ([G, T_g, E, C] never materializes with a K axis, and T_g bounds the
    quadratic T*C term) — without grouping, 32k tokens/device would need a
    multi-TB one-hot, which the dry-run memory analysis caught."""
    *lead, d = x.shape
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e = cfg.n_experts
    tg = min(MOE_GROUP_TOKENS, t)
    if t % tg:
        tg = t  # fall back to one group for odd tiny batches
    ng = t // tg
    cap = max(1, int(cfg.capacity_factor * tg * cfg.top_k / e))

    logits, _, topi, probs = _router_probs(p, xt, cfg)    # topi [T, K]
    topi_g = maybe_constrain(topi.reshape(ng, tg, cfg.top_k),
                             "batch", None, None)
    probs_g = maybe_constrain(probs.reshape(ng, tg, cfg.top_k),
                              "batch", None, None)
    xg = maybe_constrain(xt.reshape(ng, tg, d), "batch", None, None)

    # buffer position per (group, token, k): cumulative count of earlier
    # (token, k) pairs routed to the same expert within the group
    onehot = jax.nn.one_hot(topi_g, e, dtype=jnp.int32)   # [G, Tg, K, E]
    flat = onehot.reshape(ng, tg * cfg.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) * flat - 1).reshape(
        ng, tg, cfg.top_k, e)
    pos_k = jnp.take_along_axis(
        pos, topi_g[..., None], axis=-1)[..., 0]          # [G, Tg, K]
    in_cap = (pos_k < cap) & (pos_k >= 0)

    dtype = x.dtype

    @jax.checkpoint  # recompute the one-hots in backward: saving the
    def _build_dispatch(topi_g, pos_k, in_cap, probs_g):
        # per-k contrib tensors for bwd costs top_k x |disp| (tens of GB
        # for 32-expert models — caught by the dry-run memory analysis)
        disp = jnp.zeros((ng, tg, e, cap), dtype)
        comb = jnp.zeros((ng, tg, e, cap), jnp.float32)
        for k in range(cfg.top_k):                        # small static K
            oh_e = jax.nn.one_hot(topi_g[..., k], e, dtype=dtype)
            oh_c = jax.nn.one_hot(pos_k[..., k], cap, dtype=dtype)
            m = in_cap[..., k].astype(dtype)[..., None, None]
            contrib = oh_e[..., :, None] * oh_c[..., None, :] * m
            disp = disp + contrib
            comb = comb + contrib.astype(jnp.float32) * \
                probs_g[..., k, None, None]
        return disp, comb

    disp, comb = _build_dispatch(topi_g, pos_k, in_cap, probs_g)

    disp = maybe_constrain(disp, "batch", None, None, None)
    comb = maybe_constrain(comb, "batch", None, None, None)
    xe = jnp.einsum("gtd,gtec->gecd", xg, disp)           # [G, E, C, D]
    xe = maybe_constrain(xe, "batch", None, None, None)
    if cfg.act == "gelu":
        h = jax.nn.gelu(einsum_q("gecd,edf->gecf", xe, p["w_up"]))
    else:
        h = jax.nn.silu(einsum_q("gecd,edf->gecf", xe, p["w_gate"])) * \
            einsum_q("gecd,edf->gecf", xe, p["w_up"])
    ye = einsum_q("gecf,efd->gecd", h, p["w_down"])
    # NOTE (§Perf B1, refuted): forcing a reduce-scatter onto ye's D here
    # (maybe_constrain(ye, "batch", None, None, "model")) was predicted to
    # cut the row-parallel AR by ~2.5x (token-shaped vs buffer-shaped
    # payload) but GSPMD responded with an extra buffer-shaped AR on the
    # dispatch tensors plus two backward all-gathers: measured collective
    # bytes +43%.  Kept off; see EXPERIMENTS.md §Perf.
    out = jnp.einsum("gecd,gtec->gtd", ye,
                     comb.astype(ye.dtype)).reshape(t, d)
    comb_e = comb.sum(-1).reshape(t, e)                   # [T, E]
    return (out.reshape(*lead, d).astype(x.dtype),
            _aux_loss(logits, comb_e, cfg))


def _aux_loss(logits, comb, cfg: ModelConfig):
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = (comb > 0).astype(jnp.float32).mean(0)   # [E]
    frac_probs = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)


def apply_moe(p, x, cfg: ModelConfig, mode: str = "dispatch"):
    if mode == "dense":
        return apply_moe_dense(p, x, cfg)
    return apply_moe_dispatch(p, x, cfg)
