"""Fault-tolerant training loop.

Production behaviours exercised even in the CPU-scale examples:
  * periodic + final checkpointing (async, atomic, GC'd);
  * preemption handling: SIGTERM/SIGINT request a final checkpoint and a
    clean exit (restart resumes bit-exact, data iterator included);
  * elastic restart: checkpoints restore onto a different mesh/device
    count (shardings recomputed by the current plan);
  * straggler/hang watchdog: a step exceeding ``watchdog_factor`` x the
    trailing median is logged loudly (on real fleets this feeds the
    controller that evicts the slow host; in-process we surface it);
  * NaN/divergence guard: skip-and-log with a bounded budget, then abort.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import SyntheticLM


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    watchdog_factor: float = 5.0
    max_nan_skips: int = 3


class TrainLoop:
    def __init__(self, step_fn: Callable, params, opt_state,
                 data: SyntheticLM, lcfg: TrainLoopConfig,
                 shardings=None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.lcfg = lcfg
        self.shardings = shardings
        self.step = 0
        self.metrics_log: list = []
        self._preempted = False
        self._ckpt = (AsyncCheckpointer(lcfg.checkpoint_dir,
                                        lcfg.keep_checkpoints)
                      if lcfg.checkpoint_dir else None)
        self._nan_skips = 0
        self._durations: list = []

    # --- preemption --------------------------------------------------------
    def install_signal_handlers(self) -> None:
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # --- checkpoint/restore -------------------------------------------------
    def maybe_restore(self) -> bool:
        d = self.lcfg.checkpoint_dir
        if not d or latest_step(d) is None:
            return False
        (self.params, self.opt_state), extras = restore(
            d, (self.params, self.opt_state), shardings=self.shardings)
        self.step = int(extras.get("step", 0))
        self.data.load_state_dict(extras.get("data", {"step": self.step}))
        return True

    def save(self) -> None:
        if self._ckpt:
            self._ckpt.save(self.step, (self.params, self.opt_state),
                            extras={"step": self.step,
                                    "data": self.data.state_dict()})

    # --- main loop -----------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        lcfg = self.lcfg
        it = iter(self.data)
        while self.step < lcfg.total_steps and not self._preempted:
            batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            new_params, new_opt, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            if not np.isfinite(loss):
                self._nan_skips += 1
                print(f"[train] step {self.step}: non-finite loss "
                      f"({loss}); skipping update "
                      f"({self._nan_skips}/{lcfg.max_nan_skips})")
                if self._nan_skips > lcfg.max_nan_skips:
                    raise FloatingPointError(
                        "too many non-finite losses; aborting")
                continue  # params/opt_state unchanged (donated bufs: rebuilt)
            self.params, self.opt_state = new_params, new_opt
            self.step += 1
            self._durations.append(dt)

            if len(self._durations) > 20:
                med = float(np.median(self._durations[-20:]))
                if dt > lcfg.watchdog_factor * med and med > 0:
                    print(f"[watchdog] step {self.step} took {dt:.2f}s "
                          f"(median {med:.2f}s) — straggler suspected")

            if self.step % lcfg.log_every == 0:
                rec = {"step": self.step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "sec_per_step": dt}
                self.metrics_log.append(rec)
                print(f"[train] step {self.step}: loss {loss:.4f} "
                      f"gnorm {rec['grad_norm']:.2f} {dt:.2f}s/step")
            if lcfg.checkpoint_dir and \
                    self.step % lcfg.checkpoint_every == 0:
                self.save()

        if self._preempted:
            print("[train] preemption signal received — final checkpoint")
        self.save()
        if self._ckpt:
            self._ckpt.wait()
        return {"final_step": self.step, "preempted": self._preempted,
                "log": self.metrics_log}
