"""Shard-aware, elastic, async checkpointing (no orbax on this box).

Layout:  <dir>/step_<N>/
           manifest.msgpack        tree structure, shapes, dtypes, extras
           <leaf-id>.npy           one file per pytree leaf (full array) or
           <leaf-id>.shard<k>.npy  per-host shard files with global offsets

Design points for 1000+-node runs:
  * each host writes only its addressable shards (here: single host writes
    full arrays; the shard path is exercised by the multi-device tests);
  * restore is *elastic*: arrays are reassembled from shard metadata and
    re-laid-out onto whatever mesh/sharding the restoring job uses, so a
    512-chip checkpoint restores onto 256 or 1024 chips;
  * writes go to a temp dir + atomic rename — a preempted writer never
    corrupts the latest checkpoint;
  * ``AsyncCheckpointer`` snapshots device arrays to host memory, then
    writes on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_id(i: int) -> str:
    return f"leaf{i:05d}"


def _tree_paths(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def save(directory: str, step: int, tree, extras: Optional[Dict] = None,
         process_index: int = 0, process_count: int = 1) -> str:
    """Write a checkpoint.  Multi-host: each process writes its shards of
    every addressable leaf; process 0 writes the manifest."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _tree_paths(tree)
    meta = {"step": step, "leaves": [], "extras": extras or {}}
    for i, (path, leaf) in enumerate(flat):
        lid = _leaf_id(i)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, lid + ".npy"), arr)
        meta["leaves"].append({
            "id": lid, "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _update_latest(directory, step)
    return final


def _update_latest(directory: str, step: int) -> None:
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        json.dump({"step": step}, f)
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))


def _load_manifest(directory: str, step: Optional[int] = None) -> Dict:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    return meta


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        steps = [int(d.split("_")[1]) for d in os.listdir(directory)
                 if d.startswith("step_") and not d.endswith(".tmp")] \
            if os.path.isdir(directory) else []
        return max(steps) if steps else None
    with open(p) as f:
        return int(json.load(f)["step"])


def restore(directory: str, template, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``template``.

    Elastic re-shard: if ``shardings`` (a pytree of NamedSharding matching
    template) is given, each loaded array is device_put with the *new*
    sharding — the restoring job's mesh need not match the writer's.
    Returns (tree, extras).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    flat_t, treedef = _tree_paths(template)
    if len(flat_t) != len(meta["leaves"]):
        raise ValueError(
            f"checkpoint has {len(meta['leaves'])} leaves, template "
            f"{len(flat_t)} — structure changed")
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (path, tleaf), rec, shd in zip(flat_t, meta["leaves"], shard_flat):
        arr = np.load(os.path.join(d, rec["id"] + ".npy"))
        # templates may be abstract (jax.eval_shape output) — a
        # ShapeDtypeStruct carries .shape/.dtype but np.shape chokes on it
        tshape = getattr(tleaf, "shape", None)
        if tshape is None:
            tshape = np.shape(tleaf)
        if list(arr.shape) != list(tshape):
            raise ValueError(f"shape mismatch at {rec['path']}: "
                             f"{arr.shape} vs {tuple(tshape)}")
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jnp.asarray(arr, dtype=tleaf.dtype
                                      if hasattr(tleaf, "dtype") else None))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta.get("extras", {})


def save_quantized(directory: str, step: int, qtree, policy,
                   extras: Optional[Dict] = None, plan=None,
                   quant_kv: bool = True) -> str:
    """Save a SAIL-quantized (possibly mixed-precision) parameter tree.

    The ``QuantPolicy`` spec — including a sensitivity-calibrated
    per-path/per-layer bit allocation and the jointly allocated
    activation precisions (``act_per_path``/``act_bits``) — rides along
    in the manifest extras, so ``restore_quantized`` can rebuild the
    exact mixed tree structure (QTensor statics incl. ``abits``, blocks
    segmentation) from nothing but the raw model's parameter template.

    The manifest also carries the serving ``plan`` (a
    ``repro.planning.PlanSpec`` — derived from the policy when not given
    explicitly; pass the engine's ``eng.plan``, or at least ``quant_kv``,
    so KV provenance is recorded faithfully), so a restored deployment
    keeps its plan provenance (hash, SLO target, PRT mode) and can be
    re-planned without re-deriving what it was serving;
    ``restored_plan`` reads it back."""
    from repro.planning import PlanSpec
    extras = dict(extras or {})
    extras["quant_policy"] = policy.to_spec()
    if plan is None:
        try:
            plan = PlanSpec.from_policy(policy, quant_kv=quant_kv)
        except ValueError:
            plan = None    # exotic policies (explicit codebook arrays)
    if plan is not None:
        extras["plan"] = plan.to_json()
    return save(directory, step, qtree, extras)


def quantized_template(raw_template, policy):
    """Abstract (ShapeDtypeStruct) quantized tree for ``restore``: the
    structure ``quantize_params`` would emit, without doing the math."""
    from repro.models.sail_linear import quantize_params
    return jax.eval_shape(lambda p: quantize_params(p, policy)[0],
                          raw_template)


def restore_quantized(directory: str, raw_template,
                      step: Optional[int] = None):
    """Restore a quantized checkpoint given only the *unquantized* model
    params (or their shapes).  The bit policy stored by ``save_quantized``
    reconstructs the mixed tree template — heterogeneous per-leaf bits and
    scan-segmentation included.  Returns (tree, extras)."""
    from repro.models.sail_linear import QuantPolicy
    if step is None:
        # pin the step once: a background save landing mid-restore must
        # not split the manifest (template) and the weight arrays across
        # two different checkpoints
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    meta = _load_manifest(directory, step)
    spec = meta.get("extras", {}).get("quant_policy")
    if spec is None:
        raise ValueError(f"checkpoint under {directory} was not written "
                         "by save_quantized (no quant_policy in manifest)")
    policy = QuantPolicy.from_spec(spec)
    template = quantized_template(raw_template, policy)
    return restore(directory, template, step)


def restored_plan(extras: Dict):
    """The serving ``PlanSpec`` a quantized checkpoint was written under
    (from ``restore_quantized``'s extras), or None for pre-plan
    checkpoints."""
    from repro.planning import PlanSpec
    spec = extras.get("plan")
    return PlanSpec.from_json(spec) if spec is not None else None


def keep_last(directory: str, n: int = 3) -> None:
    """Garbage-collect old checkpoints, keeping the newest n."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree, extras: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extras)
                keep_last(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
