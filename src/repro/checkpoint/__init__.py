from repro.checkpoint.checkpoint import (AsyncCheckpointer, keep_last,
                                         latest_step, quantized_template,
                                         restore, restore_quantized, save,
                                         save_quantized)
