from repro.checkpoint.checkpoint import (AsyncCheckpointer, keep_last,
                                         latest_step, restore, save)
