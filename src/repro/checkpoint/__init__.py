from repro.checkpoint.checkpoint import (AsyncCheckpointer, keep_last,
                                         latest_step, quantized_template,
                                         restore, restore_quantized,
                                         restored_plan, save,
                                         save_quantized)
