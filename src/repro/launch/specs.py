"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Shapes (assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill_step
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1     -> serve_step, sub-quadratic
                                                 archs only (see DESIGN.md)

Frontend stubs: whisper gets frame embeddings [B, S, D] (conv stub output),
phi-3-vision gets 576 patch embeddings prepended to the text tokens.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# archs whose decode state is sub-quadratic (SWA window / recurrent state):
LONG_CONTEXT_OK = {"h2o-danube-3-4b", "hymba-1.5b", "mixtral-8x7b",
                   "xlstm-350m", "tinymistral-248m"}


def cell_is_runnable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in LONG_CONTEXT_OK or (
            cfg.window is not None or cfg.family in ("ssm",))
    return True


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for the given cell's step inputs (no allocation)."""
    s = SHAPES[shape]
    seq, batch, kind = s["seq"], s["batch"], s["kind"]
    d = cfg.d_model

    if kind == "train":
        if cfg.family == "encdec":
            return {"frames": sds((batch, cfg.enc_seq, d), jnp.float32),
                    "tokens": sds((batch, seq + 1), jnp.int32)}
        b: Dict[str, Any] = {"tokens": sds((batch, seq + 1), jnp.int32)}
        if cfg.frontend == "vision":
            b["prefix_embeds"] = sds((batch, cfg.vision_tokens, d),
                                     jnp.float32)
        return b

    if kind == "prefill":
        if cfg.family == "encdec":
            # encoder consumes the long sequence (longform audio)
            return {"frames": sds((batch, seq, d), jnp.float32)}
        b = {"tokens": sds((batch, seq), jnp.int32),
             "lengths": sds((batch,), jnp.int32)}
        if cfg.frontend == "vision":
            b["prefix_embeds"] = sds((batch, cfg.vision_tokens, d),
                                     jnp.float32)
        return b

    # decode: one token against a cache of `seq`
    return {"tokens": sds((batch, 1), jnp.int32)}


def decode_cache_len(cfg: ModelConfig, shape: str) -> int:
    seq = SHAPES[shape]["seq"]
    if cfg.family == "ssm":
        return 0
    if cfg.window is not None:
        return min(seq, cfg.window)
    return seq


def cache_specs(cfg: ModelConfig, shape: str, quant_kv: bool = True):
    """ShapeDtypeStructs for the decode cache (eval_shape over init_cache)."""
    from repro.models import encdec, lm
    batch = SHAPES[shape]["batch"]
    clen = decode_cache_len(cfg, shape)
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: encdec.init_dec_cache(cfg, batch, max(clen, 1),
                                          cfg.enc_seq, quant_kv))
    return jax.eval_shape(
        lambda: lm.init_cache(None, cfg, batch, max(clen, 1), quant_kv))
