"""Production serving launcher (SAIL quantized path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --ql 4 --batch 8 --requests 16

Quantizes weights to ``--ql`` bits (QTensor storage), int8 KV cache,
iteration-level batching (the paper's tensor-level scheduling).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--no-quant-kv", action="store_true")
    args = ap.parse_args()

    import repro.configs as C
    from repro.models import lm
    from repro.serving.engine import Engine, EngineConfig

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the LM server")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(
        batch_size=args.batch, cache_len=args.cache_len, quantize=True,
        ql=args.ql, group_size=min(128, cfg.d_model),
        quant_kv=not args.no_quant_kv))
    print(f"{cfg.name}: Q{args.ql} weights "
          f"({eng.compression:.2f}x compression), "
          f"{'int8' if not args.no_quant_kv else 'f32'} KV")

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(4, 16))
        eng.submit(rng.integers(0, cfg.vocab, size=n).tolist(),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    st = eng.stats()
    print(f"{st['requests']} requests, {st['generated_tokens']} tokens, "
          f"{st['generated_tokens']/dt:.2f} tok/s, "
          f"mean latency {st['mean_latency_s']:.2f}s, "
          f"{st['iterations']} iterations")


if __name__ == "__main__":
    main()
