"""Production serving launcher (SAIL quantized path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --ql 4 --batch 8 --requests 16

Quantizes weights to ``--ql`` bits (QTensor storage), int8 KV cache,
continuous batching over a fixed pool of ``--batch`` KV-cache slots (one
model iteration serves every active user — the paper's tensor-level
scheduling).  ``--mode batch`` selects the old run-to-completion loop
for A/B comparison.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--no-quant-kv", action="store_true")
    ap.add_argument("--bit-policy", default=None,
                    help="mixed-precision spec: uniform:<b>[a<ab>] | "
                         "rules:<regex>=<b>[a<ab>],... | auto:q<b> | "
                         "auto:<f>bpw | auto:q<b>a<ab>[,prt=measured]"
                         "[,maxseg=<n>] — a<ab> sets the lutmm activation "
                         "precision; auto:q<b>a<ab> jointly allocates "
                         "(wbits, abits) per layer within the projected "
                         "cycles of uniform (b, ab)")
    ap.add_argument("--mode", choices=("continuous", "batch"),
                    default="continuous")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max new prefill tokens admitted per iteration")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    args = ap.parse_args()

    import repro.configs as C
    from repro.models import lm
    from repro.serving.engine import Engine, EngineConfig

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the LM server")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(
        batch_size=args.batch, cache_len=args.cache_len, quantize=True,
        ql=args.ql, group_size=min(128, cfg.d_model),
        quant_kv=not args.no_quant_kv, mode=args.mode,
        bit_policy=args.bit_policy,
        prefill_budget=args.prefill_budget))
    quant_desc = (f"mixed-precision ({args.bit_policy})"
                  if eng.stats()["mixed_precision"] else f"Q{args.ql}")
    print(f"{cfg.name}: {quant_desc} weights "
          f"({eng.compression:.2f}x compression), "
          f"{'int8' if not args.no_quant_kv else 'f32'} KV, "
          f"{args.mode} scheduling")

    on_token = None
    if args.stream:
        on_token = lambda uid, tok: print(f"  [uid {uid}] {tok}")
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(4, 16))
        eng.submit(rng.integers(0, cfg.vocab, size=n).tolist(),
                   max_new_tokens=args.max_new, on_token=on_token)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    st = eng.stats()
    print(f"{st['requests']} requests, {st['generated_tokens']} tokens, "
          f"{st['generated_tokens']/dt:.2f} tok/s, "
          f"mean latency {st['mean_latency_s']:.2f}s "
          f"(p99 {st['p99_latency_s']:.2f}s), "
          f"mean TTFT {st['mean_ttft_s']:.2f}s, "
          f"{st['iterations']} model iterations "
          f"({st['prefill_iterations']} prefill / "
          f"{st['decode_iterations']} decode, "
          f"{st['prefill_tokens']} prompt tokens)")


if __name__ == "__main__":
    main()
