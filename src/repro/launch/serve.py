"""Production serving launcher (SAIL quantized path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --ql 4 --batch 8 --requests 16

Quantizes weights to ``--ql`` bits (QTensor storage), int8 KV cache,
continuous batching over a fixed pool of ``--batch`` KV-cache slots (one
model iteration serves every active user — the paper's tensor-level
scheduling).  ``--mode batch`` selects the old run-to-completion loop
for A/B comparison.

Precision planning (``repro.planning``):

    # serve a plan: grammar string or solved plan.json
    ... --plan "auto:q4a8,prt=measured,maxseg=4" --save-plan plan.json
    ... --plan plan.json          # reuse: no recalibration at startup

    # SLO-driven: derive the cycle+DRAM budgets from a target tokens/s
    ... --slo 80 --tap 512        # tap live traffic for later replans

``--bit-policy`` remains as a deprecated alias routed through
``PlanSpec.parse``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _ensure_tp_devices(argv=None) -> None:
    """``--tp M`` on a CPU host needs M visible XLA devices, and the
    forcing flag only works BEFORE jax initializes — scan argv and set it
    here so ``python -m repro.launch.serve --tp 4`` just works.  Real
    multi-device backends (and an explicit user XLA_FLAGS) are left
    alone."""
    argv = sys.argv[1:] if argv is None else argv
    tp = 1
    for i, a in enumerate(argv):
        if a == "--tp" and i + 1 < len(argv):
            tp = int(argv[i + 1])
        elif a.startswith("--tp="):
            tp = int(a.split("=", 1)[1])
    flags = os.environ.get("XLA_FLAGS", "")
    if tp > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={tp}").strip()


_ensure_tp_devices()

import jax  # noqa: E402  (after the device-count env fixup)
import numpy as np  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=None,
                    help="quantization group size (default "
                         "min(128, d_model)); under --tp the per-matrix "
                         "group count must divide the shard count")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--no-quant-kv", action="store_true")
    ap.add_argument("--plan", default=None,
                    help="precision plan: a grammar string "
                         "(uniform:<b>[a<ab>] | rules:<regex>=<b>[a<ab>],"
                         "... | auto:q<b>[a<ab>][,prt=...][,maxseg=<n>]"
                         "[,slo=<tps>] | auto:<f>bpw) or a path to a "
                         "plan.json written by --save-plan (solved plans "
                         "serve without recalibration)")
    ap.add_argument("--slo", type=float, default=None,
                    help="target decode tokens/s at --batch: auto plans "
                         "derive their cycle AND DRAM-byte budgets from "
                         "this instead of a fixed constant (implies "
                         "auto:q<ql>a8,prt=measured when --plan is "
                         "omitted)")
    ap.add_argument("--save-plan", default=None,
                    help="write the engine's (solved) plan JSON here")
    ap.add_argument("--tap", type=int, default=0, metavar="ROWS",
                    help="capture per-layer decode activations into an "
                         "ActivationTap of this capacity (enables online "
                         "PRT recalibration via Engine.replan)")
    ap.add_argument("--controller", action="store_true",
                    help="attach the autonomous SLO controller "
                         "(repro.serving.control.SloController): sheds/"
                         "shrinks occupancy against --slo and gates "
                         "replans on measured-vs-modeled drift")
    ap.add_argument("--deadband", type=float, default=None,
                    help="controller: |anchored drift| tolerated without "
                         "action (default 0.25)")
    ap.add_argument("--cooldown", type=int, default=None,
                    help="controller: decode iterations between actions "
                         "(default 32)")
    ap.add_argument("--check-every", type=int, default=None,
                    help="controller: decode iterations between drift "
                         "checks (default 8)")
    ap.add_argument("--bit-policy", default=None,
                    help="DEPRECATED alias for --plan (grammar strings "
                         "only)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shard count: shard the "
                         "quantized weight tree over a (1, M) mesh "
                         "(repro.serving.distributed).  On CPU the "
                         "launcher forces M host devices automatically; "
                         "a plan carrying tp= overrides this knob")
    ap.add_argument("--wire", type=int, default=32, choices=(8, 32),
                    help="TP all-reduce precision: 32 exact, 8 "
                         "compressed int8+scale partial sums")
    ap.add_argument("--mode", choices=("continuous", "batch"),
                    default="continuous")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max new prefill tokens admitted per iteration")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    args = ap.parse_args()

    import repro.configs as C
    from repro.models import lm
    from repro.planning import plan_from_arg
    from repro.serving.engine import Engine, EngineConfig

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the LM server")
    plan = plan_from_arg(args.plan) if args.plan is not None else None
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    controller = None
    if args.controller:
        knobs = {k: v for k, v in (("deadband", args.deadband),
                                   ("cooldown", args.cooldown),
                                   ("check_every", args.check_every))
                 if v is not None}
        controller = knobs or True
    eng = Engine(params, cfg, EngineConfig(
        batch_size=args.batch, cache_len=args.cache_len, quantize=True,
        ql=args.ql,
        group_size=(args.group_size if args.group_size is not None
                    else min(128, cfg.d_model)),
        quant_kv=not args.no_quant_kv, mode=args.mode,
        plan=plan, slo=args.slo, tap_capacity=args.tap,
        controller=controller, bit_policy=args.bit_policy,
        prefill_budget=args.prefill_budget, tp=args.tp, wire=args.wire))
    st = eng.stats()
    quant_desc = (f"mixed-precision plan {st['plan_hash']}"
                  if st["mixed_precision"]
                  else f"Q{args.ql} (plan {st['plan_hash']})")
    tp_desc = ""
    if st["tp"] is not None:
        tp_desc = (f", tp={st['tp']['shards']} "
                   f"(wire={st['tp']['wire_bits']})")
    print(f"{cfg.name}: {quant_desc} weights "
          f"({eng.compression:.2f}x compression), "
          f"{'int8' if not args.no_quant_kv else 'f32'} KV, "
          f"{args.mode} scheduling{tp_desc}")
    if args.save_plan and eng.plan is not None:
        eng.plan.save(args.save_plan)
        print(f"wrote plan {eng.plan.spec_hash} to {args.save_plan}")

    on_token = None
    if args.stream:
        on_token = lambda uid, tok: print(f"  [uid {uid}] {tok}")
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(4, 16))
        eng.submit(rng.integers(0, cfg.vocab, size=n).tolist(),
                   max_new_tokens=args.max_new, on_token=on_token)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    st = eng.stats()
    print(f"{st['requests']} requests, {st['generated_tokens']} tokens, "
          f"{st['generated_tokens']/dt:.2f} tok/s, "
          f"mean latency {st['mean_latency_s']:.2f}s "
          f"(p99 {st['p99_latency_s']:.2f}s), "
          f"mean TTFT {st['mean_ttft_s']:.2f}s, "
          f"{st['iterations']} model iterations "
          f"({st['prefill_iterations']} prefill / "
          f"{st['decode_iterations']} decode, "
          f"{st['prefill_tokens']} prompt tokens)")
    if st["measured_tps"] is not None and st["planned_tps"]:
        print(f"decode: measured {st['measured_tps']:.1f} tok/s vs "
              f"modeled {st['planned_tps']:.0f} tok/s at the full pool "
              f"(raw drift {st['drift']:+.3f} — absolute value is "
              f"meaningful once the plan carries host calibration)")
    if st["controller"] is not None:
        c = st["controller"]
        print(f"controller: batch cap {c['batch_cap']}, "
              f"{c['checks']} drift checks, "
              f"shed {c['shed']} / shrink {c['shrink']} / "
              f"replan {c['replan']} / resolve {c['resolve']}")
    if args.tap and eng.tap is not None:   # taps attach in continuous mode
        print(f"tap: {st['tapped_rows']} activation rows captured across "
              f"{eng.tap.n_layers} layers (Engine.replan() recalibrates "
              f"measured PRT discounts from them)")


if __name__ == "__main__":
    main()
