"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips/pod (single pod) or 2x16x16 = 512 chips (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    try:
        return jax.make_mesh(shape, axes)
    except (ValueError, RuntimeError):
        # jax.make_mesh wants exactly len(jax.devices()) in some versions;
        # build explicitly from the first n devices (dry-run uses 512
        # host devices for both meshes).
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def make_mesh(shape, axes) -> Mesh:
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def describe(mesh: Mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} on "
            f"{mesh.devices.size} devices ({mesh.devices.flat[0].platform})")
